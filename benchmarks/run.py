"""Benchmark harness — one module per paper table/claim. Prints
``name,us_per_call,derived`` CSV rows (spec format).

    PYTHONPATH=src python -m benchmarks.run [--only coherence,speed]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

SUITES = ["coherence", "speed", "fused", "pipeline", "compression",
          "srf_attention", "kernel_quality",
          "serving",   # serving/fused/pipeline run fast smoke modes;
                       # serving smoke covers kv/srf plus the hybrid and
                       # enc-dec mixed-geometry plans end to end
          "obs"]       # metrics-on vs metrics-off decode overhead


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of suites; default all")
    ap.add_argument("--roofline-in", default=None,
                    help="dryrun jsonl to append roofline rows")
    args = ap.parse_args(argv)
    picked = args.only.split(",") if args.only else SUITES

    print("name,us_per_call,derived")
    for suite in picked:
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t0 = time.time()
        for row in mod.run():
            print(row, flush=True)
        print(f"suite/{suite}/total,{(time.time()-t0)*1e6:.0f},done",
              flush=True)
    if args.roofline_in and os.path.exists(args.roofline_in):
        from benchmarks import roofline
        for row in roofline.run(args.roofline_in):
            print(row)
    return 0


if __name__ == "__main__":
    sys.exit(main())
