"""Benchmark harness — one module per paper table/claim. Prints
``name,us_per_call,derived`` CSV rows (spec format) through the obs
Reporter (the serving stack's single print sink).

    PYTHONPATH=src python -m benchmarks.run [--only coherence,speed]
    PYTHONPATH=src python -m benchmarks.run --check   # + regression gate
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.obs.report import Reporter

SUITES = ["coherence", "speed", "fused", "pipeline", "compression",
          "srf_attention", "kernel_quality",
          "serving",   # serving/fused/pipeline run fast smoke modes;
                       # serving smoke covers kv/srf plus the hybrid and
                       # enc-dec mixed-geometry plans end to end
          "obs"]       # metrics-on vs metrics-off decode overhead


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of suites; default all")
    ap.add_argument("--roofline-in", default=None,
                    help="dryrun jsonl to append roofline rows")
    ap.add_argument("--check", action="store_true",
                    help="after the suites, gate the BENCH_*.json "
                         "payloads against BENCH_history.jsonl "
                         "(benchmarks/regress.py); nonzero exit on a "
                         "regression")
    ap.add_argument("--bench-dir", default=".",
                    help="where BENCH_*.json / BENCH_history.jsonl live "
                         "(for --check)")
    args = ap.parse_args(argv)
    picked = args.only.split(",") if args.only else SUITES

    rep = Reporter()
    rep.line("name,us_per_call,derived")
    for suite in picked:
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t0 = time.time()
        for row in mod.run():
            rep.line(str(row))
        rep.line(f"suite/{suite}/total,{(time.time()-t0)*1e6:.0f},done")
    if args.roofline_in and os.path.exists(args.roofline_in):
        from benchmarks import roofline
        for row in roofline.run(args.roofline_in):
            rep.line(str(row))
    if args.check:
        from benchmarks import regress
        paths = regress.discover(args.bench_dir)
        history = os.path.join(args.bench_dir, regress.HISTORY)
        bad = regress.check_files(paths, history, reporter=rep)
        for msg in bad:
            rep.line(f"[regress] REGRESSION {msg}")
        rep.line(f"[regress] {'FAIL' if bad else 'PASS'}: "
                 f"{len(bad)} violation(s) across {len(paths)} "
                 f"payload(s)")
        return 1 if bad else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
