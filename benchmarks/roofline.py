"""Roofline table builder: reads dry-run records (jsonl) and renders the
EXPERIMENTS.md §Roofline table with the three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio, and the roofline fraction.

    PYTHONPATH=src python -m benchmarks.roofline --in dryrun_results.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.launch import hlo_analysis as H


def model_flops(rec: Dict) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D per decoded
    token; prefill like train without the backward (x 1/3)."""
    n = rec["active_params"]
    step = rec["step"]
    shape = rec["shape"]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    tokens = seq * batch
    if step == "train":
        return 6.0 * n * tokens
    if step == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * tokens          # decode: one token per sequence


def fraction(rec: Dict) -> float:
    """Useful work / roofline time, per device.

    Train/prefill: useful = MODEL_FLOPS at peak (an MFU bound).
    Decode: the workload is irreducibly memory-bound, so useful =
    the MINIMUM bytes a perfect implementation must stream per step
    (active params once per token batch + state/cache touch) at full HBM
    bandwidth."""
    chips = rec["chips"]
    t_roof = max(rec["t_compute"], rec["t_memory"], rec["t_collective"],
                 1e-12)
    if rec["step"] in ("train", "prefill"):
        t_useful = model_flops(rec) / chips / H.PEAK_FLOPS
    else:
        # weights live on the TP axis only (each data replica reads its
        # model shard every step), so per-chip useful bytes divide by the
        # model-axis width, not by all chips
        tp = 1
        for part in rec.get("mesh", "").split(" x "):
            if part.startswith("model="):
                tp = int(part.split("=")[1])
        param_bytes = 2.0 * rec["active_params"] / max(tp, 1)
        t_useful = param_bytes / H.HBM_BW
    return t_useful / t_roof


def lever(rec: Dict) -> str:
    b = rec["bottleneck"]
    if b == "memory":
        return ("cut activation/cache traffic (SP residuals, bf16 probs, "
                "fused feature-map kernel)")
    if b == "collective":
        return ("reshard to cut all-reduce bytes (SP, MoE a2a capacity, "
                "compressed cross-pod DP)")
    return "increase arithmetic intensity per chip (larger per-device batch)"


def render(records: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | step | T_comp(s) | T_mem(s) | T_coll(s) "
           "| dominant | model/hlo flops | fraction | fits HBM | note |")
    sep = "|" + "---|" * 12
    lines = [hdr, sep]
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                         f"| {r.get('step','?')} | - | - | - | FAILED | - | -"
                         f" | - | {r.get('error','')[:60]} |")
            continue
        mf = model_flops(r)
        ratio = mf / max(r["hlo_flops"] * r["chips"], 1e-9)
        frac = fraction(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | {r['step']} "
            f"| {r['t_compute']:.3f} | {r['t_memory']:.3f} "
            f"| {r['t_collective']:.3f} | {r['bottleneck']} "
            f"| {ratio:.2f} | {frac:.3f} | {r.get('fits_hbm')} "
            f"| {r.get('note','')[:40]} |")
    return "\n".join(lines)


def run(path: str) -> List[str]:
    records = [json.loads(l) for l in open(path) if l.strip()]
    out = []
    for r in records:
        if r.get("ok"):
            out.append(f"roofline/{r['arch']}/{r['shape']},0.0,"
                       f"dom={r['bottleneck']};frac={fraction(r):.3f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.jsonl")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    records = [json.loads(l) for l in open(args.inp) if l.strip()]
    if args.markdown:
        print(render(records))
    else:
        for row in run(args.inp):
            print(row)


if __name__ == "__main__":
    main()
