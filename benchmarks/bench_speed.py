"""Paper speed/space claim: structured matvec time & storage vs dense.

Measures wall time of the jit'd fast paths on this host (CPU) at sizes
where the asymptotics show, plus the analytic FLOPs/storage model used by
the roofline (the TPU numbers come from the dry-run, not wall time here).
"""
from __future__ import annotations

import statistics
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import structured as S
from repro.core import transforms as T

SIZES = [(1024, 1024), (4096, 4096)]
BATCH = 32
KINDS = ["unstructured", "circulant", "toeplitz"]


def _time(fn, *args, reps=5) -> float:
    """us per call: ONE warmup dispatch (jax.block_until_ready handles
    tuples and pytrees), then the median of ``reps`` timed calls."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


def run() -> List[str]:
    rows = []
    for m, n in SIZES:
        x = jax.random.normal(jax.random.PRNGKey(0), (BATCH, n))
        for kind in KINDS:
            params = S.init(jax.random.PRNGKey(1), kind, m, n)
            fast = jax.jit(lambda p, xx: S.matvec(kind, p, xx, m))
            us = _time(fast, params, x)
            rows.append(
                f"speed/matvec/{kind}/{m}x{n},{us:.1f},"
                f"storage_floats={S.storage_floats(kind, m, n)}")
        # FWHT vs dense hadamard matmul
        xf = jax.random.normal(jax.random.PRNGKey(2), (BATCH, n))
        f1 = jax.jit(T.fwht)
        us1 = _time(f1, xf)
        h = T.hadamard(n)
        f2 = jax.jit(lambda a: a @ h.T)
        us2 = _time(f2, xf)
        rows.append(f"speed/fwht/butterfly/{n},{us1:.1f},dense_us={us2:.1f}")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
