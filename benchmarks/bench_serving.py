"""Serving benchmark: paged continuous-batching engine vs the legacy
per-slot engine, and single-host vs mesh-sharded serving — tokens/s and
time-to-first-token across cache families and concurrency levels.

Suite mode (``python -m benchmarks.run --only serving``) runs a fast
smoke (kv/srf plus the mixed-geometry hybrid and enc-dec plans, 8
requests, one mesh cell) so the tier-1 flow exercises the serving path;
the full sweep (8–64 concurrent requests x all six families, hybrid and
enc-dec included) runs via

    PYTHONPATH=src python -m benchmarks.bench_serving --full

Emits machine-readable ``BENCH_serving.json`` (``BENCH_serving_smoke.json``
in smoke mode): paged-vs-legacy per family/concurrency, a 1-host vs
simulated 8-device-mesh comparison (2 router replicas x TP=2, run in a
subprocess so the forced host-platform device count cannot leak into
this process), a failover-cost cell (2-replica FT router, replica 1
chaos-killed mid-decode: requests/s dip vs the undisturbed run plus the
rescue latency read from the registry event stream), a shared-prefix
cell (64 requests at ~90% prompt overlap served cold vs with the radix
prefix cache + COW + chunked prefill: prefill-token reduction, TPOT-p95
ratio, bit-identity, leak check), and the ``launch/dryrun
--serve-chaos`` smoke verdict (subprocess, same device-count
isolation). ``--failover`` / ``--prefix`` re-measure ONLY that cell and
read-modify-write it into the committed ``BENCH_serving.json`` without
re-running the full sweep. CSV columns: name, us_per_call (wall us per
generated token), derived (tokens/s | mean ttft ms | preemptions).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax
import numpy as np

FAMILIES = [
    ("kv", "qwen3-4b", {}),
    ("srf", "qwen3-4b", {"attn_impl": "srf"}),
    ("mla", "deepseek-v2-lite-16b", {}),
    ("ssd", "mamba2-2.7b", {}),
    ("hybrid", "hymba-1.5b", {}),
    ("encdec", "seamless-m4t-large-v2", {}),
]

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _requests(cfg, n, seed=0):
    from repro.models import frontends
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        enc = (frontends.synthetic_audio_features(rng, cfg)
               if cfg.is_encdec else None)
        out.append(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(4, 20))
                                               ).astype(np.int32),
                           max_new=12, enc_emb=enc))
    return out


def _drive(eng, reqs):
    from repro.obs import latency_summary
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    ttft = np.mean([r.t_first - r.t_submit for r in done]) * 1e3
    return wall, toks, ttft, latency_summary(done)


def _pct_fields(summ) -> Dict:
    """Flatten a latency_summary into ttft_ms_p50/.../tpot_ms_p99 JSON
    fields (ms, rounded; None for empty samples so the JSON stays
    standard — json NaN is an extension)."""
    out = {}
    for kind in ("ttft", "tpot"):
        for pk, v in summ[f"{kind}_s"].items():
            out[f"{kind}_ms_{pk}"] = (round(v * 1e3, 2)
                                      if v == v else None)
    return out


def _bench_pair(fam, arch, over, concurrency, seed=0) -> Dict:
    """Paged vs legacy at one concurrency level -> one JSON record."""
    import warnings
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import Engine
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.serving import legacy
    cfg = registry.reduced(arch, **over)
    params = T.init(jax.random.PRNGKey(0), cfg)
    slots = min(concurrency, 16)

    eng = Engine(cfg, params, batch_slots=slots, max_len=64, seed=seed)
    wall_p, toks_p, ttft_p, summ_p = _drive(eng,
                                            _requests(cfg, concurrency, seed))

    leg = legacy.Engine(cfg, params, batch_slots=slots, max_len=64)
    wall_l, toks_l, ttft_l, summ_l = _drive(leg,
                                            _requests(cfg, concurrency, seed))

    return {"family": fam, "arch": arch, "concurrency": concurrency,
            "paged": {"tok_s": round(toks_p / wall_p, 2),
                      "ttft_ms": round(float(ttft_p), 1),
                      "us_per_tok": round(wall_p / max(toks_p, 1) * 1e6),
                      "preemptions": eng.sched.stats["preemptions"],
                      **_pct_fields(summ_p)},
            "legacy": {"tok_s": round(toks_l / wall_l, 2),
                       "ttft_ms": round(float(ttft_l), 1),
                       "us_per_tok": round(wall_l / max(toks_l, 1) * 1e6),
                       **_pct_fields(summ_l)},
            "speedup": round((toks_p / wall_p) / (toks_l / wall_l), 3)}


def _pair_rows(rec: Dict) -> List[str]:
    fam, c = rec["family"], rec["concurrency"]
    p, l = rec["paged"], rec["legacy"]
    return [
        f"serving/{fam}/paged/c{c},{p['us_per_tok']},"
        f"tok_s={p['tok_s']}|ttft_ms={p['ttft_ms']:.0f}"
        f"|preempt={p['preemptions']}",
        f"serving/{fam}/legacy/c{c},{l['us_per_tok']},"
        f"tok_s={l['tok_s']}|ttft_ms={l['ttft_ms']:.0f}|preempt=0",
        f"serving/{fam}/speedup/c{c},0,x{rec['speedup']:.2f}",
    ]


# ---------------------------------------------------------------------------
# failover cost: FT router with a chaos-killed replica vs undisturbed
# ---------------------------------------------------------------------------


def _bench_failover(concurrency: int = 16, seed: int = 0) -> Dict:
    """Serve the SAME request set twice through a 2-replica FT router —
    once undisturbed, once with replica 1 chaos-killed mid-decode
    (``raise`` at its 6th step) — and price the failover: requests/s
    dip, rescue latency (quarantine event -> last request re-homed,
    from the shared registry's event stream), the extra prefill/decode
    steps the forced-prefix replays cost, and whether the rescued
    greedy tokens stayed bit-identical (the exactly-once guarantee).

    Note the replicas step serially in this process (no real device
    parallelism), so the dip measures replay overhead, not the halved
    fleet capacity a production deployment would also see.

    The killed run also records span timelines on both replicas and the
    router, exports them as one merged Chrome-trace JSON
    (``TRACE_failover.json``; load in Perfetto), and verifies the
    quarantine -> rescue -> replay chain is present and uid-correlated
    in the exported events."""
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.obs import MetricsRegistry, SpanRecorder, chrome_trace
    from repro.serving import Engine, FTConfig, Router
    from repro.serving.chaos import ChaosEngine, ChaosPlan

    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    slots = max(2, min(concurrency, 16) // 2)   # per replica

    def serve(kill: bool, n: int = concurrency, recorders=None) -> Dict:
        reg = MetricsRegistry()
        spans = recorders or [None] * 3
        engines = [Engine(cfg, params, batch_slots=slots, max_len=64,
                          seed=seed + i, metrics=reg, spans=spans[i])
                   for i in range(2)]
        if kill:
            engines[1] = ChaosEngine(engines[1],
                                     ChaosPlan("raise", at_step=6))
        router = Router(engines, metrics=reg, ft=FTConfig(),
                        spans=spans[2])
        reqs = _requests(cfg, n, seed)
        wall, toks, _, _ = _drive(router, reqs)
        return {"reg": reg, "wall": wall, "toks": toks,
                "steps": int(reg.value_sum("engine_prefill_steps_total")
                             + reg.value_sum("engine_decode_steps_total")),
                "out": {r.uid: r.out_tokens for r in reqs}}

    serve(kill=False, n=4)      # warm the jit caches: without this the
    clean = serve(kill=False)   # clean run eats compile time and the
                                # "dip" comes out negative
    recorders = [SpanRecorder(replica=i) for i in range(3)]
    killed = serve(kill=True, recorders=recorders)
    trace = chrome_trace(recorders)
    trace_path = os.environ.get("REPRO_BENCH_TRACE_JSON",
                                "TRACE_failover.json")
    with open(trace_path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    evs = killed["reg"].events
    t_q = next((e["t"] for e in evs if e["event"] == "quarantined"), None)
    t_home = [e["t"] for e in evs
              if e["event"] in ("rescued", "replayed")]
    rescue_s = (round(max(t_home) - t_q, 4)
                if t_q is not None and t_home else None)
    req_s_clean = concurrency / clean["wall"]
    req_s_killed = concurrency / killed["wall"]
    kv = killed["reg"].value_sum
    return {
        "concurrency": concurrency, "replicas": 2, "fault": "raise@6:1",
        "clean": {"req_s": round(req_s_clean, 2),
                  "tok_s": round(clean["toks"] / clean["wall"], 2),
                  "engine_steps": clean["steps"]},
        "killed": {"req_s": round(req_s_killed, 2),
                   "tok_s": round(killed["toks"] / killed["wall"], 2),
                   "engine_steps": killed["steps"],
                   "quarantined": int(kv("router_quarantined_total")),
                   "rescued": int(kv("router_rescued_total")),
                   "replayed": int(kv("router_replayed_total")),
                   "failed": int(kv("router_failed_total"))},
        "req_s_dip_pct": round(100.0 * (1.0 - req_s_killed / req_s_clean),
                               1),
        "replay_extra_steps": killed["steps"] - clean["steps"],
        "rescue_latency_s": rescue_s,
        "tokens_match_clean": bool(killed["out"] == clean["out"]),
        "trace": _verify_failover_trace(trace, trace_path),
    }


def _verify_failover_trace(trace: Dict, path: str) -> Dict:
    """Check the exported chaos-kill Chrome trace actually tells the
    failover story: a quarantine instant on the router timeline followed
    by per-request rescue (waiting seq adopted) or replay (running seq
    re-prefilled) instants, every one uid-tagged and timestamped at or
    after the quarantine — i.e. the recovery of each request can be
    followed through the merged timeline by its uid."""
    evs = trace["traceEvents"]
    inst = [e for e in evs if e.get("ph") == "i"]
    t_q = min((e["ts"] for e in inst if e["name"] == "quarantine"),
              default=None)
    rescue = {e["args"]["uid"]: e["ts"] for e in inst
              if e["name"] == "rescue"}
    replay = {e["args"]["uid"]: e["ts"] for e in inst
              if e["name"] == "replay"}
    moved = {**rescue, **replay}
    correlated = (t_q is not None and len(moved) > 0
                  and all(u is not None for u in moved)
                  and all(t >= t_q for t in moved.values()))
    return {"path": path, "events": len(evs),
            "timelines": len({e.get("pid") for e in evs}),
            "quarantine": sum(e["name"] == "quarantine" for e in inst),
            "rescue_uids": sorted(rescue), "replay_uids": sorted(replay),
            "chain_uid_correlated": bool(correlated)}


def _failover_rows(rec: Dict) -> List[str]:
    c = rec["concurrency"]
    cl, kd = rec["clean"], rec["killed"]
    return [
        f"serving/failover/clean/c{c},0,"
        f"req_s={cl['req_s']}|tok_s={cl['tok_s']}",
        f"serving/failover/killed/c{c},0,"
        f"req_s={kd['req_s']}|tok_s={kd['tok_s']}"
        f"|dip_pct={rec['req_s_dip_pct']}",
        f"serving/failover/rescue/c{c},0,"
        f"latency_s={rec['rescue_latency_s']}"
        f"|extra_steps={rec['replay_extra_steps']}"
        f"|match={rec['tokens_match_clean']}|failed={kd['failed']}",
        f"serving/failover/trace/c{c},0,"
        f"events={rec['trace']['events']}"
        f"|timelines={rec['trace']['timelines']}"
        f"|chain_uid_correlated={rec['trace']['chain_uid_correlated']}",
    ]


# ---------------------------------------------------------------------------
# shared-prefix serving: prefix cache + COW + chunked prefill vs cold
# ---------------------------------------------------------------------------


def _bench_prefix(concurrency: int = 64, slots: int = 16,
                  seed: int = 0) -> Dict:
    """Serve ``concurrency`` requests sharing a 36-token prompt prefix
    (~90% of the prompt) twice through one paged engine — cold, and
    with the radix prefix cache + chunked prefill armed — after an
    identical 4-request donor warm-up in both runs (which also warms
    the jit caches). Prices the subsystem: prefill-token reduction
    (admission throughput — a hit skips its matched tokens), end-to-end
    tokens/s, decode-p95-TPOT ratio under chunked prefill (must stay
    ~1x: interleaving bounds decode starvation), greedy bit-identity,
    and zero leaked pages after dropping the cache."""
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.obs import MetricsRegistry
    from repro.serving import ChunkConfig, Engine, PrefixConfig, Request

    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, 36).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab,
                          3 + int(rng.integers(0, 3))).astype(np.int32)
             for _ in range(concurrency)]
    mean_len = 36 + float(np.mean([len(t) for t in tails]))

    def serve(prefix) -> Dict:
        reg = MetricsRegistry()
        eng = Engine(cfg, params, batch_slots=slots, max_len=64,
                     seed=seed, metrics=reg, prefix=prefix)
        for i in range(4):                      # donor warm-up (+ jit)
            eng.submit(Request(uid=1000 + i, prompt=shared.copy(),
                               max_new=4))
        eng.run()
        pre0 = reg.value_sum("engine_prefill_tokens_total")
        reqs = [Request(uid=i, prompt=np.concatenate([shared, t]),
                        max_new=12) for i, t in enumerate(tails)]
        wall, toks, _, summ = _drive(eng, reqs)
        rec = {"wall": wall, "toks": toks,
               "prefill_tokens": int(reg.value_sum(
                   "engine_prefill_tokens_total") - pre0),
               "tpot_p95_s": summ["tpot_s"]["p95"],
               "out": {r.uid: r.out_tokens for r in reqs}}
        if eng.prefix is not None:
            v = reg.value_sum
            rec.update({
                "hit_rate": round(v("prefix_hits_total")
                                  / v("prefix_lookups_total"), 3),
                "hit_tokens": int(v("prefix_hit_tokens_total")),
                "cow_forks": int(v("prefix_cow_forks_total")),
                "evictions": int(v("prefix_evictions_total")),
                "cache_pages": eng.prefix.pages,
            })
            eng.prefix.drop_all()
            rec["leaked_pages_after_drop"] = eng.sched.alloc.used_pages
        return rec

    cold = serve(None)
    warm = serve(PrefixConfig(chunk=ChunkConfig(chunk_tokens=32)))
    out_cold = cold.pop("out")
    out_warm = warm.pop("out")
    return {
        "concurrency": concurrency, "slots": slots, "arch": "qwen3-4b",
        "overlap_pct": round(100.0 * 36 / mean_len, 1),
        "cold": {"tok_s": round(cold["toks"] / cold["wall"], 2),
                 "prefill_tokens": cold["prefill_tokens"],
                 "tpot_ms_p95": round(cold["tpot_p95_s"] * 1e3, 2)},
        "warm": {"tok_s": round(warm["toks"] / warm["wall"], 2),
                 "prefill_tokens": warm["prefill_tokens"],
                 "tpot_ms_p95": round(warm["tpot_p95_s"] * 1e3, 2),
                 "hit_rate": warm["hit_rate"],
                 "hit_tokens": warm["hit_tokens"],
                 "cow_forks": warm["cow_forks"],
                 "evictions": warm["evictions"],
                 "cache_pages": warm["cache_pages"]},
        "prefill_reduction_x": round(cold["prefill_tokens"]
                                     / max(warm["prefill_tokens"], 1), 2),
        "tpot_p95_ratio": round(warm["tpot_p95_s"]
                                / max(cold["tpot_p95_s"], 1e-9), 3),
        "tokens_match_cold": bool(out_warm == out_cold),
        "leaked_pages_after_drop": warm["leaked_pages_after_drop"],
    }


def _prefix_rows(rec: Dict) -> List[str]:
    c = rec["concurrency"]
    cl, wm = rec["cold"], rec["warm"]
    return [
        f"serving/prefix/cold/c{c},0,"
        f"tok_s={cl['tok_s']}|prefill_toks={cl['prefill_tokens']}"
        f"|tpot_ms_p95={cl['tpot_ms_p95']}",
        f"serving/prefix/warm/c{c},0,"
        f"tok_s={wm['tok_s']}|prefill_toks={wm['prefill_tokens']}"
        f"|hit_rate={wm['hit_rate']}|forks={wm['cow_forks']}",
        f"serving/prefix/quality/c{c},0,"
        f"prefill_x={rec['prefill_reduction_x']}"
        f"|tpot_p95_ratio={rec['tpot_p95_ratio']}"
        f"|match={rec['tokens_match_cold']}"
        f"|leaked={rec['leaked_pages_after_drop']}",
    ]


# ---------------------------------------------------------------------------
# chaos smoke: launch/dryrun --serve-chaos (subprocess: the forced
# 8-device host platform must not leak into this process)
# ---------------------------------------------------------------------------


def _chaos_smoke() -> Dict:
    env = dict(os.environ, PYTHONPATH=_SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    try:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--serve-chaos"],
            env=env, capture_output=True, text=True, timeout=900)
        for line in out.stdout.splitlines():
            if line.startswith("{"):
                return json.loads(line)
        return {"ok": False, "error": "no JSON line",
                "stderr": out.stderr[-1500:]}
    except Exception as e:                      # keep the suite alive
        return {"ok": False, "error": f"{type(e).__name__}: {e}"}


def _chaos_rows(rec: Dict) -> List[str]:
    if not rec.get("ok"):
        return [f"serving/chaos_smoke/error,0,"
                f"{str(rec.get('error', 'failed'))[:60]}"]
    return [
        f"serving/chaos_smoke,0,ok={rec['ok']}"
        f"|quarantined={rec['quarantined']}"
        f"|match={rec['tokens_match_undisturbed']}"
        f"|revived={rec['revived']}|total_s={rec['total_s']}",
    ]


# ---------------------------------------------------------------------------
# 1-host vs simulated 8-device mesh (subprocess: forced device count must
# not leak into the calling process)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np
from repro.configs import registry
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.serving import Engine, Request, Router

cfg = registry.reduced("qwen3-4b", n_layers=2)
params = T.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
def reqs(n):
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab,
                    int(rng.integers(4, 20))).astype(np.int32), max_new=12)
            for i in range(n)]

def drive(eng, rs):
    for r in rs: eng.submit(r)
    t0 = time.perf_counter(); done = eng.run()
    wall = time.perf_counter() - t0
    return wall, sum(len(r.out_tokens) for r in done), {r.uid: r.out_tokens
                                                        for r in done}

N = 16
rng = np.random.default_rng(0)
single = Engine(cfg, params, batch_slots=8, max_len=64)
w1, t1, out1 = drive(single, reqs(N))
rng = np.random.default_rng(0)
meshes = mesh_lib.make_serving_meshes(replicas=2, model_parallel=2)
router = Router([Engine(cfg, params, batch_slots=8, max_len=64, mesh=m)
                 for m in meshes])
w2, t2, out2 = drive(router, reqs(N))
rep = router.engines[0].cache_report()
print("MESHJSON " + json.dumps({
    "requests": N, "replicas": 2, "model_parallel": 2,
    "single_host": {"tok_s": round(t1 / w1, 2), "pool_bytes":
                    single.cache_report()["pool_bytes"]},
    "mesh": {"tok_s": round(t2 / w2, 2),
             "pool_bytes_per_device": rep["pool_bytes_per_device"],
             "migrations": router.stats["migrations"]},
    "tokens_match": out1 == out2,
}))
"""


def _bench_mesh() -> Dict:
    env = dict(os.environ, PYTHONPATH=_SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    try:
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=900)
        for line in out.stdout.splitlines():
            if line.startswith("MESHJSON "):
                return json.loads(line[len("MESHJSON "):])
        return {"error": "no MESHJSON line",
                "stderr": out.stderr[-1500:]}
    except Exception as e:                      # keep the suite alive
        return {"error": f"{type(e).__name__}: {e}"}


def _mesh_rows(rec: Dict) -> List[str]:
    if "error" in rec:
        return [f"serving/mesh/error,0,{rec['error'][:60]}"]
    s, m = rec["single_host"], rec["mesh"]
    return [
        f"serving/mesh/single_host/c{rec['requests']},0,"
        f"tok_s={s['tok_s']}|pool_bytes={s['pool_bytes']}",
        f"serving/mesh/router2xTP2/c{rec['requests']},0,"
        f"tok_s={m['tok_s']}|pool_bytes_dev={m['pool_bytes_per_device']}"
        f"|match={rec['tokens_match']}",
    ]


def run(full: bool = False):
    """Suite entry point: fast smoke by default. Streams CSV rows as each
    cell finishes (the mesh subprocess runs LAST so paged-vs-legacy
    progress is visible while it compiles) and writes the collected JSON
    payload at the end."""
    if full:
        plan = [(fam, arch, over, c) for fam, arch, over in FAMILIES
                for c in (8, 16, 32, 64)]
    else:
        # smoke covers the structured-feature family plus one mixed-
        # geometry plan each: hybrid (kv pages + ssd slots) and enc-dec
        # (kv pages + encoder-memory slots)
        plan = [("kv", "qwen3-4b", {}, 8),
                ("srf", "qwen3-4b", {"attn_impl": "srf"}, 8),
                ("hybrid", "hymba-1.5b", {}, 8),
                ("encdec", "seamless-m4t-large-v2", {}, 8)]
    pairs = []
    for fam, arch, over, c in plan:
        rec = _bench_pair(fam, arch, over, c)
        pairs.append(rec)
        yield from _pair_rows(rec)
    failover = _bench_failover(16)
    yield from _failover_rows(failover)
    shared_prefix = _bench_prefix(64 if full else 16)
    yield from _prefix_rows(shared_prefix)
    mesh = _bench_mesh()
    yield from _mesh_rows(mesh)
    chaos = _chaos_smoke()
    yield from _chaos_rows(chaos)
    payload = {
        "bench": "serving",
        "smoke": not full,
        "backend": jax.default_backend(),
        "paged_vs_legacy": pairs,
        "failover": failover,
        "shared_prefix": shared_prefix,
        "mesh_vs_single_host": mesh,
        "chaos_smoke": chaos,
    }
    default = "BENCH_serving.json" if full else "BENCH_serving_smoke.json"
    path = os.environ.get("REPRO_BENCH_SERVING_JSON", default)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def main(argv=None):
    args = list(argv if argv is not None else sys.argv[1:])
    if "--failover" in args:
        # re-measure ONLY the failover cell and splice it into the
        # committed full-sweep JSON (the sweep itself takes far longer)
        print("name,us_per_call,derived")
        rec = _bench_failover(16)
        for row in _failover_rows(rec):
            print(row, flush=True)
        path = os.environ.get("REPRO_BENCH_SERVING_JSON",
                              "BENCH_serving.json")
        payload = {"bench": "serving"}
        if os.path.exists(path):
            with open(path) as f:
                payload = json.load(f)
        payload["failover"] = rec
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        return 0
    if "--prefix" in args:
        # re-measure ONLY the shared-prefix cell and splice it into the
        # committed full-sweep JSON (same pattern as --failover)
        print("name,us_per_call,derived")
        rec = _bench_prefix(64)
        for row in _prefix_rows(rec):
            print(row, flush=True)
        path = os.environ.get("REPRO_BENCH_SERVING_JSON",
                              "BENCH_serving.json")
        payload = {"bench": "serving"}
        if os.path.exists(path):
            with open(path) as f:
                payload = json.load(f)
        payload["shared_prefix"] = rec
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        return 0
    full = "--full" in args
    print("name,us_per_call,derived")
    for row in run(full=full):
        print(row, flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
