"""Serving benchmark: paged continuous-batching engine vs the legacy
per-slot engine — tokens/s and time-to-first-token across cache families
and concurrency levels.

Suite mode (``python -m benchmarks.run --only serving``) runs a fast
smoke (one family, 8 requests) so the tier-1 flow exercises the serving
path; the full sweep (8–64 concurrent requests x all four families) runs
via

    PYTHONPATH=src python -m benchmarks.bench_serving --full

CSV columns: name, us_per_call (wall us per generated token), derived
(tokens/s | mean ttft ms | preemptions).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

FAMILIES = [
    ("kv", "qwen3-4b", {}),
    ("srf", "qwen3-4b", {"attn_impl": "srf"}),
    ("mla", "deepseek-v2-lite-16b", {}),
    ("ssd", "mamba2-2.7b", {}),
]


def _requests(cfg, n, seed=0):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 20))
                                        ).astype(np.int32),
                    max_new=12) for i in range(n)]


def _drive(eng, reqs):
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    ttft = np.mean([r.t_first - r.t_submit for r in done]) * 1e3
    return wall, toks, ttft


def _bench_pair(fam, arch, over, concurrency, seed=0):
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import Engine
    from repro.serving import legacy
    cfg = registry.reduced(arch, **over)
    params = T.init(jax.random.PRNGKey(0), cfg)
    slots = min(concurrency, 16)

    eng = Engine(cfg, params, batch_slots=slots, max_len=64, seed=seed)
    wall_p, toks_p, ttft_p = _drive(eng, _requests(cfg, concurrency, seed))

    leg = legacy.Engine(cfg, params, batch_slots=slots, max_len=64)
    wall_l, toks_l, ttft_l = _drive(leg, _requests(cfg, concurrency, seed))

    pre = eng.sched.stats["preemptions"]
    yield (f"serving/{fam}/paged/c{concurrency},"
           f"{wall_p / max(toks_p, 1) * 1e6:.0f},"
           f"tok_s={toks_p / wall_p:.1f}|ttft_ms={ttft_p:.0f}|preempt={pre}")
    yield (f"serving/{fam}/legacy/c{concurrency},"
           f"{wall_l / max(toks_l, 1) * 1e6:.0f},"
           f"tok_s={toks_l / wall_l:.1f}|ttft_ms={ttft_l:.0f}|preempt=0")
    yield (f"serving/{fam}/speedup/c{concurrency},0,"
           f"x{(toks_p / wall_p) / (toks_l / wall_l):.2f}")


def run(full: bool = False):
    """Suite entry point: fast smoke by default."""
    if full:
        for fam, arch, over in FAMILIES:
            for c in (8, 16, 32, 64):
                yield from _bench_pair(fam, arch, over, c)
    else:
        yield from _bench_pair("kv", "qwen3-4b", {}, 8)
        yield from _bench_pair("srf", "qwen3-4b", {"attn_impl": "srf"}, 8)


def main(argv=None):
    full = "--full" in (argv or sys.argv[1:])
    print("name,us_per_call,derived")
    for row in run(full=full):
        print(row, flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
