"""Observability overhead bench: metrics-on vs metrics-off decode delta.

The registry's design contract (``src/repro/obs/metrics.py``) is that a
bound metric update costs the same as the ad-hoc ``stats`` dict write it
replaced, and a disabled registry costs nothing. This cell pins that:
the SAME engine/workload runs once with an enabled registry and once
with ``MetricsRegistry(enabled=False)``, and the per-token wall-time
delta is reported. The acceptance bar is < 2% regression for the
disabled registry vs enabled (both are dominated by the jit'd step; the
host-side accounting is noise-level).

Suite mode (``python -m benchmarks.run --only obs``) runs one cell;
rows follow the harness CSV spec (name, us_per_call, derived).
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np


def _drive(metrics_enabled: bool, params, cfg, n=8, max_new=32, seed=0):
    from repro.obs import MetricsRegistry
    from repro.serving import Engine, Request
    reg = MetricsRegistry(enabled=metrics_enabled)
    eng = Engine(cfg, params, batch_slots=8, max_len=64, seed=seed,
                 metrics=reg)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=max_new) for i in range(n)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    return wall, toks


def run() -> List[str]:
    from repro.configs import registry
    from repro.models import transformer as T
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    _drive(True, params, cfg, n=2, max_new=4)       # jit warm-up (shared)
    # alternating repeats, min per mode: the jit'd step wall time jitters
    # ~10-15% run-to-run on CPU, far above the host-side accounting being
    # measured; min-of-k is the standard noise-robust point estimate
    reps = 4
    us_on = us_off = float("inf")
    for i in range(reps):
        # flip the pair order each rep: a monotone load drift otherwise
        # systematically favors whichever mode always runs second
        for enabled in ((True, False) if i % 2 == 0 else (False, True)):
            wall, toks = _drive(enabled, params, cfg)
            us = wall / max(toks, 1) * 1e6
            if enabled:
                us_on = min(us_on, us)
            else:
                us_off = min(us_off, us)
    delta_pct = (us_on - us_off) / us_off * 100.0
    yield f"obs/decode/metrics_on,{us_on:.0f},best_of={reps}"
    yield f"obs/decode/metrics_off,{us_off:.0f},best_of={reps}"
    yield f"obs/decode/overhead,0,delta_pct={delta_pct:+.2f}"


def main(argv=None):
    print("name,us_per_call,derived")
    for row in run():
        print(row, flush=True)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
