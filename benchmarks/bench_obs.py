"""Observability overhead bench: metrics-on vs metrics-off decode delta,
plus spans-on vs spans-off.

The registry's design contract (``src/repro/obs/metrics.py``) is that a
bound metric update costs the same as the ad-hoc ``stats`` dict write it
replaced, and a disabled registry costs nothing. This cell pins that:
the SAME engine/workload runs once with an enabled registry and once
with ``MetricsRegistry(enabled=False)``, and the per-token wall-time
delta is reported. The acceptance bar is < 2% regression for the
disabled registry vs enabled (both are dominated by the jit'd step; the
host-side accounting is noise-level).

The span recorder (``src/repro/obs/spans.py``) makes the same promise —
begin/end is two ``perf_counter`` reads and a deque append on the hot
control path — so the second cell pins span-timeline overhead the same
way (acceptance: within 3%, per the regression-gate threshold on the
``us_per_tok`` cells).

Suite mode (``python -m benchmarks.run --only obs``) runs both cells;
rows follow the harness CSV spec (name, us_per_call, derived).
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np


def _drive(metrics_enabled: bool, params, cfg, n=8, max_new=32, seed=0,
           spans=None):
    from repro.obs import MetricsRegistry
    from repro.serving import Engine, Request
    reg = MetricsRegistry(enabled=metrics_enabled)
    eng = Engine(cfg, params, batch_slots=8, max_len=64, seed=seed,
                 metrics=reg, spans=spans)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=max_new) for i in range(n)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    return wall, toks


def _min_of_alternating(run_a, run_b, reps=4):
    """Best-of-k per mode with pair order flipped each rep: the jit'd
    step wall time jitters ~10-15% run-to-run on CPU, far above the
    host-side accounting being measured, and a monotone load drift
    otherwise systematically favors whichever mode always runs second."""
    us_a = us_b = float("inf")
    for i in range(reps):
        for which in ((0, 1) if i % 2 == 0 else (1, 0)):
            wall, toks = (run_a if which == 0 else run_b)()
            us = wall / max(toks, 1) * 1e6
            if which == 0:
                us_a = min(us_a, us)
            else:
                us_b = min(us_b, us)
    return us_a, us_b


def run() -> List[str]:
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.obs import SpanRecorder
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    _drive(True, params, cfg, n=2, max_new=4)       # jit warm-up (shared)
    reps = 4

    us_on, us_off = _min_of_alternating(
        lambda: _drive(True, params, cfg),
        lambda: _drive(False, params, cfg), reps)
    delta_pct = (us_on - us_off) / us_off * 100.0
    yield f"obs/decode/metrics_on,{us_on:.0f},best_of={reps}"
    yield f"obs/decode/metrics_off,{us_off:.0f},best_of={reps}"
    yield f"obs/decode/overhead,0,delta_pct={delta_pct:+.2f}"

    # span-timeline overhead: recorder armed (fresh per run so the ring
    # never saturates) vs spans=None (module NOOP recorder inside the
    # engine). Same workload, same registry mode (enabled) for both.
    us_spans, us_plain = _min_of_alternating(
        lambda: _drive(True, params, cfg, spans=SpanRecorder(replica=0)),
        lambda: _drive(True, params, cfg), reps)
    sdelta_pct = (us_spans - us_plain) / us_plain * 100.0
    yield f"obs/decode/spans_on,{us_spans:.0f},best_of={reps}"
    yield f"obs/decode/spans_off,{us_plain:.0f},best_of={reps}"
    yield f"obs/decode/spans_overhead,0,delta_pct={sdelta_pct:+.2f}"


def main(argv=None):
    from repro.obs.report import Reporter
    rep = Reporter()
    rep.line("name,us_per_call,derived")
    for row in run():
        rep.line(str(row))
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
