"""Paper Sec 2.2 / Figs 1-2: coherence parameters per structure class.

chi[P] (chromatic number of coherence graphs), mu[P], mu~[P], the
normalization property and Lemma-5 orthogonality — computed numerically
from the generic jacobian-recovered P_i matrices.
"""
from __future__ import annotations

from typing import List

import jax

from repro.core import coherence as C
from repro.core import structured as S

KINDS = ["unstructured", "circulant", "skew_circulant", "toeplitz", "hankel",
         "ldr"]
M, N = 6, 8


def run() -> List[str]:
    rows = []
    for kind in KINDS:
        params = S.init(jax.random.PRNGKey(0), kind, M, N, r=2)
        st = C.pmodel_stats(kind, params, M, N)
        rows.append(
            f"coherence/{kind},0.0,chi={st['chi']:.0f};mu={st['mu']:.3f};"
            f"mu_tilde={st['mu_tilde']:.4f};t={st['budget_t']:.0f};"
            f"normalized={st['normalized']:.0f};"
            f"orth={st['orthogonal_cols']:.0f}")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
