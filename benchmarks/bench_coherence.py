"""Paper Sec 2.2 / Figs 1-2: coherence parameters per structure class.

chi[P] (chromatic number of coherence graphs), mu[P], mu~[P], the
normalization property and Lemma-5 orthogonality — computed numerically
from the generic jacobian-recovered P_i matrices, per SpinnerBlock; a
stacked pipeline gets one report per block (the concentration machinery
applies blockwise).
"""
from __future__ import annotations

from typing import List

import jax

from repro.core import coherence as C
from repro.core import spinner

KINDS = ["unstructured", "circulant", "skew_circulant", "toeplitz", "hankel",
         "ldr"]
M, N = 6, 8


def _fmt(tag: str, st) -> str:
    return (f"coherence/{tag},0.0,chi={st['chi']:.0f};mu={st['mu']:.3f};"
            f"mu_tilde={st['mu_tilde']:.4f};t={st['budget_t']:.0f};"
            f"normalized={st['normalized']:.0f};"
            f"orth={st['orthogonal_cols']:.0f}")


def run() -> List[str]:
    rows = []
    for kind in KINDS:
        blk = spinner.SpinnerBlock(kind, M, N, r=2, use_hd=False)
        st = C.block_stats(blk, blk.init(jax.random.PRNGKey(0)))
        rows.append(_fmt(kind, st))
    # stacked pipeline: per-block reports (index-aligned with pipe.blocks)
    pipe = spinner.hd_chain("circulant", n=N, m=M, depth=2)
    params = pipe.init(jax.random.PRNGKey(1))
    for i, st in enumerate(C.pipeline_stats(pipe, params)):
        rows.append(_fmt(f"pipeline_d2/circulant/blk{i}", st))
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
