"""Fused-spinner benchmark: one-pass f(A . D1 H D0 . x) vs the unfused
three-dispatch pipeline (hd_preprocess -> structured.matvec -> pointwise f)
vs the dense O(mn) matmul, per structured kind x epilogue.

Emits machine-readable ``BENCH_fused.json`` (per-kind / per-epilogue us,
plus the seeded-vs-materialized cell: zero-storage in-kernel
regeneration throughput ratio, weight-bytes reduction, and the
seeded==oracle bit-match invariant) so the perf trajectory accumulates
across PRs, plus the CSV rows of the bench harness. ``python -m benchmarks.bench_fused`` runs the full
acceptance shape (B=256, n=1024, m=4096); the run.py suite calls
``run()`` which uses a small smoke shape to keep the suite fast.

Env: REPRO_BENCH_FUSED_JSON overrides the JSON output path.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import spinner, structured, transforms
from repro.kernels import ops as kops

FULL_SHAPE = (256, 1024, 4096)          # B, n, m — acceptance shape
SMOKE_SHAPE = (64, 256, 512)
KINDS = ("circulant", "skew_circulant", "toeplitz", "hankel")
EPILOGUES = ("identity", "relu", "exp", "cos_sin")

_EPI_FN = {
    "identity": lambda y, sq: y,
    "relu": lambda y, sq: jax.nn.relu(y),
    "heaviside": lambda y, sq: (y >= 0).astype(y.dtype),
    "sign": lambda y, sq: jnp.sign(y),
    "exp": lambda y, sq: jnp.exp(y - sq),
    "cos_sin": lambda y, sq: jnp.concatenate([jnp.cos(y), jnp.sin(y)], -1),
}


def _time_interleaved(fns_args, reps: int = 10, patience: int = 12,
                      max_reps: int = 80) -> List[float]:
    """Best-of-reps per candidate, candidates interleaved inside each rep
    so background load hits them evenly (this host is a shared 2-vCPU box
    with invisible co-tenants; sequential medians swing +/-50%). After the
    ``reps`` floor, keep going until NO candidate's minimum has improved
    for ``patience`` consecutive rounds — min-of-converged-reps estimates
    the quiet-window (intrinsic) cost for every candidate equally."""
    for fn, args in fns_args:
        jax.block_until_ready(fn(*args))           # warmup / compile
    best = [float("inf")] * len(fns_args)
    stale, done = 0, 0
    while done < reps or (stale < patience and done < max_reps):
        improved = False
        for i, (fn, args) in enumerate(fns_args):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            dt = time.perf_counter() - t0
            if dt < best[i] * 0.995:
                improved = True
            best[i] = min(best[i], dt)
        stale = 0 if improved else stale + 1
        done += 1
    return [t * 1e6 for t in best]


def _bench_one(kind: str, epilogue: str, b: int, n: int, m: int,
               reps: int, patience: int = 12, max_reps: int = 80) -> Dict:
    """Times the phi-style feature map  f(A D1 H D0 x) / sqrt(m)  — the
    actual SRF / feature hot path, including the 1/sqrt(m) feature
    scaling that the pre-fusion pipeline paid as its own pass."""
    pipe = spinner.single(kind, m=m, n=n, f=epilogue)
    (params,) = pipe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, n)) * 0.3
    inv_sqrt_m = float(m) ** -0.5

    # --- unfused: the pre-fusion hot path, one dispatch per stage
    # (hd_preprocess -> structured.matvec -> pointwise f + /sqrt(m), as
    # features.phi_* composed it before the fused spinner) ------------------
    hd = jax.jit(lambda p, xx: transforms.hd_preprocess(xx, p["d0"], p["d1"]))
    mv = jax.jit(lambda p, v: structured.matvec(kind, p, v, m))
    epi = _EPI_FN[epilogue]
    ep = jax.jit(lambda xx, y: epi(
        y, 0.5 * jnp.sum(xx * xx, -1, keepdims=True)) / jnp.sqrt(
            jnp.asarray(float(m), y.dtype)))

    def unfused(p, xx):
        return ep(xx, mv(p, hd(p, xx)))

    # --- unfused_1jit: same pre-fusion graph under ONE jit (how consumers
    # that jit their whole step saw it — XLA fuses the pointwise stages
    # but keeps the butterfly FWHT and per-stage intermediates) ------------
    @jax.jit
    def unfused_1jit(p, xx):
        v = transforms.hd_preprocess(xx, p["d0"], p["d1"])
        y = structured.matvec(kind, p, v, m)
        return epi(y, 0.5 * jnp.sum(xx * xx, -1, keepdims=True)) \
            / jnp.sqrt(jnp.asarray(float(m), y.dtype))

    # --- fused: one 1-block SpinnerPipeline.apply (identical dispatch to a
    # direct spinner_project call — pinned by bench_pipeline). Pin the
    # route: native Pallas on TPU, fused-jnp ref elsewhere (auto would
    # pick the *interpreter* for small smoke shapes, which benchmarks
    # interpretation overhead).
    use_pallas = None if jax.default_backend() == "tpu" else False

    def fused(p, xx):
        return pipe.apply((p,), xx, out_scale=inv_sqrt_m,
                          use_pallas=use_pallas)

    # --- dense oracle: materialized O(mn) matmul + epilogue, one jit --------
    a_dense = pipe.materialize((params,))

    @jax.jit
    def dense(a, xx):
        return epi(xx @ a.T,
                   0.5 * jnp.sum(xx * xx, -1, keepdims=True)) * inv_sqrt_m

    fused_us, unfused_us, unfused_1jit_us, dense_us = _time_interleaved(
        [(fused, (params, x)), (unfused, (params, x)),
         (unfused_1jit, (params, x)), (dense, (a_dense, x))],
        reps=reps, patience=patience, max_reps=max_reps)
    return {"kind": kind, "epilogue": epilogue,
            "fused_us": round(fused_us, 1),
            "unfused_us": round(unfused_us, 1),
            "unfused_1jit_us": round(unfused_1jit_us, 1),
            "dense_us": round(dense_us, 1),
            "speedup_vs_unfused": round(unfused_us / fused_us, 3),
            "speedup_vs_unfused_1jit": round(unfused_1jit_us / fused_us, 3),
            "speedup_vs_dense": round(dense_us / fused_us, 3)}


def _bench_seeded(b: int, n: int, m: int, reps: int, patience: int,
                  max_reps: int) -> Dict:
    """Seeded (in-kernel regenerated, zero-storage) vs materialized fused
    spinner on the same shape/route: throughput ratio plus the weight-
    bytes reduction the seed mode buys, and the bit-match invariant the
    whole mode rests on (seeded == materialized generator-oracle)."""
    from repro.kernels import seedgen
    pipe_m = spinner.single("circulant", m=m, n=n, f="cos_sin")
    pipe_s = spinner.single("circulant", m=m, n=n, f="cos_sin", seeded=True)
    params_m = pipe_m.init(jax.random.PRNGKey(0))
    params_s = pipe_s.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, n)) * 0.3
    use_pallas = None if jax.default_backend() == "tpu" else False

    def mat(p, xx):
        return pipe_m.apply(p, xx, use_pallas=use_pallas)

    def seeded(p, xx):
        return pipe_s.apply(p, xx, use_pallas=use_pallas)

    mat_us, seeded_us = _time_interleaved(
        [(mat, (params_m, x)), (seeded, (params_s, x))],
        reps=reps, patience=patience, max_reps=max_reps)

    bytes_of = lambda params: sum(
        int(l.size) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params))
    wb_m, wb_s = bytes_of(params_m), bytes_of(params_s)
    oracle = (seedgen.seeded_params("circulant", n, m,
                                    params_s[0]["seed"]),)
    bit = bool(jnp.array_equal(pipe_s.apply(params_s, x, use_pallas=False),
                               pipe_m.apply(oracle, x, use_pallas=False)))
    return {"materialized_us": round(mat_us, 1),
            "seeded_us": round(seeded_us, 1),
            "speedup_vs_materialized": round(mat_us / seeded_us, 3),
            "weight_bytes_materialized": wb_m,
            "weight_bytes_seeded": wb_s,
            "weight_bytes_reduction_x": round(wb_m / wb_s, 1),
            "oracle_bitmatch": bit}


def bench(shape=FULL_SHAPE, kinds=KINDS, epilogues=EPILOGUES,
          reps: int = 15, smoke: bool = False) -> Dict:
    b, n, m = shape
    # Full (artifact) runs sample until each candidate's min has been
    # stale for `patience` rounds — on this noisy shared host the ratios
    # only converge to their intrinsic values with long quiet-window
    # sampling. Smoke runs keep the floor cheap.
    patience, max_reps = (3, 12) if smoke else (25, 200)
    results = [_bench_one(k, e, b, n, m, reps, patience, max_reps)
               for k in kinds for e in epilogues]
    payload = {
        "bench": "fused_spinner",
        "smoke": smoke,
        "backend": jax.default_backend(),
        "shape": {"batch": b, "n": n, "m": m},
        "plan": {k: list(kops.spinner_plan(k, n, m)) for k in kinds},
        "results": results,
        "seeded": _bench_seeded(b, n, m, reps, patience, max_reps),
    }
    default = "BENCH_fused_smoke.json" if smoke else "BENCH_fused.json"
    path = os.environ.get("REPRO_BENCH_FUSED_JSON", default)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


def _rows(payload: Dict) -> List[str]:
    b, n, m = (payload["shape"][k] for k in ("batch", "n", "m"))
    rows = [f"fused/{r['kind']}/{r['epilogue']}/{b}x{n}x{m},"
            f"{r['fused_us']:.1f},"
            f"unfused_us={r['unfused_us']:.1f};dense_us={r['dense_us']:.1f};"
            f"speedup={r['speedup_vs_unfused']:.2f}"
            for r in payload["results"]]
    s = payload["seeded"]
    rows.append(
        f"fused/seeded/circulant/cos_sin/{b}x{n}x{m},{s['seeded_us']:.1f},"
        f"materialized_us={s['materialized_us']:.1f};"
        f"speedup={s['speedup_vs_materialized']:.2f};"
        f"weight_bytes_reduction_x={s['weight_bytes_reduction_x']:.0f};"
        f"oracle_bitmatch={int(s['oracle_bitmatch'])}")
    return rows


def run() -> List[str]:
    """run.py suite entry: smoke shape, two kinds, two epilogues."""
    payload = bench(shape=SMOKE_SHAPE, kinds=("circulant", "toeplitz"),
                    epilogues=("relu", "cos_sin"), reps=3, smoke=True)
    return _rows(payload)


def main():
    payload = bench()
    for row in _rows(payload):
        print(row)
    best = {}
    for r in payload["results"]:
        best[r["kind"]] = max(best.get(r["kind"], 0.0),
                              r["speedup_vs_unfused"])
    n_fast = sum(s >= 1.5 for s in best.values())
    print(f"fused/summary,0,kinds_ge_1.5x={n_fast};best=" +
          ";".join(f"{k}:{s:.2f}" for k, s in best.items()))


if __name__ == "__main__":
    main()
