"""Structured-JL gradient compression: wire bytes, reconstruction error,
error-feedback effect (the distributed-optimization claim)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.optim import compression as C


def run() -> List[str]:
    rows = []
    n = 1 << 16
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (n,))}
    for ratio in [2, 4, 8, 16]:
        cc = C.CompressionConfig(chunk=4096, ratio=ratio, min_size=1)
        raw, comp = C.wire_bytes(g, cc)
        sk = C.compress_tree(g, cc)
        rec = C.decompress_tree(sk, g, cc)
        rel = float(jnp.linalg.norm(rec["w"] - g["w"])
                    / jnp.linalg.norm(g["w"]))
        rows.append(f"compression/ratio{ratio},0.0,"
                    f"wire_reduction={raw/comp:.1f}x;one_shot_rel={rel:.3f}")
    # error feedback over steps: residual of accumulated signal
    cc = C.CompressionConfig(chunk=4096, ratio=8, min_size=1)
    err = C.init_error(g)
    applied = jnp.zeros(n)
    for step in range(10):
        cct = C.CompressionConfig(chunk=4096, ratio=8, seed=step, min_size=1)
        _, rec, err = C.roundtrip_with_feedback(g, err, cct)
        applied = applied + rec["w"]
    drift = float(jnp.linalg.norm(applied + err["w"] - 10 * g["w"])
                  / jnp.linalg.norm(10 * g["w"]))
    rows.append(f"compression/error_feedback_10steps,0.0,"
                f"accumulated_drift={drift:.2e}")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
