"""SRF attention (paper technique in the framework): approximation quality
vs feature count / structure class, and serving-cache bytes vs context
length (the space-complexity table)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import srf_attention as A
from repro.configs import registry
from repro.models import transformer as T


def run() -> List[str]:
    rows = []
    b, h, l, d = 2, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, l, d)) * 0.5
    k = jax.random.normal(ks[1], (b, h, l, d)) * 0.5
    v = jax.random.normal(ks[2], (b, h, l, d))
    ref = A.reference_softmax(q, k, v, causal=True)
    for kind in ["circulant", "toeplitz", "ldr", "unstructured"]:
        for m in [64, 256, 1024]:
            cfg = A.SRFConfig(kind=kind, n_features=m, head_dim=d, chunk=32)
            params = A.init(jax.random.PRNGKey(1), cfg, h)
            pq = A.feature_map(cfg, params, q, True)
            pk = A.feature_map(cfg, params, k, False)
            out = A.attention_causal(cfg, pq, pk, v)
            corr = float(jnp.corrcoef(out.ravel(), ref.ravel())[0, 1])
            mae = float(jnp.abs(out - ref).mean())
            rows.append(f"srf_quality/{kind}/m{m},0.0,"
                        f"corr={corr:.4f};mae={mae:.4f}")

    # serving cache bytes: KV vs SRF state across context lengths
    def cache_bytes(cfg, max_len):
        c = jax.eval_shape(lambda: T.init_serve_cache(cfg, 1, max_len))
        return sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(c))
    full = registry.reduced("qwen3-4b")
    srf = registry.reduced("qwen3-4b", attn_impl="srf")
    for L in [1024, 32768, 524288]:
        rows.append(
            f"srf_cache/L{L},0.0,kv_bytes={cache_bytes(full, L)};"
            f"srf_bytes={cache_bytes(srf, L)}")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
