"""Perf-regression gate over the committed ``BENCH_*.json`` runs.

Every benchmark suite in this repo emits a ``BENCH_<name>.json`` payload
(nested dicts / record lists of numeric cells). This module folds those
payloads into a line-per-run history file (``BENCH_history.jsonl``) and
checks fresh runs against per-cell thresholds derived from the history
baseline, so a slowdown fails loudly instead of rotting silently:

    PYTHONPATH=src python -m benchmarks.regress --record   # fold runs in
    PYTHONPATH=src python -m benchmarks.regress --check    # gate (CI)

The gate is also reachable as ``benchmarks/run.py --check`` and from the
launch smoke path as ``launch/dryrun.py --check-bench``.

Cells are matched to direction-aware rules by name suffix: throughput
cells (``tok_s``, ``req_s``, ``speedup*``, ``*reduction_x``) must not
drop below ``1/tol`` of the baseline median; latency cells (``ttft/tpot
p95``, ``us_per_tok``, ``*_us``) must not exceed ``tol`` times it; bool
invariant cells (``*match*``, ``*ok``, ``conservation*``) must stay
truthy. Everything else (shapes, counts, error magnitudes) is carried in
the history for reference but not gated. Tolerances are deliberately
loose (2x) — the gate exists to catch real regressions (a kernel losing
its fusion, paged attention falling back to the legacy path), not CI
timing jitter.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import statistics
import sys
from typing import Dict, Iterable, List, Optional, Tuple

HISTORY = "BENCH_history.jsonl"

#: Payload keys that identify a run rather than measure it.
META_KEYS = {"bench", "smoke", "backend", "shape", "plan", "f", "device",
             "note", "seed"}

#: Keys used (in order) to give list-of-record rows a stable path segment
#: that survives row reordering across runs.
ID_KEYS = ("family", "arch", "kind", "epilogue", "name", "mode", "case",
           "concurrency", "replicas", "n")

# (pattern, direction, tolerance). Direction "higher": fresh must be
# >= baseline / tol. "lower": fresh must be <= baseline * tol.
# "truthy": fresh must be truthy whenever the baseline was.
RULES: List[Tuple[re.Pattern, str, float]] = [
    (re.compile(r"(^|\.)(tok_s|req_s|requests_per_s)$"), "higher", 2.0),
    (re.compile(r"(speedup(_vs_[a-z0-9_]+)?|reduction_x|hit_rate)$"),
     "higher", 2.0),
    (re.compile(r"(ttft_ms_p95|tpot_ms_p95|us_per_tok)$"), "lower", 2.0),
    (re.compile(r"(_us|_ms|_seconds|overhead)$"), "lower", 3.0),
    (re.compile(r"(match|conservation|identical|correlated)[a-z_]*$"
                r"|(^|[._])ok$"), "truthy", 0.0),
]


def rule_for(cell: str) -> Optional[Tuple[str, float]]:
    """(direction, tol) for the first rule matching ``cell``, or None."""
    for pat, direction, tol in RULES:
        if pat.search(cell):
            return direction, tol
    return None


# -- flattening ---------------------------------------------------------------

def _row_key(row: dict, idx: int) -> str:
    parts = [f"{k}={row[k]}" for k in ID_KEYS if k in row
             and isinstance(row[k], (str, int))]
    return ",".join(parts) if parts else str(idx)


def _walk(node, path: str, out: Dict[str, object]) -> None:
    if isinstance(node, dict):
        for k, v in node.items():
            if not path and k in META_KEYS:
                continue
            _walk(v, f"{path}.{k}" if path else str(k), out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            seg = _row_key(v, i) if isinstance(v, dict) else str(i)
            _walk(v, f"{path}[{seg}]", out)
    elif isinstance(node, bool):
        out[path] = node
    elif isinstance(node, (int, float)):
        out[path] = float(node)


def flatten_cells(payload: dict) -> Dict[str, object]:
    """Numeric/bool leaves of a BENCH payload keyed by a dotted path
    that is stable across runs (list rows keyed by their identity
    fields, not their index)."""
    out: Dict[str, object] = {}
    _walk(payload, "", out)
    return out


def bench_name(payload: dict) -> str:
    name = str(payload.get("bench", "unknown"))
    if payload.get("smoke"):
        name += "_smoke"
    return name


# -- history ------------------------------------------------------------------

def load_history(path: str = HISTORY) -> Dict[str, List[Dict[str, object]]]:
    """history file -> {bench_name: [cells, ...]} oldest first."""
    hist: Dict[str, List[Dict[str, object]]] = {}
    if not os.path.exists(path):
        return hist
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            entry = json.loads(line)
            hist.setdefault(entry["bench"], []).append(entry["cells"])
    return hist


def record(payload: dict, path: str = HISTORY) -> str:
    """Append one history line for ``payload``; returns the bench name."""
    name = bench_name(payload)
    entry = {"bench": name, "cells": flatten_cells(payload)}
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return name


def baseline(runs: Iterable[Dict[str, object]]) -> Dict[str, object]:
    """Per-cell baseline over history runs: median for numbers, any-true
    for bools (an invariant that ever held must keep holding)."""
    acc: Dict[str, list] = {}
    for cells in runs:
        for k, v in cells.items():
            acc.setdefault(k, []).append(v)
    out: Dict[str, object] = {}
    for k, vs in acc.items():
        if all(isinstance(v, bool) for v in vs):
            out[k] = any(vs)
        else:
            out[k] = statistics.median(float(v) for v in vs)
    return out


# -- the gate -----------------------------------------------------------------

def check_cells(fresh: Dict[str, object], base: Dict[str, object],
                bench: str = "") -> List[str]:
    """Violation strings for gated cells of ``fresh`` vs ``base``.
    Cells absent from either side are skipped (suites grow cells over
    time; a vanished cell is a code-review matter, not a perf gate)."""
    bad: List[str] = []
    where = f"{bench}:" if bench else ""
    for cell, ref in sorted(base.items()):
        if cell not in fresh:
            continue
        rule = rule_for(cell)
        if rule is None:
            continue
        direction, tol = rule
        got = fresh[cell]
        if direction == "truthy":
            if ref and not got:
                bad.append(f"{where}{cell}: invariant went falsy "
                           f"(baseline {ref!r}, got {got!r})")
            continue
        ref_f, got_f = float(ref), float(got)
        if direction == "higher" and ref_f > 0 and got_f < ref_f / tol:
            bad.append(f"{where}{cell}: {got_f:.4g} < baseline "
                       f"{ref_f:.4g} / {tol:g} (throughput regression)")
        elif direction == "lower" and ref_f > 0 and got_f > ref_f * tol:
            bad.append(f"{where}{cell}: {got_f:.4g} > baseline "
                       f"{ref_f:.4g} * {tol:g} (latency regression)")
    return bad


def check_payload(payload: dict,
                  history: Dict[str, List[Dict[str, object]]]) -> List[str]:
    """Gate one fresh payload against its bench's history baseline.
    A bench with no history yet passes (nothing to regress against)."""
    runs = history.get(bench_name(payload))
    if not runs:
        return []
    return check_cells(flatten_cells(payload), baseline(runs),
                       bench_name(payload))


def discover(bench_dir: str = ".") -> List[str]:
    """The committed/fresh BENCH payload files, history excluded."""
    return sorted(p for p in glob.glob(os.path.join(bench_dir,
                                                    "BENCH_*.json")))


def check_files(paths: Iterable[str], history_path: str = HISTORY,
                reporter=None) -> List[str]:
    """Gate every payload file; returns all violations. ``reporter`` is
    an ``obs.report.Reporter``-like object (``.line(msg)``) for
    progress; silent when None."""
    history = load_history(history_path)
    bad: List[str] = []
    for p in paths:
        with open(p) as f:
            payload = json.load(f)
        name = bench_name(payload)
        errs = check_payload(payload, history)
        bad.extend(errs)
        if reporter is not None:
            n = len(history.get(name, ()))
            status = ("no-history" if not n
                      else f"FAIL({len(errs)})" if errs else "ok")
            gated = sum(1 for c in flatten_cells(payload)
                        if rule_for(c)) if n else 0
            reporter.line(f"[regress] {name}: {status} "
                          f"(runs={n} gated_cells={gated})")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json payloads; default: discover in "
                         "--bench-dir")
    ap.add_argument("--bench-dir", default=".",
                    help="where BENCH_*.json and the history live")
    ap.add_argument("--history", default=None,
                    help="history jsonl path (default <bench-dir>/"
                         f"{HISTORY})")
    ap.add_argument("--record", action="store_true",
                    help="fold the payloads into the history instead of "
                         "gating")
    ap.add_argument("--check", action="store_true",
                    help="gate the payloads against the history "
                         "(default action)")
    args = ap.parse_args(argv)

    from repro.obs.report import Reporter
    rep = Reporter(prefix="")
    history_path = args.history or os.path.join(args.bench_dir, HISTORY)
    paths = args.files or discover(args.bench_dir)
    if not paths:
        rep.line(f"[regress] no BENCH_*.json under {args.bench_dir}")
        return 0

    if args.record:
        for p in paths:
            with open(p) as f:
                name = record(json.load(f), history_path)
            rep.line(f"[regress] recorded {name} <- {p}")
        return 0

    bad = check_files(paths, history_path, reporter=rep)
    for msg in bad:
        rep.line(f"[regress] REGRESSION {msg}")
    rep.line(f"[regress] {'FAIL' if bad else 'PASS'}: "
             f"{len(bad)} violation(s) across {len(paths)} payload(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
