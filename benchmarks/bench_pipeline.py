"""Spinner-pipeline benchmark: composability must cost nothing.

Three candidates per structured kind (relu feature map, the SRF hot-path
shape):

* ``pipe1``   — 1-block SpinnerPipeline.apply. MUST be the same single
                fused spinner_project dispatch as calling the kernel op
                directly (the acceptance pin of the API redesign).
* ``direct``  — kernels.ops.spinner_project called directly (the PR-2
                hot path). ``pipe1/direct`` ~ 1.0 proves the pipeline
                layer adds no dispatches.
* ``pipe3``   — 3-block stacked pipeline (HD3.HD2.HD1, TripleSpin
                shape): chained fused dispatches, n->n->n->m.
* ``dense``   — the materialized (m, n) product as one O(mn) matmul +
                epilogue (the oracle the stack replaces).

Emits machine-readable ``BENCH_pipeline.json``; correctness is pinned in
the same run (pipe1 == direct bitwise; pipe3 vs its dense oracle).

    PYTHONPATH=src python -m benchmarks.bench_pipeline     # full shape

Env: REPRO_BENCH_PIPELINE_JSON overrides the JSON output path.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fused import _time_interleaved
from repro.core import spinner
from repro.kernels import ops as kops

FULL_SHAPE = (256, 1024, 4096)          # B, n, m — acceptance shape
SMOKE_SHAPE = (64, 256, 512)
KINDS = ("circulant", "skew_circulant", "toeplitz", "hankel")
F = "relu"


def _bench_kind(kind: str, b: int, n: int, m: int, reps: int,
                patience: int = 12, max_reps: int = 80) -> Dict:
    pipe1 = spinner.single(kind, m=m, n=n, f=F)
    pipe3 = spinner.hd_chain(kind, n=n, m=m, depth=3, f=F)
    p1 = pipe1.init(jax.random.PRNGKey(0))
    p3 = pipe3.init(jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (b, n)) * 0.3
    inv = float(m) ** -0.5
    # Pin the route (bench_fused rationale: auto would interpret on CPU).
    use_pallas = None if jax.default_backend() == "tpu" else False

    def fn_pipe1(p, xx):
        return pipe1.apply(p, xx, out_scale=inv, use_pallas=use_pallas)

    def fn_direct(p, xx):
        return kops.spinner_project(kind, p[0], xx, m, epilogue=F,
                                    out_scale=inv, use_pallas=use_pallas)

    def fn_pipe3(p, xx):
        return pipe3.apply(p, xx, out_scale=inv, use_pallas=use_pallas)

    a3 = pipe3.materialize(p3).astype(jnp.float32)       # (m, n) product

    @jax.jit
    def fn_dense(a, xx):
        return jax.nn.relu(xx @ a.T) * inv

    # --- correctness pins (same run as the timings) ------------------------
    y1 = np.asarray(fn_pipe1(p1, x))
    yd = np.asarray(fn_direct(p1, x))
    one_block_identical = bool(np.array_equal(y1, yd))
    y3 = np.asarray(fn_pipe3(p3, x), np.float32)
    yo = np.asarray(fn_dense(a3, x), np.float32)
    stack_err = float(np.max(np.abs(y3 - yo)))

    pipe1_us, direct_us, pipe3_us, dense_us = _time_interleaved(
        [(fn_pipe1, (p1, x)), (fn_direct, (p1, x)),
         (fn_pipe3, (p3, x)), (fn_dense, (a3, x))],
        reps=reps, patience=patience, max_reps=max_reps)
    return {"kind": kind,
            "pipe1_us": round(pipe1_us, 1),
            "direct_us": round(direct_us, 1),
            "pipe3_us": round(pipe3_us, 1),
            "dense_us": round(dense_us, 1),
            "pipe1_overhead": round(pipe1_us / direct_us, 3),
            "pipe3_speedup_vs_dense": round(dense_us / pipe3_us, 3),
            "pipe3_storage_floats": pipe3.storage,
            "dense_storage_floats": m * n,
            "one_block_identical": one_block_identical,
            "stack_max_abs_err": stack_err}


def bench(shape=FULL_SHAPE, kinds=KINDS, reps: int = 15,
          smoke: bool = False) -> Dict:
    b, n, m = shape
    patience, max_reps = (3, 12) if smoke else (25, 200)
    results = [_bench_kind(k, b, n, m, reps, patience, max_reps)
               for k in kinds]
    payload = {
        "bench": "spinner_pipeline",
        "smoke": smoke,
        "backend": jax.default_backend(),
        "f": F,
        "shape": {"batch": b, "n": n, "m": m},
        "results": results,
    }
    default = "BENCH_pipeline_smoke.json" if smoke else "BENCH_pipeline.json"
    path = os.environ.get("REPRO_BENCH_PIPELINE_JSON", default)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


def _rows(payload: Dict) -> List[str]:
    b, n, m = (payload["shape"][k] for k in ("batch", "n", "m"))
    return [f"pipeline/{r['kind']}/{b}x{n}x{m},"
            f"{r['pipe1_us']:.1f},"
            f"direct_us={r['direct_us']:.1f};pipe3_us={r['pipe3_us']:.1f};"
            f"dense_us={r['dense_us']:.1f};"
            f"overhead_1blk={r['pipe1_overhead']:.2f};"
            f"identical_1blk={int(r['one_block_identical'])}"
            for r in payload["results"]]


def run() -> List[str]:
    """run.py suite entry: smoke shape, two kinds."""
    payload = bench(shape=SMOKE_SHAPE, kinds=("circulant", "toeplitz"),
                    reps=3, smoke=True)
    return _rows(payload)


def main():
    payload = bench()
    for row in _rows(payload):
        print(row)
    ok = all(r["one_block_identical"] for r in payload["results"])
    worst = max(r["pipe1_overhead"] for r in payload["results"])
    print(f"pipeline/summary,0,all_1blk_identical={int(ok)};"
          f"worst_1blk_overhead={worst:.2f}")


if __name__ == "__main__":
    main()
