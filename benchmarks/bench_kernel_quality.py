"""Paper quality claim (Thm 10/11/12): structured-embedding kernel
estimation error vs m, per structure class and budget.

This is the paper's central table: for each kernel f and structure class,
mean |Lambda_f_struct - Lambda_f| over fresh P-model draws and random
vector pairs, at several embedding dims m. The theory predicts error
~ m^(-tau) with the structured classes matching unstructured up to
constants (their chi/mu enter only the constants).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import estimators as E
from repro.core import spinner

KINDS = ["unstructured", "circulant", "toeplitz", "ldr"]
FNAMES = ["heaviside", "relu", "trig", "softmax"]
MS = [32, 128, 512]
N = 128
PAIRS = 4
TRIALS = 8


def _pairs(key, n, k):
    a = jax.random.normal(key, (k, n))
    return a / jnp.linalg.norm(a, axis=-1, keepdims=True)


def run() -> List[str]:
    rows = []
    v1 = _pairs(jax.random.PRNGKey(11), N, PAIRS)
    v2 = _pairs(jax.random.PRNGKey(12), N, PAIRS)
    for fname in FNAMES:
        for kind in KINDS:
            for m in MS:
                pipe = spinner.single(kind, m=m, n=N, r=2)

                def one(k):
                    params = pipe.init(k)
                    est = jax.vmap(lambda a, b: E.estimate(
                        pipe, params, fname, a, b))(v1, v2)
                    ex = jax.vmap(lambda a, b: E.exact(fname, a, b))(v1, v2)
                    return jnp.abs(est - ex).mean()
                errs = jax.vmap(one)(
                    jax.random.split(jax.random.PRNGKey(7), TRIALS))
                rows.append(
                    f"kernel_quality/{fname}/{kind}/m{m},"
                    f"{0.0:.1f},{float(errs.mean()):.5f}")
    # concentration-rate check: error ratio between m=32 and m=512 ~ 4x
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
