"""Property-based scheduler invariants: random admit / prefill / grow /
evict / finish sequences over every pool-plan shape must never leak
capacity —

  * free + used page count is conserved in BOTH index domains,
  * without a prefix cache, no page (and never a constant-state slot)
    serves two requests; WITH one, sharing is refcounted: every live
    allocator reference is exactly one block-table entry or one trie
    node (conservation weighted by refcount), no page is freed while
    any reference remains, and a COW fork never leaves a request about
    to write a page it does not exclusively own,
  * waiting sequences hold no device capacity at all,
  * the null page / null slot (id 0) is never handed out,
  * request conservation in the metrics registry: submitted + adopted ==
    finished + released + running + waiting (migration moves requests
    between schedulers, it never creates or destroys them),
  * the registry's page/slot/queue gauges match the live allocator.

Two layers: a deterministic seeded fuzz that ALWAYS runs, and a
hypothesis-driven version (optional dependency, like in
``test_structured.py``) that explores adversarial op orderings when the
library is installed. Both share the same op interpreter and invariant
checker; a ``prefix=True`` mode attaches a :class:`PrefixCache` (tight
byte budget) and emulates the engine's side of the contract — applying
admission forks, inserting completed prompts, dropping the cache at
drain.

The companion engine-level regression for the PR 4 zeroing bug
(constant-state slots must start from zero on reuse) lives in
``test_engine_parity.test_constant_state_zeroed_on_reuse`` — zeroing is
the ENGINE's device-side duty, the scheduler only hands out ids.
"""
import random
from collections import Counter

import numpy as np
import pytest

from repro.configs import registry
from repro.serving import (PrefixConfig, SchedConfig, Scheduler,
                           plan_for)
from repro.serving.prefix import PrefixCache, cow

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # optional dep
    HAVE_HYPOTHESIS = False


PLANS = {
    "kv": plan_for(registry.reduced("qwen3-4b")),
    "srf": plan_for(registry.reduced("qwen3-4b", attn_impl="srf")),
    "ssd": plan_for(registry.reduced("mamba2-2.7b")),
    "hybrid": plan_for(registry.reduced("hymba-1.5b")),
    "encdec": plan_for(registry.reduced("seamless-m4t-large-v2")),
}

_SCHED = SchedConfig(max_batch=4, prefill_batch=2, prefill_chunk=4,
                     page_size=4, num_pages=13, table_width=4, num_slots=5)
_CAP = _SCHED.table_width * _SCHED.page_size


class _Req:
    def __init__(self, uid, plen, max_new, fill=0):
        self.uid = uid
        # a tiny token alphabet: same-fill prompts of different lengths
        # nest (deep trie paths), different fills diverge in page one
        # (sibling partial leaves)
        self.prompt = np.full((plen,), fill, np.int32)
        self.max_new = max_new
        self.priority = 0


def _check_invariants(sched: Scheduler):
    a = sched.alloc
    assert a.free_pages + a.used_pages == a.num_pages - 1
    owned = [p for s in sched.running for p in s.table.pages]
    assert 0 not in owned, "null page handed out"
    if sched.prefix is None:
        assert len(owned) == len(set(owned)), "page serves two requests"
        assert set(owned) == a._allocated, "allocator/table drift"
    else:
        cached = sched.prefix.page_ids()
        assert 0 not in cached, "null page cached"
        assert set(owned) | set(cached) == a._allocated, \
            "allocator/table/cache drift"
        # refcount-weighted conservation: every live reference is
        # exactly one table entry or one trie node — nothing freed
        # while referenced, no reference unaccounted for
        want = Counter(owned) + Counter(cached)
        for pg, n in want.items():
            assert a.refcount(pg) == n, \
                f"page {pg}: {a.refcount(pg)} refs vs {n} owners"
        assert a.total_refs == len(owned) + len(cached)
    if sched.slot_alloc is not None:
        sa = sched.slot_alloc
        assert sa.free_pages + sa.used_pages == sa.num_pages - 1
        slots = [s.slot for s in sched.running if s.slot is not None]
        assert len(slots) == len(set(slots)), "slot serves two requests"
        assert set(slots) == sa._allocated
        assert 0 not in slots, "null slot handed out"
        if sched.plan.needs_slot:
            assert all(s.slot is not None for s in sched.running)
    for s in sched.waiting:
        assert not s.table.pages and s.slot is None, \
            "waiting sequence holds device capacity"
    # registry-side conservation + gauge/allocator agreement (the same
    # registry a serve deployment scrapes; drift here means the metrics
    # lie about the allocator)
    v = sched.metrics.value_sum
    assert v("sched_submitted_total") + v("sched_adopted_total") == \
        v("sched_finished_total") + v("sched_released_total") + \
        len(sched.running) + len(sched.waiting), \
        "request conservation broken in registry"
    assert v("sched_waiting") == len(sched.waiting)
    assert v("sched_running") == len(sched.running)
    assert v("sched_free_pages") == a.free_pages
    assert v("sched_used_pages") == a.used_pages
    if sched.slot_alloc is not None:
        assert v("sched_free_slots") == sched.slot_alloc.free_pages
        assert v("sched_used_slots") == sched.slot_alloc.used_pages


def _engine_side(sched, admitted):
    """Emulate the engine's host-side admission duties: apply pending
    COW forks (drop the admission pin), consume the state payload."""
    for s in admitted:
        if s.snapshot is not None:
            sched.restored(s)                          # engine swaps in
            continue
        if s.fork is not None:                         # engine copies page
            if s.fork.pinned_src:
                sched.prefix.release_fork(s.fork.src)
            s.fork = None
        s.state_payload = None


def _maybe_insert(sched, seq, inserted):
    """Engine contract: a fully prefilled prompt is donated to the cache
    exactly once, BEFORE any finish path frees its pages."""
    if sched.prefix is None or seq.req.uid in inserted \
            or not sched.plan.has_paged:
        return
    inserted.add(seq.req.uid)
    sched.prefix.insert(seq.ns, seq.req.prompt, list(seq.table.pages),
                        payload="slot-state-bytes",
                        payload_tokens=seq.prompt_len)


def _run_ops(plan, ops, prefix=False):
    """Interpret (op, r) pairs against a fresh scheduler, checking the
    invariants after every op, then drain and require nothing leaked."""
    sched = Scheduler(_SCHED, plan)
    if prefix:
        # tight byte budget (6 of 12 usable pages) so budget eviction
        # fires under fuzz, on top of allocator-pressure eviction
        sched.attach_prefix(PrefixCache(
            sched.alloc, _SCHED.page_size, page_bytes=64,
            cfg=PrefixConfig(cache_bytes=64 * 6)))
    inserted = set()
    uid = 0
    for op, r in ops:
        if op == 0:                                    # submit
            plen = r % 10 + 1
            sched.submit(_Req(uid, plen, min(_CAP - plen, r % 6 + 1),
                              fill=r % 2))
            uid += 1
        elif op == 1:                                  # admit (+restore)
            _engine_side(sched, sched.admit())
        elif op == 2 and sched.running:                # prefill progress
            for s in sched.prefill_work():
                n = min(s.prompt_len - s.prefill_pos, _SCHED.prefill_chunk)
                if sched.prefix is not None:
                    # engine guard: prefill writes land only in pages
                    # this request exclusively owns
                    cow.assert_writable(sched.alloc, s.table.pages,
                                        s.prefill_pos, n,
                                        _SCHED.page_size)
                s.prefill_pos += n
                s.table.length = s.prefill_pos
                if s.prefill_done:
                    _maybe_insert(sched, s, inserted)
        elif op == 3 and sched.running:                # decode growth
            seq = sched.running[r % len(sched.running)]
            if not seq.prefill_done:
                continue
            ok, victim = sched.grow_for_decode(seq)
            if ok:
                seq.fork = None                        # engine copies page
                if sched.prefix is not None:
                    # post-fork: the write target is exclusively owned
                    cow.assert_writable(sched.alloc, seq.table.pages,
                                        seq.table.length, 1,
                                        _SCHED.page_size)
                seq.table.length += 1
            elif victim is not None:                   # engine evicts
                victim.fork = None
                sched.evicted(victim, snapshot="host-bytes")
        elif op == 4 and sched.running:                # finish
            # (cache insertion happened at prefill completion in op 2 —
            # the engine's contract; by finish time the table may carry
            # decode-grown pages beyond the prompt)
            sched.finished(sched.running[r % len(sched.running)])
        _check_invariants(sched)
    # drain: everything still queued can eventually run — blocked only
    # by capacity, never by a leak (the prefix cache yields its unpinned
    # pages under allocator pressure, so it must never starve admission)
    for _ in range(200):
        if not sched.waiting:
            break
        _engine_side(sched, sched.admit())
        for s in list(sched.running):
            sched.finished(s)
        _check_invariants(sched)
    assert not sched.waiting, "leaked capacity starved the queue"
    for s in list(sched.running):
        sched.finished(s)
    if sched.prefix is not None:
        # with no requests live, every remaining reference is the cache's
        assert sched.alloc.used_pages == sched.prefix.pages
        assert sched.alloc.total_refs == sched.prefix.pages
        sched.prefix.drop_all()
    assert sched.alloc.used_pages == 0
    if sched.slot_alloc is not None:
        assert sched.slot_alloc.used_pages == 0


@pytest.mark.parametrize("prefix", [False, True], ids=["cold", "prefix"])
@pytest.mark.parametrize("plan_name", sorted(PLANS))
def test_scheduler_never_leaks_capacity_seeded_fuzz(plan_name, prefix):
    """Always-run layer: 60 deterministic random op sequences per plan,
    with and without a prefix cache attached (refcounted sharing)."""
    rng = random.Random(0xC0FFEE ^ hash(plan_name) % (1 << 30))
    for _ in range(60):
        ops = [(rng.randint(0, 4), rng.randint(0, 1 << 16))
               for _ in range(rng.randint(0, 80))]
        _run_ops(PLANS[plan_name], ops, prefix=prefix)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(plan_name=st.sampled_from(sorted(PLANS)),
           ops=st.lists(st.tuples(st.integers(0, 4),
                                  st.integers(0, 2 ** 16)),
                        max_size=80),
           prefix=st.booleans())
    def test_scheduler_never_leaks_capacity_hypothesis(plan_name, ops,
                                                       prefix):
        _run_ops(PLANS[plan_name], ops, prefix=prefix)


def test_conservation_holds_across_migration():
    """release_waiting/adopt move a request between schedulers: the
    conservation identity must hold on BOTH sides at every point, with
    the released/adopted counters absorbing the hand-off."""
    src = Scheduler(_SCHED, PLANS["kv"])
    dst = Scheduler(_SCHED, PLANS["kv"])
    for i in range(8):
        src.submit(_Req(i, 4, 2))
    src.admit()
    _check_invariants(src)
    _check_invariants(dst)
    moved = 0
    for s in list(src.waiting)[:3]:
        src.release_waiting(s)
        dst.adopt(s)
        moved += 1
        _check_invariants(src)
        _check_invariants(dst)
    assert moved == 3
    assert src.metrics.value_sum("sched_released_total") == 3
    assert dst.metrics.value_sum("sched_adopted_total") == 3
    # drain both sides; conservation must close at zero in-flight
    for sched in (src, dst):
        for _ in range(50):
            if not sched.has_work:
                break
            for s in sched.admit():
                if s.snapshot is not None:
                    sched.restored(s)
            for s in list(sched.running):
                sched.finished(s)
            _check_invariants(sched)
        assert not sched.has_work


@pytest.mark.parametrize("n", [1, 2, 5, 12])
def test_mixed_geometry_admission_is_all_or_nothing(n):
    """A hybrid request that gets pages but no slot (or vice versa) must
    not be half-admitted: either both domains supply it or neither is
    charged."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        sched = Scheduler(SchedConfig(max_batch=8, prefill_batch=4,
                                      prefill_chunk=4, page_size=4,
                                      num_pages=40, table_width=4,
                                      num_slots=3),
                          PLANS["hybrid"])
        for i in range(n):
            sched.submit(_Req(i, int(rng.integers(1, 12)), 2))
        admitted = sched.admit()
        # only 2 usable slots: admission is slot-bound regardless of pages
        assert len(admitted) == min(n, 2)
        used = sum(len(s.table.pages) for s in sched.running)
        assert sched.alloc.used_pages == used
        assert sched.slot_alloc.used_pages == len(admitted)
        for s in sched.waiting:
            assert not s.table.pages and s.slot is None
