"""Per-arch REDUCED smoke tests (spec deliverable f): one forward/train step
on CPU asserting output shapes + no NaNs; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, shapes
from repro.launch import steps
from repro.models import transformer as T
from repro.optim import adamw


def _concrete_batch(cfg, b, l, training=True, seed=0):
    specs = shapes.batch_specs(cfg, b, l, training)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            if k == "pos3":
                pos = jnp.broadcast_to(jnp.arange(s.shape[-1]), s.shape[1:])
                out[k] = jnp.broadcast_to(pos, s.shape)
            else:
                out[k] = jax.random.randint(jax.random.PRNGKey(seed),
                                            s.shape, 0, cfg.vocab)
        else:
            out[k] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                       s.shape) * 0.2
    return out


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = registry.reduced(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    b, l = 2, 32
    batch = _concrete_batch(cfg, b, l)
    logits, aux = T.forward(params, cfg, batch)
    exp_len = l if cfg.frontend != "vision_stub" else l
    assert logits.shape[0] == b
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    loss, metrics = T.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss)), arch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_one_train_step(arch):
    cfg = registry.reduced(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = _concrete_batch(cfg, 2, 32)
    step = steps.make_train_step(cfg)
    # step 1, not 0: warmup lr at step 0 is exactly 0 (params unchanged)
    params2, opt2, m = jax.jit(step)(params, opt, jnp.ones((), jnp.int32),
                                     batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params actually changed
    changed = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed, arch


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "deepseek-v2-lite-16b",
                                  "mamba2-2.7b", "hymba-1.5b",
                                  "seamless-m4t-large-v2", "qwen2-vl-2b"])
def test_prefill_decode_consistency(arch):
    cfg = registry.reduced(arch, moe_capacity_factor=8.0)
    params = T.init(jax.random.PRNGKey(0), cfg)
    fwd = jax.jit(T.forward, static_argnums=1)
    pre_fn = jax.jit(T.prefill, static_argnums=1)
    dec_fn = jax.jit(T.decode_step, static_argnums=1)
    b, p, n = 2, 16, 3
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, p + n), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.is_encdec:
        batch["enc_emb"] = jax.random.normal(jax.random.PRNGKey(4),
                                             (b, cfg.enc_len, 160)) * 0.1
    logits_full, _ = fwd(params, cfg, dict(batch, labels=toks))
    cache = T.init_serve_cache(cfg, b, p + n)
    pre = {k: (v[:, :p] if k == "tokens" else v) for k, v in batch.items()}
    lp, cache = pre_fn(params, cfg, pre, cache)
    scale = float(jnp.abs(logits_full).max())
    errs = [float(jnp.abs(lp[:, 0] - logits_full[:, p - 1]).max())]
    for i in range(n):
        ld, cache = dec_fn(params, cfg, cache, toks[:, p + i:p + i + 1])
        errs.append(float(jnp.abs(ld[:, 0] - logits_full[:, p + i]).max()))
    assert max(errs) / scale < 2e-4, (arch, errs)


@pytest.mark.parametrize("arch", ["qwen3-4b", "internlm2-20b"])
def test_srf_mode_runs_everywhere(arch):
    """attn_impl=srf (the paper's technique) trains and serves."""
    cfg = registry.reduced(arch, attn_impl="srf")
    params = T.init(jax.random.PRNGKey(0), cfg)
    batch = _concrete_batch(cfg, 2, 32)
    loss, _ = T.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    cache = T.init_serve_cache(cfg, 2, 64)
    # SRF cache has no sequence axis
    s_shapes = jax.tree.leaves(jax.tree.map(lambda x: x.shape,
                                            cache["segments"][0]))
    lp, cache = T.prefill(params, cfg, {"tokens": batch["tokens"]}, cache)
    ld, cache = T.decode_step(params, cfg, cache,
                              jnp.zeros((2, 1), jnp.int32))
    assert bool(jnp.all(jnp.isfinite(ld)))


def test_param_counts_in_expected_range():
    """Full configs: analytic param count matches the advertised scale."""
    expect = {
        "mistral-nemo-12b": (11e9, 14e9),
        "internlm2-20b": (18e9, 23e9),
        "qwen2.5-14b": (13e9, 16.5e9),
        "qwen3-4b": (3.5e9, 5e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
        "mamba2-2.7b": (2.3e9, 3.2e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "qwen2-vl-2b": (1.7e9, 2.7e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:,}")


def test_scan_group_equivalence():
    cfg1 = registry.reduced("qwen3-4b", n_layers=4)
    cfg2 = registry.reduced("qwen3-4b", n_layers=4, scan_group=2,
                            remat="full")
    cfg1 = registry.reduced("qwen3-4b", n_layers=4, remat="full")
    params = T.init(jax.random.PRNGKey(0), cfg1)
    batch = _concrete_batch(cfg1, 2, 32)
    l1, _ = T.loss_fn(params, cfg1, batch)
    l2, _ = T.loss_fn(params, cfg2, batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_int8_kv_cache_decode_quality():
    """Quantized KV cache (kv_cache_dtype=int8): halves decode cache bytes;
    logits stay within ~1% and greedy tokens match the bf16 cache."""
    outs = {}
    for kvd in ["bf16", "int8"]:
        cfg = registry.reduced("qwen3-4b", kv_cache_dtype=kvd)
        params = T.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 20), 0,
                                  cfg.vocab)
        cache = T.init_serve_cache(cfg, 2, 24)
        if kvd == "int8":
            assert cache["segments"][0]["k"].dtype == jnp.int8
        lp, cache = T.prefill(params, cfg, {"tokens": toks[:, :16]}, cache)
        ls = [lp]
        for i in range(4):
            ld, cache = T.decode_step(params, cfg, cache,
                                      toks[:, 16 + i:17 + i])
            ls.append(ld)
        outs[kvd] = jnp.concatenate(ls, axis=1)
    scale = float(jnp.abs(outs["bf16"]).max())
    assert float(jnp.abs(outs["bf16"] - outs["int8"]).max()) / scale < 0.05
    assert bool(jnp.all(jnp.argmax(outs["bf16"], -1)
                        == jnp.argmax(outs["int8"], -1)))
