"""Paper-claim validation: unbiasedness (Lemma 5), closed-form kernels,
error concentration in m (Thm 11/12 direction), coherence params (Sec 2.2)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coherence as C
from repro.core import estimators as E
from repro.core import pmodel as P
from repro.core import structured as S

# These tests predate the SpinnerPipeline API and deliberately keep the
# deprecated repro.core.pmodel shim as their independent oracle (the shim
# is pinned bit-identical, which is what makes it a good comparison
# target). pytest.ini escalates our own DeprecationWarnings to errors
# suite-wide; these shim-test modules are the sanctioned exception.
pytestmark = [
    pytest.mark.filterwarnings(
        "ignore:repro.core.pmodel:DeprecationWarning"),
    pytest.mark.filterwarnings(
        "ignore:passing \\w+ here is deprecated:DeprecationWarning"),
]



def _unit(key, n):
    v = jax.random.normal(key, (n,))
    return v / jnp.linalg.norm(v)


@pytest.mark.parametrize("kind", ["circulant", "toeplitz", "hankel"])
@pytest.mark.parametrize("fname", ["identity", "heaviside", "sign", "relu"])
def test_unbiasedness_lemma5(kind, fname):
    """E over P-model draws of the structured estimator == closed form."""
    n, m, trials = 32, 32, 600
    spec = P.PModelSpec(kind=kind, m=m, n=n, use_hd=True)
    v1 = _unit(jax.random.PRNGKey(1), n)
    v2 = 0.6 * v1 + 0.8 * _unit(jax.random.PRNGKey(2), n)
    v2 = v2 / jnp.linalg.norm(v2)

    def one(k):
        params = P.init(k, spec)
        return E.estimate(spec, params, fname, v1, v2)
    ests = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(3), trials))
    exact = float(E.exact(fname, v1, v2))
    se = float(ests.std()) / math.sqrt(trials)
    assert abs(float(ests.mean()) - exact) < max(4 * se, 0.02), \
        (fname, float(ests.mean()), exact)


def test_angular_paper_form_vs_product_form():
    """theta/(2pi) (paper's ex. 2 value) + product form = 1/2 - theta/pi +
    ... consistency: product form (pi-theta)/(2pi)."""
    n = 16
    v1 = _unit(jax.random.PRNGKey(1), n)
    v2 = _unit(jax.random.PRNGKey(2), n)
    th = float(E.angle(v1, v2))
    assert abs(float(E.k_angular_product(v1, v2))
               - (math.pi - th) / (2 * math.pi)) < 1e-6
    assert abs(float(E.k_angular_paper(v1, v2)) - th / (2 * math.pi)) < 1e-6


@pytest.mark.parametrize("kind", ["circulant", "toeplitz"])
def test_error_decreases_with_m(kind):
    """Thm 11/12: estimation error concentrates as m grows."""
    n = 64
    v1 = _unit(jax.random.PRNGKey(1), n)
    v2 = _unit(jax.random.PRNGKey(2), n)
    errs = []
    for m in [16, 256]:
        spec = P.PModelSpec(kind=kind, m=m, n=n, use_hd=True)
        mean_err, _ = E.mc_error(jax.random.PRNGKey(3), spec, "heaviside",
                                 v1, v2, n_trials=48)
        errs.append(float(mean_err))
    assert errs[1] < errs[0], errs


def test_gaussian_kernel_estimate():
    n, m = 64, 2048
    spec = P.PModelSpec(kind="circulant", m=m, n=n, use_hd=True)
    params = P.init(jax.random.PRNGKey(0), spec)
    v1 = 0.7 * _unit(jax.random.PRNGKey(1), n)
    v2 = 0.5 * _unit(jax.random.PRNGKey(2), n)
    est = float(E.estimate(spec, params, "trig", v1, v2, sigma=1.0))
    exact = float(E.exact("trig", v1, v2, 1.0))
    assert abs(est - exact) < 0.05, (est, exact)


# --- coherence parameters (paper Sec 2.2 claims) -------------------------------

@pytest.mark.parametrize("kind,chi_max", [("circulant", 3), ("toeplitz", 2),
                                          ("hankel", 2)])
def test_coherence_params(kind, chi_max):
    m, n = 6, 8
    params = S.init(jax.random.PRNGKey(0), kind, m, n)
    st = C.pmodel_stats(kind, params, m, n)
    assert st["chi"] <= chi_max, st
    assert st["mu_tilde"] == pytest.approx(0.0, abs=1e-5)   # paper: mu~ = 0
    assert st["normalized"] == 1.0                          # Def. 1
    assert st["orthogonal_cols"] == 1.0                     # Lemma 5 condition
    assert st["mu"] < 2.0                                   # mu = O(1)


def test_budget_knob_monotone():
    """More randomness budget t -> (weakly) fewer constraints: toeplitz has
    strictly larger t than circulant at same (m, n)."""
    m, n = 8, 16
    assert S.budget("toeplitz", m, n) > S.budget("circulant", m, n)
