"""Observability subsystem: metrics registry semantics, trace lifecycle
derivations, kernel profiling hooks, the SRF quality probe, the
reporter, and the no-bare-print lint pin over the serving stack."""
import io
import json
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import profiling, quality, report
from repro.obs.metrics import MetricsRegistry, StatsView
from repro.obs.trace import Trace, latency_summary, percentiles

SRC = Path(__file__).resolve().parent.parent / "src"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests", ("engine",))
    c.labels(engine="0").inc()
    c.labels(engine="0").inc(2)
    c.labels(engine="1").inc(5)
    assert c.labels(engine="0").value() == 3
    assert c.total() == 8
    assert reg.value_sum("reqs_total") == 8
    with pytest.raises(ValueError):
        c.labels(engine="0").inc(-1)           # counters only go up


def test_unlabelled_metrics_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("steps_total")
    c.inc()
    c.inc(4)
    assert c.value() == 5
    g = reg.gauge("free_pages")
    g.set(7)
    assert g.value() == 7
    gl = reg.gauge("headroom", "", ("replica",))
    gl.labels(replica=0).set(3)
    gl.labels(replica=0).dec()
    gl.labels(replica=1).inc(2)
    assert gl.labels(replica=0).value() == 2
    assert reg.value_sum("headroom") == 4


def test_factory_idempotent_and_type_mismatch_raises():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", ("engine",))
    b = reg.counter("x_total", "different help", ("engine",))
    assert a is b                              # same series, not a fork
    with pytest.raises(ValueError):
        reg.gauge("x_total")                   # type mismatch
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", ("other",))  # label-set mismatch


def test_histogram_percentiles_and_ring():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "", (), max_observations=8)
    for v in range(100):
        h.observe(float(v))
    bound = h.labels()
    assert bound.count() == 100                # count survives the ring
    assert bound.sum() == sum(range(100))
    assert len(bound.values()) == 8            # observations bounded
    hh = reg.histogram("exact", "")
    for v in (5.0, 1.0, 3.0, 2.0, 4.0):
        hh.observe(v)
    assert hh.labels().percentile(50) == 3.0   # nearest-rank
    assert hh.labels().percentile(99) == 5.0
    assert reg.percentiles("exact")["p50"] == 3.0


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c_total")
    c.inc(99)
    assert c.value() == 0
    reg.event("queued", uid=1)
    assert reg.events == []
    assert reg.snapshot()["counters"] == {}
    assert reg.value_sum("c_total") == 0
    assert np.isnan(reg.percentiles("nope")["p50"])


def test_events_bounded_and_jsonl_dump():
    reg = MetricsRegistry(max_events=3)
    for i in range(5):
        reg.event("queued", uid=i)
    assert len(reg.events) == 3
    assert reg.events_dropped == 2
    buf = io.StringIO()
    assert reg.dump_events_jsonl(buf) == 3
    lines = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert [e["uid"] for e in lines] == [0, 1, 2]
    assert all(e["event"] == "queued" and "t" in e for e in lines)


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("a_total", "things", ("engine",)).labels(engine="0").inc(3)
    reg.gauge("b").set(1.5)
    reg.histogram("c_seconds", "lat", ("engine",)) \
       .labels(engine="0").observe(0.25)
    text = reg.prometheus_text()
    assert "# TYPE a_total counter" in text
    assert 'a_total{engine="0"} 3' in text
    assert "b 1.5" in text
    assert "# TYPE c_seconds summary" in text
    assert 'c_seconds{engine="0",quantile="0.5"} 0.25' in text
    assert 'c_seconds_count{engine="0"} 1' in text


def test_prometheus_label_value_escaping():
    # Prometheus exposition: backslash, newline and double-quote inside
    # a label VALUE must be escaped; ordinary values pass through
    # byte-identical (pinned by test_prometheus_text_format above).
    reg = MetricsRegistry()
    c = reg.counter("esc_total", "", ("tenant",))
    c.labels(tenant='a"b\\c\nd').inc()
    text = reg.prometheus_text()
    assert r'esc_total{tenant="a\"b\\c\nd"} 1' in text
    assert MetricsRegistry._escape_label_value("plain-0") == "plain-0"


def test_prometheus_empty_histogram_and_label_only_series():
    # A histogram that was registered but never observed must still
    # export valid exposition (TYPE line, zero count, no quantile lines
    # that would divide by an empty sample), and a labelled metric with
    # no bound children exports just its header.
    reg = MetricsRegistry()
    reg.histogram("idle_seconds", "never observed")
    reg.counter("unbound_total", "no children yet", ("engine",))
    text = reg.prometheus_text()
    assert "# TYPE idle_seconds summary" in text
    assert "# TYPE unbound_total counter" in text
    lines = [l for l in text.splitlines() if l.startswith("idle_seconds")]
    for line in lines:
        assert "quantile" not in line or not line.endswith("nan")
    h = reg.histogram("idle_seconds", "")
    assert h.labels().count() == 0 and h.labels().sum() == 0.0


def test_noop_registry_snapshot_shape():
    # The disabled registry's snapshot must be shape-compatible with the
    # enabled one (same top-level keys), so reporters can read either.
    live = MetricsRegistry().snapshot()
    noop = MetricsRegistry(enabled=False).snapshot()
    assert set(noop) == set(live)
    assert all(noop[k] in ({}, [], 0) for k in noop)
    reg = MetricsRegistry(enabled=False)
    h = reg.histogram("h_seconds")
    h.observe(1.0)
    assert reg.snapshot()["histograms"] == {}


def test_stats_view_is_read_only_live_mapping():
    reg = MetricsRegistry()
    c = reg.counter("tok_total")
    view = StatsView({"tokens": c.value})
    assert view["tokens"] == 0
    c.inc(4)
    assert view["tokens"] == 4                 # live, not a copy
    assert dict(view) == {"tokens": 4}
    assert "tokens" in view and len(view) == 1
    with pytest.raises(TypeError):
        view["tokens"] = 9                     # Mapping, not MutableMapping


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------

def test_trace_derivations_and_monotonic():
    tr = Trace(uid=1)
    tr.stamp("queued", 1.0)
    tr.stamp("admitted", 1.5)
    tr.stamp("prefill", 1.6)
    tr.stamp("first_token", 2.0)
    tr.stamp("preempted", 2.1)
    tr.stamp("restored", 2.2)
    tr.stamp("decode", 2.3)
    tr.stamp("done", 3.0)
    assert tr.queue_time == pytest.approx(0.5)
    assert tr.ttft == pytest.approx(1.0)
    assert tr.e2e == pytest.approx(2.0)
    assert tr.tpot(5) == pytest.approx(1.0 / 4)
    assert tr.tpot(1) is None                  # single token: no TPOT
    assert tr.monotonic()
    assert tr.count("preempted") == 1


def test_trace_detects_out_of_order():
    tr = Trace()
    tr.stamp("queued", 2.0)
    tr.stamp("admitted", 1.0)                  # time goes backwards
    assert not tr.monotonic()
    tr2 = Trace()
    tr2.stamp("first_token", 1.0)
    tr2.stamp("queued", 1.0)                   # milestones out of order
    tr2.stamp("admitted", 1.0)
    assert not tr2.monotonic()


def test_percentiles_nearest_rank_and_empty():
    p = percentiles([10.0, 20.0, 30.0, 40.0], qs=(50, 95, 99))
    assert p == {"p50": 30.0, "p95": 40.0, "p99": 40.0}
    assert all(np.isnan(v) for v in percentiles([]).values())


def test_latency_summary_falls_back_to_stamps():
    class R:
        done = True
        out_tokens = [1, 2, 3]
        t_submit, t_first, t_done = 0.0, 0.5, 1.5
        trace = None
    s = latency_summary([R(), R()])
    assert s["requests"] == 2 and s["tokens"] == 6
    assert s["ttft_s"]["p50"] == pytest.approx(0.5)
    assert s["tpot_s"]["p50"] == pytest.approx(0.5)
    assert s["e2e_s"]["p50"] == pytest.approx(1.5)


# ---------------------------------------------------------------------------
# profiling hooks
# ---------------------------------------------------------------------------

def test_dispatch_times_eager_calls_when_enabled():
    reg = MetricsRegistry()
    try:
        profiling.enable_kernel_timing(reg)
        out = profiling.dispatch("toy", lambda: jnp.ones((4,)) * 2)
        np.testing.assert_allclose(np.asarray(out), 2.0)
        h = reg.histogram("kernel_dispatch_seconds", "", ("kernel",))
        assert h.labels(kernel="toy").count() == 1
        assert h.labels(kernel="toy").sum() > 0
    finally:
        profiling.disable_kernel_timing()
    profiling.dispatch("toy", lambda: jnp.ones((4,)))
    assert h.labels(kernel="toy").count() == 1  # off: nothing recorded


def test_dispatch_skips_timing_under_jit_trace():
    reg = MetricsRegistry()
    try:
        profiling.enable_kernel_timing(reg)

        @jax.jit
        def f(x):
            return profiling.dispatch("traced", lambda: x * 3)
        np.testing.assert_allclose(np.asarray(f(jnp.ones((2,)))), 3.0)
        h = reg.histogram("kernel_dispatch_seconds", "", ("kernel",))
        assert h.labels(kernel="traced").count() == 0
    finally:
        profiling.disable_kernel_timing()


def test_ops_dispatch_records_kernel_histogram():
    from repro.kernels import ops
    reg = MetricsRegistry()
    try:
        profiling.enable_kernel_timing(reg)
        pool = jnp.zeros((4, 2, 8))
        tables = jnp.zeros((2, 2), jnp.int32)
        ops.paged_gather(pool, tables, use_pallas=False)
        h = reg.histogram("kernel_dispatch_seconds", "", ("kernel",))
        assert h.labels(kernel="paged_gather").count() == 1
    finally:
        profiling.disable_kernel_timing()


# ---------------------------------------------------------------------------
# quality probe
# ---------------------------------------------------------------------------

def test_srf_quality_probe():
    from repro.configs import registry
    from repro.models import transformer as T
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    assert quality.srf_quality_probe(cfg, params) is None   # non-SRF

    scfg = registry.reduced("qwen3-4b", n_layers=2, attn_impl="srf")
    sparams = T.init(jax.random.PRNGKey(0), scfg)
    stats = quality.srf_quality_probe(scfg, sparams)
    assert set(stats) == {"srf_row_mean_abs_max", "srf_row_var_err_max"}
    # Def. 1 calibration: freshly initialized rows are near N(0, I) rows
    assert 0 <= stats["srf_row_mean_abs_max"] < 1.0
    assert 0 <= stats["srf_row_var_err_max"] < 1.0


def test_engine_publishes_quality_gauge():
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import Engine, Request
    cfg = registry.reduced("qwen3-4b", n_layers=2, attn_impl="srf")
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=2, max_len=64, quality_every=2)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new=6))
    eng.run()
    qual = eng.metrics.snapshot()["gauges"].get("srf_quality", {})
    assert qual, "srf engine never sampled the quality gauge"
    assert all(np.isfinite(v) for v in qual.values())


# ---------------------------------------------------------------------------
# reporter
# ---------------------------------------------------------------------------

def test_reporter_periodic_and_final(tmp_path):
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import Engine, Request
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    reg = MetricsRegistry()
    eng = Engine(cfg, params, batch_slots=4, max_len=64, metrics=reg)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=np.arange(5, dtype=np.int32),
                           max_new=4))
    buf = io.StringIO()
    rep = report.Reporter(stream=buf)
    done = eng.run(on_step=rep.periodic(reg, every_s=0.0))
    dump = tmp_path / "metrics.prom"
    rep.final(reg, done, dump_path=str(dump))
    text = buf.getvalue()
    assert "[metrics] t=" in text              # periodic line fired
    assert "tok/s=" in text
    assert "ttft_ms p50=" in text and "tpot_ms" in text
    assert "requests=4" in text
    assert "engine_requests_total" in dump.read_text()
    events = (tmp_path / "metrics.prom.events.jsonl").read_text()
    assert all(json.loads(l)["event"] for l in events.splitlines())


# ---------------------------------------------------------------------------
# per-tenant accounting
# ---------------------------------------------------------------------------

def test_tenant_accounting_labels_flow_through_engine():
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import Engine, Request
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    reg = MetricsRegistry()
    eng = Engine(cfg, params, batch_slots=4, max_len=64, metrics=reg)
    for i, ns in enumerate(["acme", "acme", "globex", ""]):
        eng.submit(Request(uid=i, prompt=np.arange(5, dtype=np.int32),
                           max_new=4, namespace=ns))
    eng.run()
    lab = {"engine": eng.engine_id}

    def by_tenant(name):
        c = reg.counter(name, "", ("engine", "tenant"))
        return {t: c.labels(**lab, tenant=t).value()
                for t in ("acme", "globex", "-")}

    reqs = by_tenant("tenant_requests_total")
    assert reqs == {"acme": 2, "globex": 1, "-": 1}   # "" renders as "-"
    dec = by_tenant("tenant_decode_tokens_total")
    assert dec["acme"] == 8 and dec["globex"] == 4 and dec["-"] == 4
    pre = by_tenant("tenant_prefill_tokens_total")
    assert sum(pre.values()) == reg.value_sum("engine_prefill_tokens_total")
    assert reg.value_sum("tenant_decode_tokens_total") == \
        reg.value_sum("engine_tokens_total")
    # pages all released after drain: every tenant gauge back at zero
    g = reg.gauge("tenant_pages_held", "", ("engine", "tenant"))
    for t in ("acme", "globex", "-"):
        assert g.labels(**lab, tenant=t).value() == 0


def test_tenant_namespaces_partition_prefix_cache():
    """Two tenants sending the IDENTICAL prompt must not share cached
    pages; two requests of one tenant must."""
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import ChunkConfig, Engine, PrefixConfig, Request
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    reg = MetricsRegistry()
    eng = Engine(cfg, params, batch_slots=2, max_len=64, metrics=reg,
                 prefix=PrefixConfig(chunk=ChunkConfig(chunk_tokens=16)))
    prompt = np.arange(20, dtype=np.int32)

    def serve_one(uid, ns):
        eng.submit(Request(uid=uid, prompt=prompt.copy(), max_new=2,
                           namespace=ns))
        eng.run()
        return reg.value_sum("prefix_hits_total")

    assert serve_one(0, "acme") == 0          # cold
    assert serve_one(1, "globex") == 0        # same tokens, other tenant
    assert serve_one(2, "acme") == 1          # same tenant: hits
    hits = reg.counter("prefix_tenant_hits_total", "",
                       ("engine", "tenant"))
    assert hits.labels(engine=eng.engine_id, tenant="acme").value() == 1
    assert hits.labels(engine=eng.engine_id, tenant="globex").value() == 0


# ---------------------------------------------------------------------------
# lint pin: the serving stack never prints directly
# ---------------------------------------------------------------------------

def test_no_bare_print_in_serving():
    """All human-facing serving output routes through obs.report.Reporter;
    a bare print() in the serving stack, the launchers, or the bench
    harness bypasses the registry and drifts from the metrics report."""
    repo = SRC.parent
    files = sorted((SRC / "repro" / "serving").rglob("*.py"))
    files.append(SRC / "repro" / "launch" / "serve.py")
    files.append(SRC / "repro" / "launch" / "dryrun.py")
    files.append(repo / "benchmarks" / "run.py")
    pat = re.compile(r"(?<![\w.])print\(")
    offenders = []
    for f in files:
        for i, line in enumerate(f.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(
                    f"{f.relative_to(repo)}:{i}: {line.strip()}")
    assert not offenders, "bare print() in the serving stack:\n" + \
        "\n".join(offenders)


def test_metric_name_table_in_readme_is_complete():
    """serving/README.md documents every metric series the stack
    registers. Registered names are collected statically (string-literal
    first argument of counter()/gauge()/histogram() calls under
    src/repro/serving and src/repro/obs), so adding a metric without
    documenting it fails this pin."""
    pat = re.compile(r'\.(?:counter|gauge|histogram)\(\s*"([a-z0-9_]+)"',
                     re.S)
    # registration through the local one-letter factory aliases some
    # modules bind (c = metrics.counter(...).labels(...), etc.)
    alias = re.compile(r'(?<![\w.])[cgh]\(\s*"([a-z0-9_]+)"', re.S)
    names = set()
    for root in (SRC / "repro" / "serving", SRC / "repro" / "obs"):
        for f in sorted(root.rglob("*.py")):
            text = f.read_text()
            names.update(pat.findall(text))
            names.update(alias.findall(text))
    assert len(names) > 20, "metric-name scrape came back implausibly thin"
    readme = (SRC / "repro" / "serving" / "README.md").read_text()
    missing = sorted(n for n in names if n not in readme)
    assert not missing, \
        "metrics registered but undocumented in serving/README.md: " + \
        ", ".join(missing)
