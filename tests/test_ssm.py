"""Mamba-2 SSD: chunked scan == naive per-step recurrence; decode == prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import ssm as S


def _cfg(**kw):
    return registry.reduced("mamba2-2.7b", **kw)


def _naive_ssd(p, cfg, x):
    """O(L) per-step recurrence oracle (decode step applied sequentially)."""
    b, l, d = x.shape
    cache = S.init_ssm_cache(cfg, b, jnp.float32)
    outs = []
    for t in range(l):
        o, cache = S.ssm_apply(p, cfg, x[:, t:t + 1], "decode", cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), cache


@pytest.mark.parametrize("l", [8, 16, 19])   # 19: exercises chunk padding
def test_chunked_equals_naive(l):
    cfg = _cfg(ssm_chunk=8)
    p = S.ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, l, cfg.d_model)) * 0.5
    y_chunk, _ = S.ssm_apply(p, cfg, x, "train")
    y_naive, _ = _naive_ssd(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-4)


def test_prefill_state_matches_naive():
    cfg = _cfg(ssm_chunk=8)
    p = S.ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    _, cache_pre = S.ssm_apply(p, cfg, x, "prefill")
    _, cache_naive = _naive_ssd(p, cfg, x)
    np.testing.assert_allclose(np.asarray(cache_pre["ssm"]),
                               np.asarray(cache_naive["ssm"]),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache_pre["conv"]),
                               np.asarray(cache_naive["conv"]),
                               rtol=1e-4, atol=1e-5)


def test_state_is_sequence_free():
    cfg = _cfg()
    for l in [8, 64]:
        p = S.ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, l, cfg.d_model))
        _, cache = S.ssm_apply(p, cfg, x, "prefill")
        assert cache["ssm"].shape == (1, cfg.ssm_heads, cfg.ssm_state,
                                      cfg.ssm_head_dim)
