"""Paged serving subsystem: allocator invariants, paged-gather kernel vs
jnp reference, scheduler policies, sampler semantics, and end-to-end
engine runs with mixed-length concurrent requests per cache family."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import ops, ref
from repro.models import transformer as T
from repro.serving import (BlockAllocator, BlockTable, Engine, Request,
                           SchedConfig)
from repro.serving.blocks import NULL_PAGE


def _legacy():
    """Import the legacy oracle without tripping the deprecation-as-error
    filter (its import warns by design; see pytest.ini)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.serving import legacy
    return legacy


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------

def test_allocator_no_double_alloc_and_free_returns():
    a = BlockAllocator(num_pages=8, page_size=4)
    seen = set()
    p1 = a.alloc(3)
    p2 = a.alloc(4)
    assert p1 is not None and p2 is not None
    for p in p1 + p2:
        assert p not in seen, "page handed out twice"
        assert p != NULL_PAGE
        seen.add(p)
    assert a.alloc(1) is None                 # exhausted (7 usable)
    a.free(p1)
    assert a.free_pages == 3
    p3 = a.alloc(3)
    assert p3 is not None and set(p3) == set(p1)


def test_allocator_double_free_raises():
    a = BlockAllocator(num_pages=4, page_size=4)
    p = a.alloc(1)
    a.free(p)
    with pytest.raises(ValueError):
        a.free(p)


def test_defrag_compacts_live_pages():
    a = BlockAllocator(num_pages=16, page_size=4)
    p1 = a.alloc(3)
    p2 = a.alloc(3)
    a.free(p1)
    moves = a.defrag_plan()
    # surviving pages now occupy 1..3
    live_after = set(moves.get(p, p) for p in p2)
    assert live_after == {1, 2, 3}
    assert a.alloc(12) is not None            # whole pool reusable


def test_block_table_pages_needed():
    t = BlockTable(pages=[5], length=4)
    assert t.pages_needed(4, page_size=4) == 0
    assert t.pages_needed(5, page_size=4) == 1
    assert t.pages_needed(9, page_size=4) == 2
    assert t.padded(3) == [5, NULL_PAGE, NULL_PAGE]


# ---------------------------------------------------------------------------
# paged-gather kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(6, 4, 8), (10, 8, 16)])
def test_paged_gather_kernel_matches_ref(shape):
    n, p, d = shape
    pool = jax.random.normal(jax.random.PRNGKey(0), (n, p, d))
    tables = jax.random.randint(jax.random.PRNGKey(1), (3, 4), 0, n)
    want = ref.paged_gather_ref(pool, tables)
    got = ops.paged_gather(pool, tables, use_pallas=True)     # interpret
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    got_ref = ops.paged_gather(pool, tables, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want))


@pytest.mark.parametrize("shape", [(6, 4, 8), (10, 8, 16)])
def test_paged_gather_dequant_kernel_matches_ref(shape):
    n, p, d = shape
    pool = jax.random.randint(jax.random.PRNGKey(0), (n, p, d), -127, 128,
                              jnp.int8)
    scales = jax.random.uniform(jax.random.PRNGKey(1), (n, p, 1),
                                jnp.float32, 0.01, 0.1)
    tables = jax.random.randint(jax.random.PRNGKey(2), (3, 4), 0, n)
    want = ref.paged_gather_dequant_ref(pool, scales, tables)
    got = ops.paged_gather_dequant(pool, scales, tables, use_pallas=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    got_ref = ops.paged_gather_dequant(pool, scales, tables,
                                       use_pallas=False)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want))
    # manual dequant oracle
    idx = np.asarray(tables)
    oracle = np.asarray(pool).astype(np.float32)[idx] * \
        np.asarray(scales)[idx]
    np.testing.assert_allclose(
        np.asarray(want), oracle.reshape(3, 4 * p, d))


# ---------------------------------------------------------------------------
# engine end-to-end per family
# ---------------------------------------------------------------------------

FAMILY_CASES = [
    ("kv", "qwen3-4b", {}),
    ("srf", "qwen3-4b", {"attn_impl": "srf"}),
    ("mla", "deepseek-v2-lite-16b", {}),
    ("ssd", "mamba2-2.7b", {}),
]


@pytest.mark.parametrize("fam,arch,over", FAMILY_CASES,
                         ids=[c[0] for c in FAMILY_CASES])
def test_engine_mixed_lengths_per_family(fam, arch, over):
    cfg = registry.reduced(arch, n_layers=2, **over)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=8, max_len=64)
    rng = np.random.default_rng(0)
    n = 16
    for i in range(n):
        plen = int(rng.integers(2, 24))
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, plen)
                           .astype(np.int32),
                           max_new=int(rng.integers(3, 8))))
    done = eng.run()
    assert len(done) == n
    assert all(len(r.out_tokens) == r.max_new for r in done)
    assert eng.stats["requests"] == n
    # every page returned to the pool
    assert eng.sched.alloc.used_pages == 0


def test_paged_matches_legacy_greedy():
    """Same params, same prompt: the paged engine's greedy output equals
    the legacy contiguous-cache engine's."""
    legacy = _legacy()
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(11, dtype=np.int32)

    eng = Engine(cfg, params, batch_slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new=8))
    paged = eng.run()[0].out_tokens

    leg = legacy.Engine(cfg, params, batch_slots=1, max_len=64)
    leg.submit(Request(uid=0, prompt=prompt, max_new=8))
    old = leg.run()[0].out_tokens
    assert paged == old


def test_preemption_restores_state():
    """Tight pool forces eviction mid-decode; copy-on-preempt + swap-in
    must reproduce the unconstrained outputs exactly."""
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 3).astype(np.int32)
               for _ in range(4)]

    def drive(sched):
        eng = Engine(cfg, params, batch_slots=4, max_len=16, sched=sched)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new=10))
        done = eng.run()
        return {r.uid: r.out_tokens for r in done}, eng.stats["preemptions"]

    tight = SchedConfig(max_batch=4, prefill_batch=2, prefill_chunk=4,
                        page_size=4, num_pages=9, table_width=4)
    roomy = SchedConfig(max_batch=4, prefill_batch=2, prefill_chunk=4,
                        page_size=4, num_pages=33, table_width=4)
    out_tight, n_pre = drive(tight)
    out_roomy, _ = drive(roomy)
    assert n_pre > 0, "pool was not tight enough to force preemption"
    assert out_tight == out_roomy


def test_priority_policy_orders_admission():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    # pool with room for a single active request at a time
    sched = SchedConfig(max_batch=1, prefill_batch=1, prefill_chunk=8,
                        page_size=8, num_pages=3, table_width=2,
                        policy="priority")
    eng = Engine(cfg, params, sched=sched)
    prompt = np.arange(6, dtype=np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=4, priority=0))
    eng.submit(Request(uid=1, prompt=prompt, max_new=4, priority=5))
    done = eng.run()
    assert len(done) == 2
    by_uid = {r.uid: r for r in done}
    assert by_uid[1].t_done <= by_uid[0].t_done   # high priority first


@pytest.mark.parametrize("attn", ["full", "srf"])
def test_chunked_prefill_long_prompt(attn):
    """Prompt much longer than the chunk: result equals one-shot legacy
    (for SRF this also covers rope positions past the single state page
    and the carried-state chunk boundary)."""
    legacy = _legacy()
    cfg = registry.reduced("qwen3-4b", n_layers=2, attn_impl=attn)
    params = T.init(jax.random.PRNGKey(0), cfg)
    prompt = (np.arange(50, dtype=np.int32) * 7) % cfg.vocab
    sched = SchedConfig(max_batch=2, prefill_batch=2, prefill_chunk=8,
                        page_size=8, num_pages=33, table_width=8)
    eng = Engine(cfg, params, sched=sched)
    eng.submit(Request(uid=0, prompt=prompt, max_new=6))
    paged = eng.run()[0].out_tokens
    leg = legacy.Engine(cfg, params, batch_slots=1, max_len=128)
    leg.submit(Request(uid=0, prompt=prompt, max_new=6))
    assert paged == leg.run()[0].out_tokens


def test_max_new_one_emits_exactly_one_token():
    """Regression: a max_new=1 request finishes AT PREFILL with exactly
    one output token. Previously the prefill step appended the first
    token without checking eos/max_new, so such a request took an extra
    decode step and emitted max_new+1 tokens (both engines had the bug)."""
    legacy = _legacy()
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(2, 12))).astype(np.int32)
               for _ in range(6)]

    eng = Engine(cfg, params, batch_slots=4, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p.copy(), max_new=1))
    done = eng.run()
    assert len(done) == 6
    assert all(len(r.out_tokens) == 1 for r in done)
    # finished at prefill: no decode step ran, everything returned
    assert eng.metrics.value_sum("engine_decode_steps_total") == 0
    assert eng.sched.alloc.used_pages == 0

    leg = legacy.Engine(cfg, params, batch_slots=4, max_len=64)
    for i, p in enumerate(prompts):
        leg.submit(Request(uid=i, prompt=p.copy(), max_new=1))
    ldone = leg.run()
    assert all(len(r.out_tokens) == 1 for r in ldone)
    assert {r.uid: r.out_tokens for r in done} == \
        {r.uid: r.out_tokens for r in ldone}


def test_eos_on_first_token_finishes_at_prefill():
    """A request whose FIRST sampled token is eos stops with one token
    and a closed trace: learn the greedy first token, resubmit with it
    as eos_id."""
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(9, dtype=np.int32)
    eng = Engine(cfg, params, batch_slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt.copy(), max_new=8))
    first = eng.run()[0].out_tokens[0]

    eng2 = Engine(cfg, params, batch_slots=2, max_len=64)
    eng2.submit(Request(uid=0, prompt=prompt.copy(), max_new=8,
                        eos_id=int(first)))
    done = eng2.run()
    assert len(done) == 1
    r = done[0]
    assert r.out_tokens == [first]
    assert r.t_submit <= r.t_first <= r.t_done
    assert r.trace.count("done") == 1 and r.trace.monotonic()
    assert eng2.metrics.value_sum("engine_decode_steps_total") == 0


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def test_sampler_greedy_topk_topp():
    from repro.serving.sampler import sample
    logits = jnp.log(jnp.asarray([[0.05, 0.15, 0.5, 0.3]] * 3))
    out = sample(jax.random.PRNGKey(0), logits,
                 jnp.asarray([0.0, 1.0, 1.0]),      # greedy / k=1 / tiny p
                 jnp.asarray([0, 1, 0]),
                 jnp.asarray([1.0, 1.0, 1e-6]))
    assert list(np.asarray(out)) == [2, 2, 2]
    # top-k=2 support is exactly {2, 3}
    hits = set()
    for i in range(64):
        o = sample(jax.random.PRNGKey(i), logits, jnp.asarray([1.0] * 3),
                   jnp.asarray([2] * 3), jnp.asarray([1.0] * 3))
        hits.update(int(x) for x in np.asarray(o))
    assert hits == {2, 3}


def test_engine_sampled_run_completes():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=4, max_len=64, seed=7)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=np.arange(5, dtype=np.int32),
                           max_new=6, temperature=0.9, top_k=50, top_p=0.95))
    done = eng.run()
    assert len(done) == 4
    assert all(len(r.out_tokens) == 6 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out_tokens)


# ---------------------------------------------------------------------------
# seeded SRF: per-request zero-storage personalized projections
# ---------------------------------------------------------------------------

def _seeded_srf_cfg():
    import dataclasses
    cfg = registry.reduced("qwen3-4b", n_layers=2, attn_impl="srf")
    return dataclasses.replace(
        cfg, srf=dataclasses.replace(cfg.srf, seeded=True))


def test_seeded_srf_engine_personalizes_per_request():
    """Requests carry ``embed_seed``: same prompt, different seeds →
    different (personalized) greedy streams; same seed → bit-identical
    regardless of which other requests share the batch. embed_seed=0 is
    the shared base projection. No per-request projection weights exist
    anywhere — the kernel regenerates them from the folded seed."""
    cfg = _seeded_srf_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    # the SRF projection params really are seeds — one uint32 per
    # (layer, head), no float matrices (zero storage in n_features)
    seeds = [l for l in jax.tree_util.tree_leaves(params)
             if l.dtype == jnp.uint32]
    assert seeds and all(l.size <= cfg.n_layers * cfg.n_heads
                         for l in seeds)
    prompt = np.arange(9, dtype=np.int32)

    def run(seeds):
        eng = Engine(cfg, params, batch_slots=4, max_len=64)
        for i, es in enumerate(seeds):
            eng.submit(Request(uid=i, prompt=prompt.copy(), max_new=6,
                               embed_seed=es))
        return {r.uid: list(r.out_tokens) for r in eng.run()}

    mixed = run([0, 123, 777])
    assert mixed[1] != mixed[0], "embed_seed=123 did not personalize"
    assert mixed[2] != mixed[1]
    # batch-composition invariance: each stream reproduces solo
    assert run([123])[0] == mixed[1]
    assert run([0])[0] == mixed[0]
    # determinism: rerun bit-identical
    assert run([0, 123, 777]) == mixed


def test_seeded_srf_zero_embed_matches_unseeded_semantics():
    """The base (embed_seed=0) projection is one fixed per-head seed set:
    an all-base batch equals a batch submitted without touching
    embed_seed at all (the default)."""
    cfg = _seeded_srf_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(3, 14)))
               .astype(np.int32) for _ in range(5)]

    def run(with_field):
        eng = Engine(cfg, params, batch_slots=4, max_len=64)
        for i, p in enumerate(prompts):
            kw = {"embed_seed": 0} if with_field else {}
            eng.submit(Request(uid=i, prompt=p.copy(), max_new=5, **kw))
        return {r.uid: list(r.out_tokens) for r in eng.run()}

    assert run(True) == run(False)
