"""Fault-tolerance integration: loss decreases, crash->resume determinism,
straggler watchdog, data-stream determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import synth
from repro.data.loader import ShardedLoader
from repro.ft.straggler import StragglerConfig, StragglerWatchdog
from repro.launch.steps import TrainHyper
from repro.train.trainer import CrashInjected, Trainer, TrainerConfig


def _tcfg(tmp_path, **kw):
    base = dict(num_steps=30, batch=4, seq=32, ckpt_every=10, log_every=5,
                ckpt_dir=str(tmp_path),
                hyper=TrainHyper(lr=1e-2, warmup=5, total_steps=30))
    base.update(kw)
    return TrainerConfig(**base)


def test_loss_decreases(tmp_path):
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    tr = Trainer(cfg, _tcfg(tmp_path, num_steps=40))
    out = tr.train()
    losses = [r["loss"] for r in out["log"]]
    assert losses[-1] < losses[0] - 0.3, losses


def test_crash_resume_is_deterministic(tmp_path):
    """Train A: uninterrupted. Train B: crash at step 17, restart, resume
    from the step-10 checkpoint. Final params must match EXACTLY."""
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    ta = Trainer(cfg, _tcfg(tmp_path / "a"))
    out_a = ta.train()

    tb = Trainer(cfg, _tcfg(tmp_path / "b"), crash_at=17)
    with pytest.raises(CrashInjected):
        tb.train()
    # the step-10 save is async; model it as durably committed before the
    # crash (in-process, the writer thread races the immediate "restart")
    tb.ckpt.wait()
    # "restart the job"
    tb2 = Trainer(cfg, _tcfg(tmp_path / "b"))
    assert tb2.try_resume()
    assert tb2.step == 10          # resumed from the committed checkpoint
    out_b = tb2.train()
    la = jax.tree.leaves(ta.params)
    lb = jax.tree.leaves(tb2.params)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert out_a["final_step"] == out_b["final_step"] == 30


def test_data_stream_determinism():
    b1 = synth.lm_batch(100, 4, 16, step=3, seed=7, shard=2)
    b2 = synth.lm_batch(100, 4, 16, step=3, seed=7, shard=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synth.lm_batch(100, 4, 16, step=4, seed=7, shard=2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    b4 = synth.lm_batch(100, 4, 16, step=3, seed=7, shard=3)
    assert not np.array_equal(b1["tokens"], b4["tokens"])


def test_loader_reset_replays(tmp_path):
    def mk(step, shard):
        return {"x": np.full((2,), step)}
    ld = ShardedLoader(mk, prefetch=2)
    it = iter(ld)
    s0, b0 = next(it)
    s1, b1 = next(it)
    assert (s0, s1) == (0, 1)
    ld.reset(1)
    it = iter(ld)
    s, b = next(it)
    assert s == 1 and b["x"][0] == 1
    ld.stop()


def test_straggler_watchdog_reassigns():
    wd = StragglerWatchdog(4, StragglerConfig(grace_steps=2, threshold=1.5))
    ev = None
    for step in range(10):
        for h in range(4):
            dt = 1.0 if h != 2 else 3.0      # host 2 is slow
            e = wd.record(h, step, dt)
            ev = e or ev
    assert ev is not None and ev["host"] == 2
    assert ev["action"] == "reassign"
    assert len(wd.events) >= 1


def test_straggler_exclude_policy():
    wd = StragglerWatchdog(4, StragglerConfig(grace_steps=1, threshold=1.5,
                                              policy="exclude"))
    for step in range(6):
        for h in range(4):
            wd.record(h, step, 5.0 if h == 0 else 1.0)
    shard_map = wd.active_shard_map()
    assert 0 not in shard_map
    assert len(shard_map) == 3


def test_straggler_reassign_with_all_peers_excluded_warns():
    """Regression: ``_act`` with policy=reassign used to crash on
    ``min()`` over an empty candidate set when every other host was
    excluded (external controllers — elastic shrink, the serving router
    — mark hosts excluded outside the exclude policy). It must degrade
    to a warn event instead."""
    wd = StragglerWatchdog(4, StragglerConfig(grace_steps=1, threshold=1.5))
    for step in range(4):                     # establish EMAs
        for h in range(4):
            wd.record(h, step, 1.0)
    for h in (0, 1, 3):                       # external exclusion
        wd.hosts[h].excluded = True
    # record path stays quiet (median needs >= 2 active hosts) ...
    assert wd.record(2, 5, 9.0) is None
    # ... and the direct act path warns instead of raising ValueError
    ev = wd._act(2, 5, 1.0)
    assert ev["action"] == "warn"
    assert "reassigned_to_host" not in ev
    assert wd.hosts[2].shard == 2             # shard map untouched


def test_elastic_shrink_plan_and_axis():
    from repro.ft import elastic
    plan = elastic.shrink_plan(4, failed=(1, 3), model=1)
    assert plan == {"alive_hosts": 2, "new_data_axis": 2,
                    "shard_of_host": {0: 0, 2: 1}}
    assert elastic.viable_data_axis(8, 2) == 4
    with pytest.raises(ValueError):
        elastic.viable_data_axis(6, 4)


def test_elastic_degrade_and_reshard():
    from types import SimpleNamespace
    from jax.sharding import PartitionSpec as P
    from repro.ft import elastic
    mesh2 = SimpleNamespace(axis_names=("data", "model"),
                            devices=np.zeros((2, 2)))
    # dividing dims keep their axes; non-dividing degrade to replication
    assert elastic._degrade(P("data"), (4, 8), mesh2) == P("data", None)
    assert elastic._degrade(P("data"), (3, 8), mesh2) == P(None, None)
    assert elastic._degrade(P(("data", "model")), (8,), mesh2) \
        == P(("data", "model"))
    assert elastic._degrade(P(("data", "model")), (6,), mesh2) == P(None)
    # reshard on a real (1, 1) mesh round-trips values
    mesh = elastic.remesh(jax.devices()[:1], model_parallel=1)
    assert mesh.devices.shape == (1, 1)
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    out = elastic.reshard_tree(tree, {"w": P("data")}, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


def test_compressed_dp_trainer_runs(tmp_path):
    """compress_dp path on a (pod=2, data=1, model=1)-style mesh is covered
    by the subprocess sharding test; here: config plumbs through on 1 dev
    without a pod axis -> falls back to plain training."""
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    tr = Trainer(cfg, _tcfg(tmp_path, num_steps=6, compress_dp=True))
    out = tr.train()   # mesh=None -> plain path
    assert out["final_step"] == 6
