"""FWHT: butterfly == dense Hadamard == Kronecker (MXU) form; HD isometry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import transforms as T


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 9), seed=st.integers(0, 2**16))
def test_fwht_equals_dense(k, seed):
    n = 1 << k
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, n))
    h = T.hadamard(n)
    np.testing.assert_allclose(np.asarray(T.fwht(x)), np.asarray(x @ h.T),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 10), seed=st.integers(0, 2**16))
def test_kron_form_equals_butterfly(k, seed):
    n = 1 << k
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, n))
    np.testing.assert_allclose(np.asarray(T.fwht_kron(x)),
                               np.asarray(T.fwht(x)), rtol=1e-4, atol=1e-4)


def test_hd_preprocess_is_isometry():
    n = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (5, n))
    d0 = T.sample_signs(jax.random.PRNGKey(1), n)
    d1 = T.sample_signs(jax.random.PRNGKey(2), n)
    y = T.hd_preprocess(x, d0, d1)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_pad_pow2():
    x = jnp.ones((2, 100))
    assert T.pad_pow2(x).shape == (2, 128)
    assert T.pad_pow2(jnp.ones((2, 64))).shape == (2, 64)


def test_balancedness_after_hd():
    """Lemma 15's working: HD spreads mass -> coordinates are log(n)-balanced."""
    n = 256
    x = jnp.zeros((n,)).at[3].set(1.0)   # worst case: a basis vector
    d0 = T.sample_signs(jax.random.PRNGKey(1), n)
    d1 = T.sample_signs(jax.random.PRNGKey(2), n)
    y = T.hd_preprocess(x, d0, d1)
    assert float(jnp.abs(y).max()) <= np.log(n) / np.sqrt(n)
