"""Property tests: every structured fast path == dense materialization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import structured as S

KINDS = list(S.KINDS)


@st.composite
def mn(draw):
    n = draw(st.sampled_from([4, 8, 16, 32]))
    m = draw(st.integers(1, 3 * n))
    return m, n


@settings(max_examples=25, deadline=None)
@given(kind=st.sampled_from(KINDS), shapes=mn(), seed=st.integers(0, 2**16),
       batch=st.integers(1, 3))
def test_matvec_matches_dense(kind, shapes, seed, batch):
    m, n = shapes
    r = 2
    params = S.init(jax.random.PRNGKey(seed), kind, m, n, r=r)
    a = S.materialize(kind, params, m, n)
    assert a.shape == (m, n)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, n))
    y_fast = S.matvec(kind, params, x, m)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(x @ a.T),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kind", KINDS)
def test_budget_below_dense(kind):
    m, n = 64, 64
    t = S.budget(kind, m, n, r=2)
    if kind == "unstructured":
        assert t == m * n
    else:
        assert t < m * n  # the paper's point: t << mn


@pytest.mark.parametrize("kind", ["circulant", "toeplitz", "hankel",
                                  "skew_circulant"])
def test_rows_are_standard_gaussian(kind):
    """Normalization property (Def. 1): rows of A are N(0, I_n) marginally."""
    m, n = 8, 16
    trials = 4000
    keys = jax.random.split(jax.random.PRNGKey(0), trials)

    def row0(k):
        p = S.init(k, kind, m, n)
        return S.materialize(kind, p, m, n)[m // 2]
    rows = jax.vmap(row0)(keys)
    mean = np.asarray(rows.mean(0))
    var = np.asarray(rows.var(0))
    assert np.all(np.abs(mean) < 0.1), mean
    assert np.all(np.abs(var - 1.0) < 0.15), var


def test_bf16_fft_paths():
    """bf16 inputs route through f32 FFT and come back finite."""
    p = S.init(jax.random.PRNGKey(0), "circulant", 8, 16)
    p = {k: v.astype(jnp.bfloat16) for k, v in p.items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16), jnp.bfloat16)
    y = S.matvec("circulant", p, x, 8)
    assert y.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


def test_storage_claim():
    """Space complexity: structured storage is O(n), dense is O(mn)."""
    m, n = 256, 256
    assert S.storage_floats("circulant", m, n) == n
    assert S.storage_floats("toeplitz", m, n) == n + m - 1
    assert S.storage_floats("unstructured", m, n) == m * n
