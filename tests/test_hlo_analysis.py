"""HLO analyzer: trip-count-aware flops, collective detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _scan_model(L):
    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x
    return f


@pytest.mark.parametrize("L", [1, 3, 8])
def test_scan_flops_scale_with_trip_count(L):
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    c = jax.jit(_scan_model(L)).lower(ws, x).compile()
    r = H.analyze(c.as_text())
    expect = 2 * 32 * 64 * 64 * L
    assert abs(r["flops"] - expect) < 1e-6 * expect, (r["flops"], expect)
    # XLA's own cost_analysis counts the body once (the reason this module
    # exists) — guard that the premise still holds:
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):       # older jaxlib: one dict per device
        ca = ca[0]
    if L > 1:
        assert ca["flops"] < expect


def test_nested_scan_trips_multiply():
    def f(x):
        def outer(x, _):
            def inner(y, _):
                return jnp.tanh(y @ jnp.eye(16)), None
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    r = H.analyze(c.as_text())
    expect = 2 * 8 * 16 * 16 * 15
    assert abs(r["flops"] - expect) < 1e-6 * expect, r["flops"]


def test_roofline_terms():
    per_dev = {"flops": 197e12, "bytes": 819e9 / 2, "collective_bytes": 0.0}
    t = H.roofline_terms(per_dev)
    assert t["t_compute"] == pytest.approx(1.0)
    assert t["t_memory"] == pytest.approx(0.5)
    assert t["bottleneck"] == "compute"


def test_shape_bytes_parse():
    assert H._shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert H._shape_bytes("bf16[16]") == 32
    assert H._shape_bytes("(f32[2,2], s32[3])") == 16 + 12
