"""Serving engine: continuous batching drains, outputs deterministic,
SRF cache (paper technique) serves identically-shaped outputs."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as T
from repro.serving.engine import Engine, Request


@pytest.mark.parametrize("attn", ["full", "srf"])
def test_engine_generates(attn):
    cfg = registry.reduced("qwen3-4b", n_layers=2, attn_impl=attn)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab, 8).astype(np.int32), max_new=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)
    assert eng.stats["requests"] == 5


def test_engine_greedy_deterministic():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(8, dtype=np.int32)

    def gen():
        eng = Engine(cfg, params, batch_slots=1, max_len=64)
        eng.submit(Request(uid=0, prompt=prompt, max_new=8))
        return eng.run()[0].out_tokens
    assert gen() == gen()


def test_eos_stops_early():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new=50, eos_id=-2))  # never fires
    r = eng.run()[0]
    assert len(r.out_tokens) == 50
