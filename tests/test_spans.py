"""Span timelines: recorder semantics, Chrome-trace export (golden
schema pin), multi-replica merge, and the engine/router instrumentation
contract (spans off by default, clock reads unchanged)."""
import json

import numpy as np

from repro.obs import SpanRecorder, chrome_trace, dump_chrome_trace
from repro.obs.spans import NOOP


# ---------------------------------------------------------------------------
# recorder semantics
# ---------------------------------------------------------------------------

def test_begin_end_records_span_with_args():
    rec = SpanRecorder()
    tok = rec.begin("work", uid=7, rows=3)
    tok.args["extra"] = 1
    rec.end(tok)
    (sp,) = rec.snapshot()
    assert sp.name == "work" and sp.uid == 7
    assert sp.args == {"rows": 3, "extra": 1}
    assert sp.t1 >= sp.t0 and sp.kind == "span"


def test_parent_links_follow_open_span_stack():
    rec = SpanRecorder()
    outer = rec.begin("outer")
    inner = rec.begin("inner")
    rec.end(inner)
    rec.end(outer)
    by_name = {s.name: s for s in rec.snapshot()}
    assert by_name["outer"].parent is None
    assert by_name["inner"].parent == by_name["outer"].sid


def test_context_manager_and_instant():
    rec = SpanRecorder(replica=2)
    with rec.span("step", uid=1):
        rec.instant("hit", uid=1, tokens=4)
    kinds = {s.name: s for s in rec.snapshot()}
    assert kinds["hit"].kind == "instant"
    assert kinds["hit"].t0 == kinds["hit"].t1
    assert kinds["hit"].parent == kinds["step"].sid   # nested under step
    assert all(s.replica == 2 for s in rec.snapshot())


def test_ring_bounded_and_dropped_counter():
    rec = SpanRecorder(maxlen=4)
    for i in range(10):
        rec.instant(f"e{i}")
    assert len(rec) == 4
    assert rec.dropped == 6
    assert [s.name for s in rec.snapshot()] == ["e6", "e7", "e8", "e9"]
    rec.clear()
    assert len(rec) == 0


def test_disabled_recorder_is_noop():
    rec = SpanRecorder(enabled=False)
    tok = rec.begin("x", uid=1)
    tok.args["y"] = 2          # absorbed, never recorded
    rec.end(tok)
    with rec.span("z"):
        rec.instant("i")
    assert len(rec) == 0 and rec.snapshot() == []
    assert len(NOOP) == 0      # the module-level shared instance too


def test_sids_unique_across_recorders():
    a, b = SpanRecorder(replica=0), SpanRecorder(replica=1)
    a.instant("x")
    b.instant("x")
    sids = [s.sid for s in a.snapshot() + b.snapshot()]
    assert len(set(sids)) == 2  # process-global counter: merge-safe


# ---------------------------------------------------------------------------
# chrome-trace export: golden schema pin (fixed timestamps via complete())
# ---------------------------------------------------------------------------

def _golden_recorders():
    r0 = SpanRecorder(replica=0)
    root = r0.complete("engine_step", 1.0, 1.5, rows=2)
    r0.complete("prefill_step", 1.1, 1.3, parent=root)
    r0.complete("decode_step", 1.3, 1.5, parent=root)
    r1 = SpanRecorder(replica=1)
    r1.complete("engine_step", 1.2, 1.4, uid=9)
    return [r0, r1]


def test_chrome_trace_golden_schema(tmp_path):
    recs = _golden_recorders()
    path = tmp_path / "trace.json"
    n = dump_chrome_trace(str(path), recs)
    doc = json.loads(path.read_text())       # schema-valid JSON on disk
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert n == len(evs)
    meta = [e for e in evs if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} == {"replica 0", "replica 1"}
    be = [e for e in evs if e["ph"] in "BE"]
    # every B/E event carries the required Chrome trace-event fields
    for e in be:
        assert {"name", "ph", "pid", "tid", "ts"} <= set(e)
        assert isinstance(e["ts"], float) and e["ts"] >= 0.0
    # earliest span anchors the merged clock at ts=0
    assert min(e["ts"] for e in be) == 0.0


def test_chrome_trace_begin_end_paired_and_monotonic():
    recs = _golden_recorders()
    doc = chrome_trace(recs)
    for pid in (0, 1):
        seq = [e for e in doc["traceEvents"]
               if e.get("pid") == pid and e["ph"] in "BE"]
        # ts never decreases within one pid row
        assert all(a["ts"] <= b["ts"] for a, b in zip(seq, seq[1:]))
        stack = []
        for e in seq:
            if e["ph"] == "B":
                stack.append(e["name"])
            else:
                assert stack.pop() == e["name"]   # E matches innermost B
        assert stack == []                        # fully paired


def test_chrome_trace_merges_replicas_onto_one_clock():
    recs = _golden_recorders()
    evs = chrome_trace(recs)["traceEvents"]
    b0 = next(e for e in evs if e["pid"] == 0 and e["ph"] == "B"
              and e["name"] == "engine_step")
    b1 = next(e for e in evs if e["pid"] == 1 and e["ph"] == "B")
    # replica 1's step began 0.2s into replica 0's: 200000us on the
    # shared normalized clock, not 0 on a per-replica clock
    assert b1["ts"] - b0["ts"] == 200000.0
    assert b1["args"]["uid"] == 9                 # uid rides into args


def test_chrome_trace_instants():
    r = SpanRecorder(replica=3)
    r.complete("step", 2.0, 3.0)
    r.instant("prefix_hit", uid=5, tokens=8)
    evs = chrome_trace([r])["traceEvents"]
    (i,) = [e for e in evs if e["ph"] == "i"]
    assert i["s"] == "t" and i["pid"] == 3
    assert i["args"]["uid"] == 5 and i["args"]["tokens"] == 8


def test_chrome_trace_empty_recorder():
    doc = chrome_trace(SpanRecorder())
    assert doc["traceEvents"] == []


# ---------------------------------------------------------------------------
# engine integration: spans record the serving control flow
# ---------------------------------------------------------------------------

def test_engine_records_step_spans_and_export_loads():
    import jax
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import Engine, Request

    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rec = SpanRecorder(replica=0)
    eng = Engine(cfg, params, batch_slots=2, max_len=64, spans=rec)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                           max_new=4))
    eng.run()
    names = {s.name for s in rec.snapshot()}
    assert {"engine_step", "admit", "prefill_step",
            "decode_step", "sample"} <= names
    by_name = {}
    for s in rec.snapshot():
        by_name.setdefault(s.name, s)
    # nesting: prefill/decode/sample live under an engine_step
    steps = {s.sid for s in rec.snapshot() if s.name == "engine_step"}
    assert by_name["prefill_step"].parent in steps
    assert by_name["decode_step"].parent in steps
    doc = chrome_trace(rec)
    assert json.loads(json.dumps(doc)) == doc     # JSON-serializable
    assert any(e["ph"] == "B" for e in doc["traceEvents"])


def test_engine_without_spans_records_nothing():
    # default Engine uses the shared NOOP recorder: no per-step cost
    import jax
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving import Engine, Request

    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    before = len(NOOP)
    eng = Engine(cfg, params, batch_slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new=2))
    eng.run()
    assert len(NOOP) == before == 0
