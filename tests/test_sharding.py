"""Sharding rules: divisibility degrade, ZeRO-1 specs, elastic resharding,
and an 8-device (2,2,2) subprocess lower/compile of train+decode+compressed
collectives (the multi-pod dry-run in miniature)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding as S
from repro.ft import elastic
from repro.models import transformer as T
from repro.optim import adamw

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh11():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_param_specs_cover_all_leaves():
    mesh = _mesh11()
    for arch in registry.ARCHS:
        cfg = registry.reduced(arch)
        params = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
        specs = S.param_specs(params, mesh)
        n_p = len(jax.tree.leaves(params))
        n_s = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_p == n_s, arch


def test_degrade_to_replication_on_indivisible():
    """qwen2-vl has 12 heads; under model=16 the q_dim must NOT be sharded
    if it does not divide. With a fake 16-wide axis check _fits logic."""
    devs = np.array(jax.devices() * 16)[:16].reshape(1, 16)
    mesh = Mesh(devs, ("data", "model"))
    # 12 heads * 128 = 1536 does not divide 16? 1536/16=96 -> divides.
    assert S._fits((1536,), 0, mesh, "model")
    assert not S._fits((25,), 0, mesh, "model")      # hymba heads
    assert not S._fits((10, 3), 1, mesh, "model")


def test_zero1_adds_data_axis():
    devs = np.array(jax.devices() * 8)[:8].reshape(4, 2)
    mesh = Mesh(devs, ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    pspecs = {"w": P(None, "model")}
    z = S.zero1_specs(params, pspecs, mesh)
    assert z["w"] == P("data", "model")


def test_elastic_degrade_spec():
    devs = np.array(jax.devices() * 8)[:8].reshape(4, 2)
    mesh = Mesh(devs, ("data", "model"))
    spec = elastic._degrade(P("data", "model"), (12, 10), mesh)
    assert spec == P("data", "model")   # both divide (12%4, 10%2)
    spec2 = elastic._degrade(P("data", "model"), (13, 10), mesh)
    assert spec2 == P(None, "model")    # 13 % 4 != 0 -> replicate dim0
    spec3 = elastic._degrade(P("data", "model"), (12, 9), mesh)
    assert spec3 == P("data", None)


def test_shrink_plan():
    plan = elastic.shrink_plan(8, failed=(2, 5), model=2)
    assert plan["alive_hosts"] == 6
    assert plan["shard_of_host"][0] == 0
    assert plan["shard_of_host"][3] == 2     # compacted


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import registry, shapes
    from repro.distributed import sharding as S, collectives
    from repro.launch import mesh as M, steps
    from repro.models import transformer as T, hooks
    from repro.optim import adamw, compression as C

    mesh = M.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = registry.reduced("deepseek-v2-lite-16b")
    hooks.set_constrainer(S.make_constrainer(mesh, cfg))
    params = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
    pspecs = S.param_specs(params, mesh)
    opt = jax.eval_shape(lambda: adamw.init(params))
    ospecs = S.opt_state_specs(opt, params, pspecs, mesh)
    bspecs_sds = shapes.batch_specs(cfg, 8, 32, training=True)
    bspecs = S.batch_specs_tree(bspecs_sds, mesh)
    with mesh:
        fn = steps.make_train_step(cfg)
        c = jax.jit(fn, in_shardings=(S.named(mesh, pspecs),
                                      S.named(mesh, ospecs), None,
                                      S.named(mesh, bspecs)),
                    donate_argnums=(0, 1)).lower(
            params, opt, jax.ShapeDtypeStruct((), jnp.int32),
            bspecs_sds).compile()
        assert "all-reduce" in c.as_text() or "all-gather" in c.as_text()
        print("TRAIN_OK")

        # decode step with cache sharding
        ins = shapes.input_specs(cfg, "decode_32k", batch_override=8,
                                 seq_override=64)
        cspecs = S.cache_specs_tree(ins["cache"], cfg, mesh)
        sfn = steps.make_serve_step(cfg)
        c2 = jax.jit(sfn, in_shardings=(S.named(mesh, pspecs),
                                        S.named(mesh, cspecs), None),
                     donate_argnums=(1,)).lower(
            params, ins["cache"], ins["tokens"]).compile()
        print("DECODE_OK")

        # compressed cross-pod mean: real execution on 8 cpu devices
        g = {"w": jnp.ones((2048,), jnp.float32)}
        err = C.init_error(g)
        cc = C.CompressionConfig(chunk=512, ratio=4, min_size=1)
        gm, err2 = collectives.compressed_pod_mean(g, err, mesh, cc)
        assert gm["w"].shape == (2048,)
        import numpy as np
        rel = float(jnp.abs(gm["w"] - 1.0).mean())
        # contractive projection one-shot error ~ sqrt(1 - m/n) = 0.87;
        # error feedback recovers the residual across steps (test_optim)
        assert rel < 0.95, rel
        # error feedback captured exactly what was not transmitted
        resid = float(jnp.abs(err2["w"] + gm["w"] - g["w"]).max())
        assert resid < 1e-4, resid
        print("COMPRESS_OK", rel)
""")


@pytest.mark.slow
def test_multi_axis_subprocess_lowering():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "TRAIN_OK" in out.stdout, out.stdout + out.stderr[-3000:]
    assert "DECODE_OK" in out.stdout, out.stdout + out.stderr[-3000:]
    assert "COMPRESS_OK" in out.stdout, out.stdout + out.stderr[-3000:]
