"""AdamW reference math, clipping, decay masking, schedules, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, compression as C, schedule


def test_adamw_matches_reference_math():
    cfg = adamw.AdamWConfig(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                            clip_norm=1e9)
    params = {"w": jnp.array([1.0, -2.0])}
    state = adamw.init(params)
    g = {"w": jnp.array([0.1, 0.2])}
    p2, s2, _ = adamw.update(g, state, params, lr=0.1, cfg=cfg)
    # manual: mu=0.1g? mu = 0.1*g, nu = 0.01*g^2; bias-corrected = g, g^2
    step = (0.1 * np.array([0.1, 0.2]) / 0.1) / (
        np.sqrt(0.01 * np.array([0.01, 0.04]) / 0.01) + 1e-8)
    expect = np.array([1.0, -2.0]) - 0.1 * step
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)
    assert int(s2["count"]) == 1


def test_clip_norm_applied():
    cfg = adamw.AdamWConfig(clip_norm=0.1, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    _, _, stats = adamw.update(g, adamw.init(params), params, 0.1, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_decay_mask_skips_norms_biases():
    params = {"layer": {"mlp": {"wi": jnp.ones(2)},
                        "ln1": {"w": jnp.ones(2)},
                        "attn": {"bq": jnp.ones(2)}}}
    mask = adamw.decay_mask(params)
    assert mask["layer"]["mlp"]["wi"] is True
    assert mask["layer"]["ln1"]["w"] is False
    assert mask["layer"]["attn"]["bq"] is False


def test_warmup_cosine_shape():
    lrs = [float(schedule.warmup_cosine(s, 1.0, 10, 100)) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, rel=0.05)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, rel=0.05)   # min_ratio


# --- structured-JL gradient compression ----------------------------------------

def test_sketch_unbiased():
    """scaling='unbiased': E[unsketch(sketch(x))] == x over draws."""
    n = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    trials = 400
    acc = jnp.zeros_like(x)
    for i in range(trials):
        cc = C.CompressionConfig(chunk=n, ratio=4, seed=i, min_size=1,
                                 scaling="unbiased")
        y = C.compress_leaf(x, cc, 0)
        acc = acc + C.decompress_leaf(y, cc, 0, x.shape, x.dtype)
    err = float(jnp.abs(acc / trials - x).max()) / float(jnp.abs(x).max())
    assert err < 0.25, err


def test_error_feedback_identity_and_stability():
    """EF algebra: applied + err == accumulated true gradient, and with
    the CONTRACTIVE scaling + rotated sketches the error stays bounded
    (the unbiased scaling provably diverges here — see compression.py)."""
    n = 512
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (n,))}
    err = C.init_error(g)
    applied = jnp.zeros(n)
    cc = C.CompressionConfig(chunk=n, ratio=8, seed=0, min_size=1)
    for step in range(20):
        sk, recon, err = C.roundtrip_with_feedback(g, err, cc, step=step)
        applied = applied + recon["w"]
    total_true = 20 * g["w"]
    resid = float(jnp.linalg.norm(applied + err["w"] - total_true))
    assert resid < 1e-3 * float(jnp.linalg.norm(total_true))
    # contractive + rotation -> error memory at its theoretical steady
    # state ||e*|| ~ (1-delta)/delta ||g|| = 7 ||g|| (ratio 8), not inf
    assert float(jnp.linalg.norm(err["w"])) < 12 * float(
        jnp.linalg.norm(g["w"]))


def test_wire_bytes_ratio():
    tree = {"a": jnp.zeros(1 << 16), "b": jnp.zeros(10)}
    cc = C.CompressionConfig(chunk=4096, ratio=8, min_size=1024)
    raw, comp = C.wire_bytes(tree, cc)
    assert raw == ((1 << 16) + 10) * 4
    assert comp == ((1 << 16) // 8 + 10) * 4


def test_compressed_sgd_converges_least_squares():
    """End-to-end: compressed+EF SGD reaches the same loss ballpark as
    exact SGD on a least-squares problem (the convergence claim)."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (64, 32))
    xstar = jax.random.normal(jax.random.PRNGKey(1), (32,))
    b = a @ xstar

    def loss(x):
        return 0.5 * jnp.mean((a @ x - b) ** 2)
    gfn = jax.grad(loss)
    cc = C.CompressionConfig(chunk=32, ratio=4, seed=0, min_size=1)
    x_exact = jnp.zeros(32)
    x_comp = jnp.zeros(32)
    err = {"x": jnp.zeros(32)}
    for step in range(800):
        if step < 300:
            x_exact = x_exact - 0.3 * gfn(x_exact)
        g = {"x": gfn(x_comp)}
        _, recon, err = C.roundtrip_with_feedback(g, err, cc, step=step)
        # EF noise ~ ||e*|| requires a smaller step than exact SGD
        x_comp = x_comp - 0.1 * recon["x"]
    le, lc = float(loss(x_exact)), float(loss(x_comp))
    assert lc < 1e-2, (le, lc)
