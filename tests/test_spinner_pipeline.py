"""Composable Spinner API: multi-block pipelines vs dense oracles, grads,
bf16 bounds, back-compat shims, (de)serialization, registry extension."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import coherence, estimators, features, pmodel, spinner
from repro.core.pmodel import PModelSpec
from repro.core.spinner import KindDef, Nonlinearity, SpinnerBlock, SpinnerPipeline
from repro.kernels import ops as kops

KINDS = list(spinner.structured.KINDS)
NLS = ["identity", "relu", "heaviside", "sign", "exp", "cos_sin"]


def _oracle(pipe, params, x, y_scale=1.0, out_scale=1.0):
    """f(y_scale . A_k...A_1 x) . out_scale via the dense materialized
    product — the semantic ground truth for any pipeline."""
    a = pipe.materialize(params).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    y = (xf @ a.T) * y_scale
    nl = spinner.nonlinearity(pipe.f)
    sq = 0.5 * jnp.sum(xf * xf, -1, keepdims=True) if nl.needs_input else None
    return nl.fn(y, sq) * out_scale


# ---------------------------------------------------------------------------
# multi-block correctness: materialized-product oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("f", NLS)
def test_three_block_matches_dense_oracle(kind, f):
    """HD3.HD2.HD1 stack == its dense product, every kind x nonlinearity."""
    pipe = spinner.hd_chain(kind, n=16, m=24, depth=3, r=2, f=f)
    params = pipe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 16)) * 0.05
    y = pipe.apply(params, x, y_scale=0.7, out_scale=1.3)
    yo = _oracle(pipe, params, x, y_scale=0.7, out_scale=1.3)
    assert y.shape == (5, pipe.out_dim)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yo),
                               rtol=2e-3, atol=2e-3)


def test_mixed_kind_chain_matches_oracle():
    pipe = spinner.chain([SpinnerBlock("circulant", 32, 32),
                          SpinnerBlock("toeplitz", 16, 32),
                          SpinnerBlock("hankel", 48, 16, use_hd=True)],
                         f="relu")
    params = pipe.init(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32)) * 0.1
    np.testing.assert_allclose(np.asarray(pipe.apply(params, x)),
                               np.asarray(_oracle(pipe, params, x)),
                               rtol=2e-3, atol=2e-3)


def test_one_block_identical_to_kernel_op():
    """A 1-block pipeline IS the fused spinner_project dispatch (bitwise)."""
    pipe = spinner.single("skew_circulant", m=96, n=64, f="relu")
    (p,) = pipe.init(jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (7, 64)) * 0.3
    y = pipe.apply((p,), x, out_scale=0.25)
    yk = kops.spinner_project("skew_circulant", p, x, 96, epilogue="relu",
                              out_scale=0.25)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yk))


def test_grouped_multiblock_matches_pergroup():
    pipe = spinner.hd_chain("toeplitz", n=16, m=24, depth=2, f="cos_sin")
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    gp = jax.vmap(lambda k: pipe.init(k))(keys)
    xg = jax.random.normal(jax.random.PRNGKey(7), (3, 6, 16)) * 0.2
    yg = pipe.apply(gp, xg, grouped=True)
    assert yg.shape == (3, 6, pipe.out_dim)
    for g in range(3):
        one = jax.tree_util.tree_map(lambda t: t[g], gp)
        np.testing.assert_allclose(np.asarray(yg[g]),
                                   np.asarray(pipe.apply(one, xg[g])),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gradients through 2- and 3-block stacks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [2, 3])
@pytest.mark.parametrize("kind", ["circulant", "toeplitz"])
def test_gradients_match_dense_oracle(kind, depth):
    pipe = spinner.hd_chain(kind, n=8, m=8, depth=depth, f="cos_sin")
    params = pipe.init(jax.random.PRNGKey(8))
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 8)) * 0.2
    w = jax.random.normal(jax.random.PRNGKey(10), (3, pipe.out_dim))

    def loss_fast(p, xx):
        return jnp.sum(w * pipe.apply(p, xx))

    def loss_oracle(p, xx):
        return jnp.sum(w * _oracle(pipe, p, xx))

    gf = jax.grad(loss_fast, argnums=(0, 1))(params, x)
    go = jax.grad(loss_oracle, argnums=(0, 1))(params, x)
    for a, b in zip(jax.tree_util.tree_leaves(gf),
                    jax.tree_util.tree_leaves(go)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# bf16 tolerance bounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("f", ["identity", "relu", "cos_sin"])
def test_bf16_three_block_within_bounds(f):
    pipe = spinner.hd_chain("circulant", n=32, m=32, depth=3, f=f)
    p16 = jax.tree_util.tree_map(lambda t: t.astype(jnp.bfloat16),
                                 pipe.init(jax.random.PRNGKey(11)))
    x32 = jax.random.normal(jax.random.PRNGKey(12), (6, 32)) * 0.02
    y16 = pipe.apply(p16, x32.astype(jnp.bfloat16))
    assert y16.dtype == jnp.bfloat16
    # oracle from the SAME (bf16-rounded) params, so the bound measures
    # the chained compute path: 3 blocks compound ~3x the 1-block bound
    p32 = jax.tree_util.tree_map(lambda t: t.astype(jnp.float32), p16)
    yo = _oracle(pipe, p32, x32)
    tol = dict(rtol=1.5e-1, atol=1.5e-1) if f == "cos_sin" \
        else dict(rtol=6e-2, atol=1e-1)
    np.testing.assert_allclose(np.asarray(y16, np.float32),
                               np.asarray(yo, np.float32), **tol)


# ---------------------------------------------------------------------------
# back-compat shims: identical outputs + DeprecationWarning
# ---------------------------------------------------------------------------

def test_pmodel_shim_identical_outputs_and_warns():
    spec = PModelSpec(kind="toeplitz", m=48, n=32)
    pipe = spec.pipeline
    with pytest.warns(DeprecationWarning):
        params = pmodel.init(jax.random.PRNGKey(0), spec)
    params_new = pipe.init(jax.random.PRNGKey(0))
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]),
                                      np.asarray(params_new[0][k]))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32)) * 0.3
    with pytest.warns(DeprecationWarning):
        y_old = pmodel.project(spec, params, x)
    np.testing.assert_array_equal(np.asarray(y_old),
                                  np.asarray(pipe.apply(params_new, x)))
    with pytest.warns(DeprecationWarning):
        z_old = pmodel.project_fused(spec, params, x, epilogue="relu",
                                     y_scale=0.5, out_scale=2.0)
    z_new = pipe.with_f("relu").apply(params_new, x, y_scale=0.5,
                                      out_scale=2.0)
    np.testing.assert_array_equal(np.asarray(z_old), np.asarray(z_new))
    np.testing.assert_array_equal(
        np.asarray(pmodel.materialize(spec, params)),
        np.asarray(pipe.materialize(params_new)))


def test_phi_shims_identical_outputs_and_warn():
    spec = PModelSpec(kind="circulant", m=64, n=32)
    pipe = spec.pipeline
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        params = pmodel.init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 32)) * 0.4
    cases = [
        (lambda p: features.phi_scalar(p, params, x, "heaviside"),),
        (lambda p: features.phi_trig(p, params, x, sigma=1.5),),
        (lambda p: features.phi_softmax_pos(p, params, x, stabilize=False),),
        (lambda p: features.phi_softmax_pos(p, params, x, stabilize=True),),
        (lambda p: features.phi_softmax_trig(p, params, x),),
    ]
    for (fn,) in cases:
        with pytest.warns(DeprecationWarning):
            z_old = fn(spec)
        np.testing.assert_array_equal(np.asarray(z_old), np.asarray(fn(pipe)))


def test_estimator_accepts_pipeline_and_legacy_spec():
    v1 = jax.random.normal(jax.random.PRNGKey(2), (32,))
    v1 = v1 / jnp.linalg.norm(v1)
    v2 = jax.random.normal(jax.random.PRNGKey(3), (32,))
    v2 = v2 / jnp.linalg.norm(v2)
    pipe = spinner.single("circulant", m=128, n=32)
    params = pipe.init(jax.random.PRNGKey(4))
    e_new = float(estimators.estimate(pipe, params, "heaviside", v1, v2))
    with pytest.warns(DeprecationWarning):
        e_old = float(estimators.estimate(
            PModelSpec(kind="circulant", m=128, n=32), params[0],
            "heaviside", v1, v2))
    assert e_new == e_old


# ---------------------------------------------------------------------------
# (de)serialization + checkpointing
# ---------------------------------------------------------------------------

def test_config_roundtrip_and_apply_identical():
    pipe = spinner.chain([SpinnerBlock("circulant", 32, 32),
                          SpinnerBlock("ldr", 48, 32, r=2, ldr_nnz=3)],
                         f="exp")
    pipe2 = spinner.loads(spinner.dumps(pipe))
    assert pipe2 == pipe
    params = pipe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32)) * 0.1
    np.testing.assert_array_equal(np.asarray(pipe.apply(params, x)),
                                  np.asarray(pipe2.apply(params, x)))


def test_config_version_guard():
    cfg = spinner.to_config(spinner.single("circulant", m=8, n=8))
    cfg["version"] = 99
    with pytest.raises(ValueError, match="version"):
        spinner.from_config(cfg)


def test_params_checkpoint_roundtrip(tmp_path):
    """Pipeline params are a plain pytree: the checkpoint manager
    round-trips them against a freshly-initialized target."""
    pipe = spinner.hd_chain("circulant", n=16, m=32, depth=2)
    params = pipe.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, {"spinner": params, "pipeline_json": np.frombuffer(
        spinner.dumps(pipe).encode(), dtype=np.uint8)}, blocking=True)
    blank = {"spinner": pipe.init(jax.random.PRNGKey(99)),
             "pipeline_json": np.zeros(
                 len(spinner.dumps(pipe).encode()), np.uint8)}
    restored, step, _ = mgr.restore(blank)
    assert step == 7
    assert spinner.loads(bytes(restored["pipeline_json"]).decode()) == pipe
    for a, b in zip(jax.tree_util.tree_leaves(restored["spinner"]),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_legacy_srf_checkpoint_layout(tmp_path):
    """Pre-pipeline checkpoints stored SRF params as ONE dict
    ('.../srf/g'); restore maps them onto the 1-block tuple layout."""
    pipe = spinner.single("circulant", m=32, n=16)
    (old,) = pipe.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"layers": {"attn": {"srf": old}}}, blocking=True)
    target = {"layers": {"attn": {"srf": pipe.init(jax.random.PRNGKey(5))}}}
    restored, step, _ = mgr.restore(target)
    assert step == 1
    for k in old:
        np.testing.assert_array_equal(
            np.asarray(restored["layers"]["attn"]["srf"][0][k]),
            np.asarray(old[k]))
    # root-level srf params (no path prefix) alias too
    mgr.save(2, {"srf": old}, blocking=True)
    restored2, _, _ = mgr.restore({"srf": pipe.init(jax.random.PRNGKey(6))},
                                  step=2)
    for k in old:
        np.testing.assert_array_equal(np.asarray(restored2["srf"][0][k]),
                                      np.asarray(old[k]))


def test_phi_scalar_accepts_registered_custom_nonlinearity():
    _ensure_test_registrations()
    pipe = spinner.single("circulant", m=32, n=16)
    params = pipe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16)) * 0.2
    z = features.phi_scalar(pipe, params, x, "tanh_test")
    a = pipe.materialize(params).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(jnp.tanh(x @ a.T) * 32 ** -0.5),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(KeyError, match="scalar pointwise"):
        features.phi_scalar(pipe, params, x, "cos_sin")


def test_specs_are_zero_leaf_pytrees_and_static():
    pipe = spinner.hd_chain("circulant", n=8, m=8, depth=2, f="relu")
    assert jax.tree_util.tree_leaves(pipe) == []
    assert jax.tree_util.tree_leaves(SpinnerBlock()) == []

    calls = []

    @jax.jit
    def emb(p, params, x):          # pipeline as a (static) jit argument
        calls.append(1)
        return p.apply(params, x)

    params = pipe.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, 8)) * 0.1
    emb(pipe, params, x)
    emb(pipe, params, x)
    assert len(calls) == 1          # retrace only on new spec


# ---------------------------------------------------------------------------
# registries: extension points
# ---------------------------------------------------------------------------

def _ensure_test_registrations():
    if "diag_test" not in spinner.registered_kinds():
        spinner.register_kind(KindDef(
            name="diag_test",
            init=lambda rng, m, n, r=1, ldr_nnz=4, dtype=jnp.float32:
                {"g": jax.random.normal(rng, (n,), dtype)},
            matvec=lambda params, x, m: x * params["g"],
            materialize=lambda params, m, n: jnp.diag(params["g"]),
            budget=lambda m, n, r: n,
            storage=lambda m, n, r: n,
            flops=lambda m, n, r: float(n)))
    if "tanh_test" not in spinner.registered_nonlinearities():
        spinner.register_nonlinearity(Nonlinearity(
            "tanh_test", lambda y, sq: jnp.tanh(y)))


def test_custom_kind_and_nonlinearity_in_pipeline():
    _ensure_test_registrations()
    pipe = spinner.chain([SpinnerBlock("circulant", 16, 16),
                          SpinnerBlock("diag_test", 16, 16, use_hd=False)],
                         f="tanh_test")
    params = pipe.init(jax.random.PRNGKey(0))
    assert pipe.budget == 16 + 16 and pipe.out_dim == 16
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16)) * 0.2
    y = pipe.apply(params, x, out_scale=0.5)
    a = pipe.materialize(params).astype(jnp.float32)
    yo = jnp.tanh(x @ a.T) * 0.5
    np.testing.assert_allclose(np.asarray(y), np.asarray(yo),
                               rtol=1e-4, atol=1e-4)


def test_custom_kind_gets_coherence_diagnostics():
    _ensure_test_registrations()
    blk = SpinnerBlock("diag_test", 8, 8, use_hd=False)
    st = coherence.block_stats(blk, blk.init(jax.random.PRNGKey(0)))
    # diag rows touch a single Gaussian: trivial coherence graphs, and NOT
    # row-normalized in the Def-1 sense (zero off-diagonal P_i columns)
    assert st["budget_t"] == 8.0 and st["chi"] <= 1.0
    assert st["mu_tilde"] == 0.0 and st["normalized"] == 0.0


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        spinner.register_kind(spinner.kind_def("circulant"))
    with pytest.raises(ValueError, match="already registered"):
        spinner.register_nonlinearity(spinner.nonlinearity("relu"))


# ---------------------------------------------------------------------------
# validation, accounting, diagnostics
# ---------------------------------------------------------------------------

def test_chain_dim_mismatch_rejected():
    with pytest.raises(ValueError, match="chain mismatch"):
        SpinnerPipeline((SpinnerBlock("circulant", 32, 16),
                         SpinnerBlock("circulant", 16, 64)))


def test_unknown_kind_and_f_rejected():
    with pytest.raises(ValueError, match="unknown spinner kind"):
        SpinnerBlock("nope", 8, 8)
    with pytest.raises(ValueError, match="unknown nonlinearity"):
        spinner.single("circulant", m=8, n=8, f="nope")


def test_multiblock_rejects_bare_dict_params():
    pipe = spinner.hd_chain("circulant", n=8, m=8, depth=2)
    with pytest.raises(ValueError, match="param"):
        pipe.apply(pipe.init(jax.random.PRNGKey(0))[0], jnp.ones((1, 8)))


def test_accounting_sums_blocks():
    pipe = spinner.hd_chain("circulant", n=16, m=32, depth=3)
    blocks = pipe.blocks
    assert pipe.budget == sum(b.budget for b in blocks)
    assert pipe.storage == sum(b.storage for b in blocks)
    assert pipe.flops == sum(b.flops for b in blocks)
    assert pipe.with_f("cos_sin").out_dim == 2 * pipe.m_out
    # per-block HD storage: 2n signs each
    assert all(b.storage == b.budget + 2 * b.n for b in blocks)


def test_per_block_row_moments_and_coherence():
    pipe = spinner.hd_chain("circulant", n=8, m=8, depth=2)
    params = pipe.init(jax.random.PRNGKey(0))
    moments = pipe.row_gaussianity_moments(params)
    assert len(moments) == 2
    for mean, var in moments:
        assert mean.shape == (8,) and var.shape == (8,)
    stats = coherence.pipeline_stats(pipe, params)
    assert len(stats) == 2
    assert all(s["chi"] <= 3 for s in stats)        # circulant: Sec 2.2
    assert all(s["mu_tilde"] < 1e-6 for s in stats)
    with pytest.raises(ValueError, match="per-block"):
        coherence.pipeline_stats(pipe, params[:1])


# ---------------------------------------------------------------------------
# spinner_plan dtype cache key (VMEM satellite)
# ---------------------------------------------------------------------------

def test_spinner_plan_dtype_separates_cache_entries():
    n, m = 128, 8192
    kw = dict(use_hd=True, epilogue="identity")
    f32 = kops.spinner_plan("circulant", n, m, dtype=jnp.float32, **kw)
    b16 = kops.spinner_plan("circulant", n, m, dtype=jnp.bfloat16, **kw)
    # bf16 x/out tiles are half the bytes (compute scratch stays f32):
    # its plan must be at least as large, and at this (small n, big m)
    # shape strictly larger.
    assert b16[0] * b16[1] > f32[0] * f32[1]
    f32_bytes = kops._spinner_vmem_bytes("circulant", n, m, f32[0],
                                         min(f32[1], m), True,
                                         "identity", 4)
    assert f32_bytes <= kops._VMEM_BUDGET
    b16_as_f32 = kops._spinner_vmem_bytes("circulant", n, m, b16[0],
                                          min(b16[1], m), True,
                                          "identity", 4)
    assert b16_as_f32 > kops._VMEM_BUDGET    # the shared-plan bug this fixes


# ---------------------------------------------------------------------------
# seeded (zero-storage) pipelines
# ---------------------------------------------------------------------------

def test_seeded_pipeline_matches_dense_oracle():
    """seeded=True: params are one uint32 per block, yet the pipeline's
    output matches the dense product of the regenerated matrices (the
    oracle materializes through the same generator)."""
    pipe = spinner.hd_chain("circulant", n=16, m=24, depth=2, seeded=True)
    params = pipe.init(jax.random.PRNGKey(0))
    assert all(set(p) == {"seed"} for p in params)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 16)) * 0.05
    y = pipe.apply(params, x, y_scale=0.7, out_scale=1.3)
    yo = _oracle(pipe, params, x, y_scale=0.7, out_scale=1.3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yo),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kind", KINDS)
def test_seeded_single_bitmatches_materialized_twin(kind):
    """A seeded block applied == the SAME pipeline with the generator-
    oracle params materialized up front, bit for bit, for every kind."""
    from repro.kernels import seedgen
    pipe_s = spinner.single(kind, m=96, n=64, seeded=True)
    pipe_m = spinner.single(kind, m=96, n=64)
    params_s = pipe_s.init(jax.random.PRNGKey(0))
    oracle = (seedgen.seeded_params(kind, 64, 96, params_s[0]["seed"]),)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 64)) * 0.1
    np.testing.assert_array_equal(np.asarray(pipe_s.apply(params_s, x)),
                                  np.asarray(pipe_m.apply(oracle, x)))


def test_seeded_storage_is_o1():
    """Acceptance: seeded storage is O(1) in (n, m) — one scalar per
    block — while the dense twin grows with the matrix."""
    big = spinner.hd_chain("circulant", n=512, m=2048, depth=2, seeded=True)
    small = spinner.hd_chain("circulant", n=16, m=32, depth=2, seeded=True)
    assert big.storage == small.storage == 2
    assert spinner.hd_chain("circulant", n=512, m=2048, depth=2).storage \
        > 1000
    params = big.init(jax.random.PRNGKey(0))
    for p in params:
        assert p["seed"].shape == () and p["seed"].dtype == jnp.uint32


def test_seeded_config_roundtrip_and_apply_identical():
    pipe = spinner.hd_chain("toeplitz", n=16, m=24, depth=2, seeded=True)
    pipe2 = spinner.loads(spinner.dumps(pipe))
    assert pipe2 == pipe and all(b.seeded for b in pipe2.blocks)
    params = pipe.init(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 16)) * 0.1
    np.testing.assert_array_equal(np.asarray(pipe.apply(params, x)),
                                  np.asarray(pipe2.apply(params, x)))


def test_seeded_rejects_unregenerable_kind():
    """Custom registered kinds have no positional generator; seeded mode
    must refuse them at construction, not fail at dispatch."""
    _ensure_test_registrations()
    with pytest.raises(ValueError, match="seeded"):
        SpinnerBlock("diag_test", 8, 8, seeded=True)


def test_seeded_row_moments_regenerate():
    """Gaussianity diagnostics work on seeded blocks by regenerating the
    oracle params — moments match the materialized twin exactly."""
    from repro.kernels import seedgen
    blk = SpinnerBlock("circulant", 48, 32, seeded=True)
    params = blk.init(jax.random.PRNGKey(4))
    mean_s, var_s = blk.row_gaussianity_moments(params)
    twin = SpinnerBlock("circulant", 48, 32)
    oracle = seedgen.seeded_params("circulant", 32, 48, params["seed"])
    mean_m, var_m = twin.row_gaussianity_moments(oracle)
    np.testing.assert_array_equal(np.asarray(mean_s), np.asarray(mean_m))
    np.testing.assert_array_equal(np.asarray(var_s), np.asarray(var_m))
