"""Cross-engine parity matrix: the paged engine vs the ``serving.legacy``
per-slot oracle, over EVERY tiny config in ``configs/registry``.

Greedy cells must BIT-MATCH the legacy engine for >= 8 concurrent
mixed-length requests — continuous batching, chunked prefill, paged
gathers, per-request encoder memories and hybrid attn+SSM fusion may
change how the work is scheduled, never what tokens come out.
Temperature cells pin sampling determinism twice over: two
identically-seeded paged runs are bit-identical (and a different seed
actually changes something somewhere — the sampler is not a disguised
argmax), AND the paged engine bit-matches the legacy oracle at
temperature > 0. The latter only holds because both engines derive
per-token noise statelessly from ``(base_key, uid, position)``
(``sampler.sample_stateless``) — an engine-side RNG would make sampled
tokens depend on batch composition and admission order, which differ
between the two engines by construction.

MoE archs run with a generous ``moe_capacity_factor``: capacity drops
are batch-composition-dependent BY DESIGN (tokens compete per group for
expert slots), so a tight factor would compare drop policies, not
engines.

The big cells (duplicate family representatives and the widest configs)
are marked ``slow``; one representative of every pool plan stays fast.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import frontends
from repro.models import transformer as T
from repro.serving import Engine, Request, SchedConfig


def _legacy():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.serving import legacy
    return legacy


def _cfg(arch, **over):
    kw = {"n_layers": 2}
    if registry.get(arch).is_moe:
        kw["moe_capacity_factor"] = 8.0
    kw.update(over)
    return registry.reduced(arch, **kw)


def _requests(cfg, n, seed=0, temperature=0.0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        enc = (frontends.synthetic_audio_features(rng, cfg)
               if cfg.is_encdec else None)
        out.append(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab,
                                int(rng.integers(2, 20))).astype(np.int32),
            max_new=int(rng.integers(3, 7)),
            temperature=temperature, enc_emb=enc))
    return out


def _drive(eng, reqs):
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    for r in done:
        # lifecycle sanity rides along on every parity cell: stamps are
        # monotonic (perf_counter), so ordering must hold exactly — even
        # for requests finishing at prefill (t_first == t_done)
        assert r.t_submit <= r.t_first <= r.t_done, \
            (r.uid, r.t_submit, r.t_first, r.t_done)
        if r.trace is not None:                  # legacy engine: no trace
            assert r.trace.monotonic(), r.trace.events
            assert r.trace.count("done") == 1
    return {r.uid: r.out_tokens for r in done}


# the fast set keeps one representative per pool plan (kv, srf, ssd,
# hybrid, enc-dec, mla, moe, vlm); same-family duplicates ride as slow
_FAST = {"qwen3-4b", "mamba2-2.7b", "hymba-1.5b", "seamless-m4t-large-v2",
         "deepseek-v2-lite-16b", "qwen2-vl-2b"}

CELLS = [pytest.param(arch, marks=() if arch in _FAST
                      else (pytest.mark.slow,))
         for arch in registry.ARCHS]


@pytest.mark.parametrize("arch", CELLS)
def test_greedy_bitmatch_legacy(arch):
    cfg = _cfg(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    n = 8
    paged = _drive(Engine(cfg, params, batch_slots=4, max_len=64),
                   _requests(cfg, n))
    legacy = _drive(_legacy().Engine(cfg, params, batch_slots=4, max_len=64),
                    _requests(cfg, n))
    assert len(paged) == n
    assert paged == legacy


@pytest.mark.parametrize("arch", CELLS)
def test_seeded_sampling_deterministic(arch):
    cfg = _cfg(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    n = 8

    def run(seed):
        return _drive(Engine(cfg, params, batch_slots=4, max_len=64,
                             seed=seed),
                      _requests(cfg, n, temperature=0.9))
    a, b, c = run(7), run(7), run(8)
    assert len(a) == n
    assert a == b                                # same seed: bit-identical
    assert all(0 <= t < cfg.vocab for toks in a.values() for t in toks)
    assert c != a or cfg.vocab <= 2              # the seed is actually live


@pytest.mark.parametrize("arch", CELLS)
def test_sampled_bitmatch_legacy(arch):
    """temperature > 0 cells: stateless per-request sampling keys make the
    sampled stream a pure function of (base_key, uid, token index), so
    the paged engine must BIT-MATCH the legacy per-slot oracle even
    though the two engines batch, schedule and pad completely
    differently. This is the regression test for the engine-wide
    ``split(self._rng)`` bug, where sampled tokens depended on batch
    composition and admission order."""
    cfg = _cfg(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    n = 8
    paged = _drive(Engine(cfg, params, batch_slots=4, max_len=64, seed=5),
                   _requests(cfg, n, temperature=0.8))
    legacy = _drive(_legacy().Engine(cfg, params, batch_slots=4, max_len=64,
                                     seed=5),
                    _requests(cfg, n, temperature=0.8))
    assert len(paged) == n
    assert paged == legacy


@pytest.mark.parametrize("arch", ["hymba-1.5b", "seamless-m4t-large-v2"])
def test_new_families_16_concurrent_bitmatch(arch):
    """Acceptance: the hybrid and enc-dec tiny variants serve >= 16
    concurrent mixed-length requests through the paged engine and
    bit-match the legacy oracle's greedy decode."""
    cfg = _cfg(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    n = 16
    eng = Engine(cfg, params, batch_slots=8, max_len=64)
    paged = _drive(eng, _requests(cfg, n, seed=3))
    legacy = _drive(_legacy().Engine(cfg, params, batch_slots=8, max_len=64),
                    _requests(cfg, n, seed=3))
    assert len(paged) == n
    assert paged == legacy
    assert eng.sched.alloc.used_pages == 0       # every page returned
    assert eng.free_slots == eng.usable_slots    # every slot returned


def test_hybrid_preemption_restores_both_domains():
    """Tight paged pool forces eviction of hybrid sequences mid-decode;
    the copy-on-preempt snapshot must carry BOTH the kv pages and the ssd
    slot state, so swap-in reproduces the unconstrained outputs exactly."""
    cfg = _cfg("hymba-1.5b")
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 3).astype(np.int32)
               for _ in range(4)]

    def drive(sched):
        eng = Engine(cfg, params, batch_slots=4, max_len=16, sched=sched)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p.copy(), max_new=10))
        done = eng.run()
        return {r.uid: r.out_tokens for r in done}, eng.stats["preemptions"]

    tight = SchedConfig(max_batch=4, prefill_batch=2, prefill_chunk=4,
                        page_size=4, num_pages=9, table_width=4)
    roomy = SchedConfig(max_batch=4, prefill_batch=2, prefill_chunk=4,
                        page_size=4, num_pages=33, table_width=4)
    out_tight, n_pre = drive(tight)
    out_roomy, _ = drive(roomy)
    assert n_pre > 0, "pool was not tight enough to force preemption"
    assert out_tight == out_roomy


@pytest.mark.parametrize("arch,over", [
    ("mamba2-2.7b", {}),
    ("qwen3-4b", {"attn_impl": "srf"}),
    ("hymba-1.5b", {}),
    ("seamless-m4t-large-v2", {}),
], ids=["ssd", "srf", "hybrid", "encdec"])
def test_constant_state_zeroed_on_reuse(arch, over):
    """Regression for the PR 4 bug: constant-state slots are accumulators,
    so a slot re-issued to a later request must start from zero. Two
    waves through the SAME engine (slots reused) must match fresh-engine
    outputs for the second wave."""
    cfg = _cfg(arch, **over)
    params = T.init(jax.random.PRNGKey(0), cfg)
    wave1 = _requests(cfg, 6, seed=1)
    wave2 = _requests(cfg, 6, seed=2)

    eng = Engine(cfg, params, batch_slots=4, max_len=64)
    _drive(eng, wave1)
    got = _drive(eng, wave2)                     # reuses freed slots

    fresh = Engine(cfg, params, batch_slots=4, max_len=64)
    want = _drive(fresh, _requests(cfg, 6, seed=2))
    assert got == want
