"""SRF attention: softmax-kernel approximation quality + exact state algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import srf_attention as A

# These tests predate the SpinnerPipeline API and deliberately keep the
# deprecated repro.core.pmodel shim as their independent oracle (the shim
# is pinned bit-identical, which is what makes it a good comparison
# target). pytest.ini escalates our own DeprecationWarnings to errors
# suite-wide; these shim-test modules are the sanctioned exception.
pytestmark = [
    pytest.mark.filterwarnings(
        "ignore:repro.core.pmodel:DeprecationWarning"),
    pytest.mark.filterwarnings(
        "ignore:passing \\w+ here is deprecated:DeprecationWarning"),
]



def _qkv(key, b=2, h=2, l=64, d=32, scale=0.5):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, l, d)) * scale
    k = jax.random.normal(ks[1], (b, h, l, d)) * scale
    v = jax.random.normal(ks[2], (b, h, l, d))
    return q, k, v


@pytest.mark.parametrize("kind", ["circulant", "toeplitz", "unstructured"])
def test_srf_approximates_softmax(kind):
    cfg = A.SRFConfig(kind=kind, n_features=512, head_dim=32, chunk=16)
    params = A.init(jax.random.PRNGKey(0), cfg, n_kv_heads=2)
    q, k, v = _qkv(jax.random.PRNGKey(1))
    pq = A.feature_map(cfg, params, q, True)
    pk = A.feature_map(cfg, params, k, False)
    out = A.attention_causal(cfg, pq, pk, v)
    refo = A.reference_softmax(q, k, v, causal=True)
    corr = float(jnp.corrcoef(out.ravel(), refo.ravel())[0, 1])
    assert corr > 0.9, corr


def test_causal_equals_unchunked():
    """Chunked scan == direct masked computation (pure algebra, no approx)."""
    cfg = A.SRFConfig(kind="circulant", n_features=64, head_dim=32, chunk=8)
    params = A.init(jax.random.PRNGKey(0), cfg, 1)
    q, k, v = _qkv(jax.random.PRNGKey(2), b=1, h=1, l=24, d=32)
    pq = A.feature_map(cfg, params, q, True)
    pk = A.feature_map(cfg, params, k, False)
    out = A.attention_causal(cfg, pq, pk, v)
    # direct O(L^2) masked linear attention
    attn = jnp.einsum("bhim,bhjm->bhij", pq, pk)
    tri = jnp.tril(jnp.ones((24, 24)))
    attn = attn * tri
    num = jnp.einsum("bhij,bhjd->bhid", attn, v)
    den = attn.sum(-1)[..., None]
    ref = num / (den + 1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3,
                               atol=1e-4)


def test_decode_chain_equals_causal():
    cfg = A.SRFConfig(kind="circulant", n_features=64, head_dim=32, chunk=8)
    params = A.init(jax.random.PRNGKey(0), cfg, 2)
    q, k, v = _qkv(jax.random.PRNGKey(3), b=2, h=2, l=16, d=32)
    pq = A.feature_map(cfg, params, q, True)
    pk = A.feature_map(cfg, params, k, False)
    full = A.attention_causal(cfg, pq, pk, v)
    s, z = A.prefill_state(pk[:, :, :12], v[:, :, :12])
    state = (s, z)
    outs = []
    for t in range(12, 16):
        state, o = A.decode_step(state, pq[:, :, t:t + 1], pk[:, :, t:t + 1],
                                 v[:, :, t:t + 1])
        outs.append(o)
    dec = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, 12:]),
                               rtol=2e-3, atol=2e-4)


def test_state_size_is_sequence_free():
    """The paper's space claim for serving: state does not grow with L."""
    cfg = A.SRFConfig(kind="circulant", n_features=64, head_dim=32)
    params = A.init(jax.random.PRNGKey(0), cfg, 1)
    for l in [8, 64]:
        q, k, v = _qkv(jax.random.PRNGKey(4), b=1, h=1, l=l, d=32)
        pk = A.feature_map(cfg, params, k, False)
        s, z = A.prefill_state(pk, v)
        assert s.shape == (1, 1, 64, 32) and z.shape == (1, 1, 64)


def test_budget_knob_changes_feature_quality():
    """ldr with larger r (bigger budget) should not be worse than r=1 on
    average; smoke-check it runs and produces finite features."""
    for r in [1, 4]:
        cfg = A.SRFConfig(kind="ldr", n_features=64, head_dim=32, r=r)
        params = A.init(jax.random.PRNGKey(0), cfg, 1)
        q, _, _ = _qkv(jax.random.PRNGKey(5), b=1, h=1, l=8, d=32)
        pq = A.feature_map(cfg, params, q, True)
        assert bool(jnp.all(jnp.isfinite(pq)))


def test_phi_softmax_pos_stabilized_large_norm_finite():
    """Regression: stabilize=True must stay finite (and match the shifted
    closed form) for large-norm inputs where raw exp(y - ||x||^2/2)
    under/overflows f32 — the SRF query path depends on this."""
    import numpy as np
    from repro.core import features, pmodel
    from repro.core.pmodel import PModelSpec

    spec = PModelSpec(kind="circulant", m=128, n=64)
    params = pmodel.init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64)) * 3.0  # sq ~ 290
    phi = features.phi_softmax_pos(spec, params, x, stabilize=True)
    assert np.isfinite(np.asarray(phi)).all()
    y = pmodel.project(spec, params, x)
    z = y - 0.5 * jnp.sum(x * x, -1, keepdims=True)
    z = z - jnp.max(z, -1, keepdims=True)
    ref = jnp.exp(z) / jnp.sqrt(jnp.asarray(spec.m, jnp.float32))
    np.testing.assert_allclose(np.asarray(phi), np.asarray(ref),
                               rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# seeded mode: zero-storage projections + per-request embed seeds
# ---------------------------------------------------------------------------

def test_seeded_srf_approximates_softmax():
    """Zero-storage projections are the same random features — the
    softmax-approximation quality bar holds unchanged."""
    cfg = A.SRFConfig(kind="circulant", n_features=512, head_dim=32,
                      chunk=16, seeded=True)
    params = A.init(jax.random.PRNGKey(0), cfg, n_kv_heads=2)
    assert all(set(p) == {"seed"} for p in params)
    q, k, v = _qkv(jax.random.PRNGKey(1))
    pq = A.feature_map(cfg, params, q, True)
    pk = A.feature_map(cfg, params, k, False)
    out = A.attention_causal(cfg, pq, pk, v)
    refo = A.reference_softmax(q, k, v, causal=True)
    corr = float(jnp.corrcoef(out.ravel(), refo.ravel())[0, 1])
    assert corr > 0.9, corr


def test_embed_seed_zero_is_base_projection():
    """embed_seed 0 is the sentinel for 'base projection': a batch of
    zeros must be BIT-identical to calling without embed_seeds (that is
    what lets mixed personalized/base batches share one jit program)."""
    cfg = A.SRFConfig(kind="circulant", n_features=128, head_dim=32,
                      chunk=16, seeded=True)
    params = A.init(jax.random.PRNGKey(0), cfg, n_kv_heads=2)
    q, _, _ = _qkv(jax.random.PRNGKey(1))
    base = A.feature_map(cfg, params, q, True)
    zeros = A.feature_map(cfg, params, q, True,
                          embed_seeds=jnp.zeros((q.shape[0],), jnp.uint32))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(zeros))


def test_embed_seed_personalizes_per_request_batch_invariant():
    """Row i's features depend ONLY on its own embed seed: changing a
    neighbor's seed (or the batch composition) never changes row i, and a
    nonzero seed actually produces a different projection."""
    cfg = A.SRFConfig(kind="circulant", n_features=128, head_dim=32,
                      chunk=16, seeded=True)
    params = A.init(jax.random.PRNGKey(0), cfg, n_kv_heads=2)
    q, _, _ = _qkv(jax.random.PRNGKey(1))           # (2, 2, 64, 32)
    base = A.feature_map(cfg, params, q, True)
    e1 = jnp.asarray([5, 0], jnp.uint32)
    e2 = jnp.asarray([5, 9], jnp.uint32)
    p1 = A.feature_map(cfg, params, q, True, embed_seeds=e1)
    p2 = A.feature_map(cfg, params, q, True, embed_seeds=e2)
    # row 0 identical across batches; row 1 flips base -> personalized
    np.testing.assert_array_equal(np.asarray(p1[0]), np.asarray(p2[0]))
    np.testing.assert_array_equal(np.asarray(p1[1]), np.asarray(base[1]))
    assert not np.allclose(np.asarray(p1[0]), np.asarray(base[0]))
    assert not np.allclose(np.asarray(p2[1]), np.asarray(base[1]))
    # batch-1 call reproduces the same personalized row bit-for-bit
    solo = A.feature_map(cfg, params, q[:1], True,
                         embed_seeds=jnp.asarray([5], jnp.uint32))
    np.testing.assert_array_equal(np.asarray(solo[0]), np.asarray(p1[0]))


def test_embed_seeds_require_seeded_cfg():
    cfg = A.SRFConfig(kind="circulant", n_features=128, head_dim=32,
                      chunk=16)
    params = A.init(jax.random.PRNGKey(0), cfg, n_kv_heads=2)
    q, _, _ = _qkv(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="seeded"):
        A.feature_map(cfg, params, q, True,
                      embed_seeds=jnp.zeros((2,), jnp.uint32))
