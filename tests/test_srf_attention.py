"""SRF attention: softmax-kernel approximation quality + exact state algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import srf_attention as A

# These tests predate the SpinnerPipeline API and deliberately keep the
# deprecated repro.core.pmodel shim as their independent oracle (the shim
# is pinned bit-identical, which is what makes it a good comparison
# target). pytest.ini escalates our own DeprecationWarnings to errors
# suite-wide; these shim-test modules are the sanctioned exception.
pytestmark = [
    pytest.mark.filterwarnings(
        "ignore:repro.core.pmodel:DeprecationWarning"),
    pytest.mark.filterwarnings(
        "ignore:passing \\w+ here is deprecated:DeprecationWarning"),
]



def _qkv(key, b=2, h=2, l=64, d=32, scale=0.5):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, l, d)) * scale
    k = jax.random.normal(ks[1], (b, h, l, d)) * scale
    v = jax.random.normal(ks[2], (b, h, l, d))
    return q, k, v


@pytest.mark.parametrize("kind", ["circulant", "toeplitz", "unstructured"])
def test_srf_approximates_softmax(kind):
    cfg = A.SRFConfig(kind=kind, n_features=512, head_dim=32, chunk=16)
    params = A.init(jax.random.PRNGKey(0), cfg, n_kv_heads=2)
    q, k, v = _qkv(jax.random.PRNGKey(1))
    pq = A.feature_map(cfg, params, q, True)
    pk = A.feature_map(cfg, params, k, False)
    out = A.attention_causal(cfg, pq, pk, v)
    refo = A.reference_softmax(q, k, v, causal=True)
    corr = float(jnp.corrcoef(out.ravel(), refo.ravel())[0, 1])
    assert corr > 0.9, corr


def test_causal_equals_unchunked():
    """Chunked scan == direct masked computation (pure algebra, no approx)."""
    cfg = A.SRFConfig(kind="circulant", n_features=64, head_dim=32, chunk=8)
    params = A.init(jax.random.PRNGKey(0), cfg, 1)
    q, k, v = _qkv(jax.random.PRNGKey(2), b=1, h=1, l=24, d=32)
    pq = A.feature_map(cfg, params, q, True)
    pk = A.feature_map(cfg, params, k, False)
    out = A.attention_causal(cfg, pq, pk, v)
    # direct O(L^2) masked linear attention
    attn = jnp.einsum("bhim,bhjm->bhij", pq, pk)
    tri = jnp.tril(jnp.ones((24, 24)))
    attn = attn * tri
    num = jnp.einsum("bhij,bhjd->bhid", attn, v)
    den = attn.sum(-1)[..., None]
    ref = num / (den + 1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3,
                               atol=1e-4)


def test_decode_chain_equals_causal():
    cfg = A.SRFConfig(kind="circulant", n_features=64, head_dim=32, chunk=8)
    params = A.init(jax.random.PRNGKey(0), cfg, 2)
    q, k, v = _qkv(jax.random.PRNGKey(3), b=2, h=2, l=16, d=32)
    pq = A.feature_map(cfg, params, q, True)
    pk = A.feature_map(cfg, params, k, False)
    full = A.attention_causal(cfg, pq, pk, v)
    s, z = A.prefill_state(pk[:, :, :12], v[:, :, :12])
    state = (s, z)
    outs = []
    for t in range(12, 16):
        state, o = A.decode_step(state, pq[:, :, t:t + 1], pk[:, :, t:t + 1],
                                 v[:, :, t:t + 1])
        outs.append(o)
    dec = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, 12:]),
                               rtol=2e-3, atol=2e-4)


def test_state_size_is_sequence_free():
    """The paper's space claim for serving: state does not grow with L."""
    cfg = A.SRFConfig(kind="circulant", n_features=64, head_dim=32)
    params = A.init(jax.random.PRNGKey(0), cfg, 1)
    for l in [8, 64]:
        q, k, v = _qkv(jax.random.PRNGKey(4), b=1, h=1, l=l, d=32)
        pk = A.feature_map(cfg, params, k, False)
        s, z = A.prefill_state(pk, v)
        assert s.shape == (1, 1, 64, 32) and z.shape == (1, 1, 64)


def test_budget_knob_changes_feature_quality():
    """ldr with larger r (bigger budget) should not be worse than r=1 on
    average; smoke-check it runs and produces finite features."""
    for r in [1, 4]:
        cfg = A.SRFConfig(kind="ldr", n_features=64, head_dim=32, r=r)
        params = A.init(jax.random.PRNGKey(0), cfg, 1)
        q, _, _ = _qkv(jax.random.PRNGKey(5), b=1, h=1, l=8, d=32)
        pq = A.feature_map(cfg, params, q, True)
        assert bool(jnp.all(jnp.isfinite(pq)))


def test_phi_softmax_pos_stabilized_large_norm_finite():
    """Regression: stabilize=True must stay finite (and match the shifted
    closed form) for large-norm inputs where raw exp(y - ||x||^2/2)
    under/overflows f32 — the SRF query path depends on this."""
    import numpy as np
    from repro.core import features, pmodel
    from repro.core.pmodel import PModelSpec

    spec = PModelSpec(kind="circulant", m=128, n=64)
    params = pmodel.init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64)) * 3.0  # sq ~ 290
    phi = features.phi_softmax_pos(spec, params, x, stabilize=True)
    assert np.isfinite(np.asarray(phi)).all()
    y = pmodel.project(spec, params, x)
    z = y - 0.5 * jnp.sum(x * x, -1, keepdims=True)
    z = z - jnp.max(z, -1, keepdims=True)
    ref = jnp.exp(z) / jnp.sqrt(jnp.asarray(spec.m, jnp.float32))
    np.testing.assert_allclose(np.asarray(phi), np.asarray(ref),
                               rtol=1e-5, atol=1e-7)
