"""Perf-regression gate (benchmarks/regress.py): flattening stability,
direction-aware rules, the committed-baseline pass, and the synthetic
slowdown that must fail. The benchmarks tree is not a package under
``PYTHONPATH=src``, so the module is loaded by file path — the same way
``launch/dryrun.py --check-bench`` loads it."""
import importlib.util
import json
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

spec = importlib.util.spec_from_file_location(
    "regress", REPO / "benchmarks" / "regress.py")
regress = importlib.util.module_from_spec(spec)
spec.loader.exec_module(regress)


PAYLOAD = {
    "bench": "toy", "smoke": False, "backend": "cpu",
    "results": [
        {"kind": "circulant", "fused_us": 100.0, "dense_us": 400.0,
         "speedup_vs_dense": 4.0, "match_dense": True},
        {"kind": "toeplitz", "fused_us": 120.0, "dense_us": 360.0,
         "speedup_vs_dense": 3.0, "match_dense": True},
    ],
    "paged": {"tok_s": 50.0, "ttft_ms_p95": 20.0, "tpot_ms_p95": 5.0},
}


# ---------------------------------------------------------------------------
# flattening
# ---------------------------------------------------------------------------

def test_flatten_uses_identity_keys_not_indices():
    cells = regress.flatten_cells(PAYLOAD)
    assert cells["results[kind=circulant].fused_us"] == 100.0
    assert cells["results[kind=circulant].match_dense"] is True
    assert cells["paged.tok_s"] == 50.0
    assert "backend" not in cells and "bench" not in cells
    # row reorder does not move cells (index-keyed flattening would)
    flipped = dict(PAYLOAD, results=list(reversed(PAYLOAD["results"])))
    assert regress.flatten_cells(flipped) == cells


def test_bench_name_distinguishes_smoke():
    assert regress.bench_name({"bench": "serving"}) == "serving"
    assert regress.bench_name({"bench": "serving", "smoke": True}) \
        == "serving_smoke"


def test_rules_direction_aware():
    assert regress.rule_for("paged.tok_s")[0] == "higher"
    assert regress.rule_for("x.speedup_vs_dense")[0] == "higher"
    assert regress.rule_for("shared_prefix.prefill_reduction_x")[0] \
        == "higher"
    assert regress.rule_for("paged.ttft_ms_p95")[0] == "lower"
    assert regress.rule_for("r.us_per_tok")[0] == "lower"
    assert regress.rule_for("r.match_dense")[0] == "truthy"
    assert regress.rule_for("chaos_smoke.ok")[0] == "truthy"
    assert regress.rule_for("x.conservation_holds")[0] == "truthy"
    assert regress.rule_for("failover.trace.chain_uid_correlated")[0] \
        == "truthy"
    assert regress.rule_for("concurrency") is None   # counts ungated
    assert regress.rule_for("results[k].storage_floats") is None


# ---------------------------------------------------------------------------
# history + baseline
# ---------------------------------------------------------------------------

def test_record_and_load_history_roundtrip(tmp_path):
    hist = tmp_path / "h.jsonl"
    assert regress.record(PAYLOAD, str(hist)) == "toy"
    regress.record(PAYLOAD, str(hist))
    loaded = regress.load_history(str(hist))
    assert list(loaded) == ["toy"] and len(loaded["toy"]) == 2
    assert loaded["toy"][0]["paged.tok_s"] == 50.0


def test_baseline_median_and_bool_any():
    base = regress.baseline([{"a": 1.0, "ok": True},
                             {"a": 3.0, "ok": False},
                             {"a": 100.0}])
    assert base["a"] == 3.0          # median, robust to one outlier
    assert base["ok"] is True        # an invariant that ever held, holds


# ---------------------------------------------------------------------------
# the gate
# ---------------------------------------------------------------------------

def _history_of(payload, n=1):
    return {regress.bench_name(payload):
            [regress.flatten_cells(payload)] * n}


def test_gate_passes_on_identical_run():
    assert regress.check_payload(PAYLOAD, _history_of(PAYLOAD)) == []


def test_gate_passes_within_tolerance():
    jittered = json.loads(json.dumps(PAYLOAD))
    jittered["paged"]["tok_s"] = 30.0          # 0.6x: above the 1/2 floor
    jittered["paged"]["ttft_ms_p95"] = 35.0    # 1.75x: under the 2x bar
    assert regress.check_payload(jittered, _history_of(PAYLOAD)) == []


def test_gate_fails_on_synthetic_slowdown():
    degraded = json.loads(json.dumps(PAYLOAD))
    degraded["paged"]["tok_s"] = 10.0          # 5x throughput collapse
    degraded["paged"]["ttft_ms_p95"] = 200.0   # 10x latency blowup
    degraded["results"][0]["match_dense"] = False
    bad = regress.check_payload(degraded, _history_of(PAYLOAD))
    assert len(bad) == 3
    joined = "\n".join(bad)
    assert "paged.tok_s" in joined and "throughput regression" in joined
    assert "paged.ttft_ms_p95" in joined and "latency regression" in joined
    assert "match_dense" in joined and "falsy" in joined


def test_gate_skips_unknown_bench_and_new_cells():
    assert regress.check_payload(PAYLOAD, {}) == []    # no history yet
    grown = json.loads(json.dumps(PAYLOAD))
    grown["paged"]["req_s"] = 1.0              # new cell, no baseline
    assert regress.check_payload(grown, _history_of(PAYLOAD)) == []


def test_check_files_end_to_end(tmp_path):
    hist = tmp_path / "BENCH_history.jsonl"
    good = tmp_path / "BENCH_toy.json"
    good.write_text(json.dumps(PAYLOAD))
    regress.record(PAYLOAD, str(hist))
    assert regress.check_files([str(good)], str(hist)) == []
    degraded = json.loads(json.dumps(PAYLOAD))
    degraded["paged"]["tok_s"] = 1.0
    good.write_text(json.dumps(degraded))
    bad = regress.check_files([str(good)], str(hist))
    assert bad and "toy:paged.tok_s" in bad[0]


# ---------------------------------------------------------------------------
# the committed baseline: what CI actually gates on
# ---------------------------------------------------------------------------

def test_committed_payloads_pass_committed_history():
    """The repo's own BENCH_*.json must pass against the repo's own
    BENCH_history.jsonl — this is exactly what ``launch/dryrun.py
    --check-bench`` (and ``benchmarks/run.py --check``) run in CI."""
    hist = REPO / "BENCH_history.jsonl"
    assert hist.exists(), "committed BENCH_history.jsonl is missing"
    paths = regress.discover(str(REPO))
    assert len(paths) >= 4, "committed BENCH payloads went missing"
    bad = regress.check_files(paths, str(hist))
    assert bad == [], "committed payloads regress vs committed history:" \
        "\n" + "\n".join(bad)


def test_committed_history_covers_key_cells():
    hist = regress.load_history(str(REPO / "BENCH_history.jsonl"))
    serving = regress.baseline(hist["serving"])
    gated = [c for c in serving if regress.rule_for(c)]
    # the headline serving cells the issue names are actually gated
    assert any(c.endswith(".tok_s") for c in gated)
    assert any(c.endswith("ttft_ms_p95") for c in gated)
    assert any(c.endswith("tpot_ms_p95") for c in gated)
    assert any("prefill_reduction_x" in c for c in gated)
    assert any(c.endswith("req_s") for c in gated)


def test_dryrun_check_bench_entrypoint(capsys):
    """--check-bench loads regress.py by file path and gates the
    committed payloads; it must exit 0 on the committed tree."""
    import os
    import sys
    sys.modules.pop("repro.launch.dryrun", None)
    prev_flags = os.environ.get("XLA_FLAGS")
    os.environ["REPRO_DRYRUN_DEVICES"] = "1"
    try:
        from repro.launch import dryrun
        code = dryrun.check_bench(str(REPO))
    finally:
        os.environ.pop("REPRO_DRYRUN_DEVICES", None)
        # the dryrun module sets XLA_FLAGS at import; jax is long since
        # initialized here (inert in-process) but restore it anyway
        if prev_flags is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = prev_flags
    out = capsys.readouterr().out
    assert code == 0
    assert "[regress] PASS" in out
