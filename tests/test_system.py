"""End-to-end behaviour tests for the paper's system.

The headline claims, executed:
1. Structured embeddings estimate kernels with << mn randomness (quality).
2. The structured pipeline is asymptotically cheaper (flops/storage model).
3. Serving with the paper's SRF state replaces the O(L) KV cache (space
   claim at serving time).
4. The dry-run analysis path works end to end in-process.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimators as E
from repro.core import pmodel as P
from repro.core import structured as S

# These tests predate the SpinnerPipeline API and deliberately keep the
# deprecated repro.core.pmodel shim as their independent oracle (the shim
# is pinned bit-identical, which is what makes it a good comparison
# target). pytest.ini escalates our own DeprecationWarnings to errors
# suite-wide; these shim-test modules are the sanctioned exception.
pytestmark = [
    pytest.mark.filterwarnings(
        "ignore:repro.core.pmodel:DeprecationWarning"),
    pytest.mark.filterwarnings(
        "ignore:passing \\w+ here is deprecated:DeprecationWarning"),
]



def test_structured_beats_budget_with_same_quality():
    """Claim: circulant (t=n) achieves error comparable to unstructured
    (t=mn) at equal m — within 2x on mean |err| for the angular kernel."""
    n, m = 64, 256
    v1 = jax.random.normal(jax.random.PRNGKey(1), (n,))
    v1 = v1 / jnp.linalg.norm(v1)
    v2 = jax.random.normal(jax.random.PRNGKey(2), (n,))
    v2 = v2 / jnp.linalg.norm(v2)
    errs = {}
    for kind in ["circulant", "unstructured"]:
        spec = P.PModelSpec(kind=kind, m=m, n=n, use_hd=True)
        mean_err, _ = E.mc_error(jax.random.PRNGKey(3), spec, "heaviside",
                                 v1, v2, n_trials=64)
        errs[kind] = float(mean_err)
    assert errs["circulant"] < 2.0 * errs["unstructured"] + 0.01, errs
    t_circ = S.budget("circulant", m, n)
    t_unst = S.budget("unstructured", m, n)
    assert t_circ * 32 <= t_unst    # 'recycling randomness' is real


def test_flops_and_storage_asymptotics():
    m = n = 4096
    assert S.flops_fast("circulant", m, n) < 0.05 * S.flops_fast(
        "unstructured", m, n)
    assert S.storage_floats("circulant", m, n) * 100 < S.storage_floats(
        "unstructured", m, n)


def test_serving_space_claim():
    """SRF cache bytes are independent of context length; KV cache is not."""
    from repro.configs import registry
    from repro.models import transformer as T

    def cache_bytes(cfg, max_len):
        c = jax.eval_shape(lambda: T.init_serve_cache(cfg, 1, max_len))
        return sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(c))
    full = registry.reduced("qwen3-4b")
    srf = registry.reduced("qwen3-4b", attn_impl="srf")
    assert cache_bytes(full, 4096) > 30 * cache_bytes(full, 128)
    assert cache_bytes(srf, 4096) == cache_bytes(srf, 128)


def test_dryrun_analysis_inprocess():
    from repro.launch import hlo_analysis as H
    from repro.configs import registry, shapes
    from repro.launch import steps
    from repro.optim import adamw
    from repro.models import transformer as T
    cfg = registry.reduced("mistral-nemo-12b")
    params = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw.init(params))
    bspecs = shapes.batch_specs(cfg, 4, 32, training=True)
    fn = steps.make_train_step(cfg)
    compiled = jax.jit(fn).lower(params, opt,
                                 jax.ShapeDtypeStruct((), jnp.int32),
                                 bspecs).compile()
    r = H.analyze(compiled.as_text())
    assert r["flops"] > 0 and r["bytes"] > 0
    assert H.roofline_terms(r)["t_roofline"] > 0
