"""Fault-tolerant serving: chaos recovery matrix + deadline/degradation
behavior.

The core matrix kills replica 1 mid-service with each chaos fault kind
(hard exception, simulated stall, corrupt admission, pool exhaustion)
for one representative arch per multi-domain pool plan {kv, hybrid,
enc-dec}, and asserts the whole fault-tolerance contract at once:

* every submitted request reaches a terminal state exactly once (one
  ``done`` event per uid in the shared registry),
* greedy outputs are bit-identical to an undisturbed single-engine run
  — rescue/replay must not change a single token,
* the scheduler conservation invariants of ``test_scheduler_props``
  hold after EVERY router round, across quarantine and rescue,
* after ``heal()`` + ``revive()`` the replica rejoins, serves new
  requests bit-identically, and no page or slot is leaked.

Chaos cells run with migration disabled: otherwise ordinary pressure
migration quietly drains the starved replica before the stuck detector
can fire (a correct but different recovery path — the matrix pins the
quarantine one).
"""
import numpy as np
import pytest

import jax

from repro.configs import registry
from repro.models import transformer as T
from repro.obs import MetricsRegistry
from repro.serving import (Engine, FTConfig, ReplicaWatchdog, Request,
                           Router, RouterConfig, SchedConfig, Scheduler,
                           plan_for)
from repro.serving import ft as ft_lib
from repro.serving.chaos import ChaosEngine, ChaosError, ChaosPlan

ARCHS = ["qwen3-4b", "hymba-1.5b", "seamless-m4t-large-v2"]
KINDS = ["raise", "hang", "reject", "oom"]
N_REQ = 8
MAX_NEW = 10

_cache = {}


def _setup(arch):
    """Per-arch params, request blueprints, and the undisturbed
    single-engine reference outputs (cached across matrix cells)."""
    if arch in _cache:
        return _cache[arch]
    cfg = registry.reduced(arch, n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    blue = []
    for i in range(N_REQ):
        enc = None
        if cfg.is_encdec:
            from repro.models import frontends
            enc = frontends.synthetic_audio_features(rng, cfg)
        blue.append((rng.integers(1, cfg.vocab,
                                  int(rng.integers(4, 20))).astype(np.int32),
                     enc))
    ref = [Request(uid=i, prompt=p.copy(), max_new=MAX_NEW, enc_emb=e)
           for i, (p, e) in enumerate(blue)]
    eng = Engine(cfg, params, batch_slots=2, max_len=64, seed=0)
    for r in ref:
        eng.submit(r)
    eng.run()
    want = {r.uid: list(r.out_tokens) for r in ref}
    assert all(len(t) == MAX_NEW for t in want.values())
    _cache[arch] = (cfg, params, blue, want)
    return _cache[arch]


def _requests(blue):
    # fresh Request objects per run; prompts copied because replay folds
    # emitted tokens into req.prompt in place
    return [Request(uid=i, prompt=p.copy(), max_new=MAX_NEW, enc_emb=e)
            for i, (p, e) in enumerate(blue)]


def _inner(e):
    return getattr(e, "_eng", e)


def _check_allocators(engines, allow_foreign=False):
    """The test_scheduler_props invariants, per replica. ``allow_foreign``
    tolerates the oom fault's hostage allocations (pages allocated but
    owned by no sequence — by design)."""
    for e in engines:
        sched = _inner(e).sched
        a = sched.alloc
        assert a.free_pages + a.used_pages == a.num_pages - 1
        owned = [p for s in sched.running for p in s.table.pages]
        assert len(owned) == len(set(owned))
        assert 0 not in owned
        if allow_foreign:
            assert set(owned) <= a._allocated
        else:
            assert set(owned) == a._allocated
        for s in sched.waiting:
            assert not s.table.pages and s.slot is None
        if sched.slot_alloc is not None:
            sa = sched.slot_alloc
            assert sa.free_pages + sa.used_pages == sa.num_pages - 1
            slots = [s.slot for s in sched.running if s.slot is not None]
            assert len(slots) == len(set(slots))
            assert 0 not in slots
            if allow_foreign:
                assert set(slots) <= sa._allocated
            else:
                assert set(slots) == sa._allocated


def _check_conservation(reg, engines):
    """Global request conservation across ALL replicas (rescue moves
    requests between schedulers; it must never create or destroy them)."""
    running = sum(len(_inner(e).sched.running) for e in engines)
    waiting = sum(len(_inner(e).sched.waiting) for e in engines)
    v = reg.value_sum
    assert v("sched_submitted_total") + v("sched_adopted_total") == \
        v("sched_finished_total") + v("sched_released_total") + \
        running + waiting


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("kind", KINDS)
def test_chaos_matrix(arch, kind):
    cfg, params, blue, want = _setup(arch)
    reg = MetricsRegistry()
    engines = [Engine(cfg, params, batch_slots=2, max_len=64, seed=i,
                      metrics=reg) for i in range(2)]
    engines[1] = ChaosEngine(engines[1], ChaosPlan(kind, at_step=4))
    router = Router(engines, cfg=RouterConfig(migrate=False), metrics=reg,
                    ft=FTConfig(grace_steps=2, stuck_rounds=3))
    reqs = _requests(blue)
    for r in reqs:
        router.submit(r)

    def on_step(rt):
        _check_allocators(rt.engines, allow_foreign=(kind == "oom"))
        _check_conservation(reg, rt.engines)

    router.run(on_step=on_step)

    # terminal exactly once, served (not failed/shed/timed out)
    assert all(r.done for r in reqs)
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    dones = {}
    for ev in reg.events:
        if ev.get("event") == "done":
            dones[ev["uid"]] = dones.get(ev["uid"], 0) + 1
    assert dones == {i: 1 for i in range(N_REQ)}
    # bit-identical greedy vs the undisturbed single-engine run
    assert {r.uid: list(r.out_tokens) for r in reqs} == want
    # the fault actually took the quarantine path
    assert reg.value_sum("router_quarantined_total") == 1
    assert 1 in router.dead
    assert reg.value_sum("router_rescued_total") + \
        reg.value_sum("router_replayed_total") >= 1
    assert reg.value_sum("router_failed_total") == 0

    # heal the fault, revive via probe, then serve on the healed set
    engines[1].heal()
    assert router.revive(1)
    assert router.dead == set()
    assert reg.value_sum("router_revived_total") == 1
    extra = [Request(uid=100 + i, prompt=blue[i][0].copy(),
                     max_new=MAX_NEW, enc_emb=blue[i][1]) for i in range(2)]
    for r in extra:
        router.submit(r)
    router.run(on_step=lambda rt: _check_allocators(rt.engines))
    assert all(r.done and list(r.out_tokens) == want[i]
               for i, r in enumerate(extra))
    # no page/slot leaked after quarantine + revive
    for e in engines:
        sched = _inner(e).sched
        assert sched.alloc.used_pages == 0
        if sched.slot_alloc is not None:
            assert sched.slot_alloc.used_pages == 0
    _check_conservation(reg, engines)


def test_chaos_sampled_decode_bitmatch():
    """Sampled decode (temperature > 0) survives a mid-decode replica
    kill bit-exactly. Sampling noise is stateless per
    ``(base_key, uid, token index)`` — never engine RNG state — so when
    replicas share a base sampling seed, the rescue replica replays
    exactly the noise the killed replica would have drawn and the
    rescued streams bit-match an undisturbed single-engine run. (The
    old engine-wide ``split(self._rng)`` keying made this impossible:
    replayed tokens depended on how the rescue batch happened to be
    composed.)"""
    cfg, params, blue, _ = _setup("qwen3-4b")

    def sampled_requests():
        return [Request(uid=i, prompt=p.copy(), max_new=MAX_NEW, enc_emb=e,
                        temperature=0.9, top_k=50, top_p=0.95)
                for i, (p, e) in enumerate(blue)]

    ref = sampled_requests()
    eng = Engine(cfg, params, batch_slots=2, max_len=64, seed=0)
    for r in ref:
        eng.submit(r)
    eng.run()
    want = {r.uid: list(r.out_tokens) for r in ref}
    assert any(want[i] != _setup("qwen3-4b")[3][i] for i in want), \
        "sampling produced pure argmax streams; cell is vacuous"

    reg = MetricsRegistry()
    engines = [Engine(cfg, params, batch_slots=2, max_len=64, seed=0,
                      metrics=reg) for _ in range(2)]
    engines[1] = ChaosEngine(engines[1], ChaosPlan("raise", at_step=4))
    router = Router(engines, cfg=RouterConfig(migrate=False), metrics=reg,
                    ft=FTConfig(grace_steps=2, stuck_rounds=3))
    reqs = sampled_requests()
    for r in reqs:
        router.submit(r)
    router.run()

    assert all(r.done for r in reqs)
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    assert {r.uid: list(r.out_tokens) for r in reqs} == want
    # the kill actually happened and rescue actually ran
    assert reg.value_sum("router_quarantined_total") == 1
    assert reg.value_sum("router_rescued_total") + \
        reg.value_sum("router_replayed_total") >= 1
    assert reg.value_sum("router_failed_total") == 0


# ---------------------------------------------------------------------------
# chaos harness
# ---------------------------------------------------------------------------

def test_chaos_plan_from_seed_deterministic():
    a = ChaosPlan.from_seed(7)
    b = ChaosPlan.from_seed(7)
    assert (a.kind, a.at_step) == (b.kind, b.at_step)
    kinds = {ChaosPlan.from_seed(s).kind for s in range(32)}
    assert kinds == {"raise", "hang", "reject", "oom"}
    with pytest.raises(ValueError):
        ChaosPlan("segfault")


def test_chaos_raise_without_ft_propagates():
    """Without ``ft`` the router must NOT swallow replica exceptions —
    pre-FT behavior is preserved exactly."""
    cfg, params, blue, _ = _setup("qwen3-4b")
    engines = [Engine(cfg, params, batch_slots=2, max_len=64, seed=i)
               for i in range(2)]
    engines[1] = ChaosEngine(engines[1], ChaosPlan("raise", at_step=1))
    router = Router(engines)
    for r in _requests(blue):
        router.submit(r)
    with pytest.raises(ChaosError):
        router.run()


# ---------------------------------------------------------------------------
# watchdog (unit: fed synthetic observations, no engines)
# ---------------------------------------------------------------------------

def test_watchdog_flags_slow_replica_vs_peer_median():
    wd = ReplicaWatchdog(3, FTConfig(ema=0.5, threshold=2.0, grace_steps=2))
    verdict = None
    for _ in range(6):
        wd.observe(0, 0.01, True, True)
        wd.observe(1, 0.01, True, True)
        verdict = wd.observe(2, 0.5, True, True)
    assert verdict is not None and "slow" in verdict
    # two replicas: the slow one must still be detectable (peer median,
    # not global median — the global upper median IS the slow replica)
    wd2 = ReplicaWatchdog(2, FTConfig(ema=0.5, threshold=2.0, grace_steps=2))
    verdict = None
    for _ in range(6):
        wd2.observe(0, 0.01, True, True)
        verdict = wd2.observe(1, 0.5, True, True)
    assert verdict is not None and "slow" in verdict


def test_watchdog_stuck_and_reset():
    wd = ReplicaWatchdog(2, FTConfig(stuck_rounds=3))
    assert wd.observe(0, None, False, True) is None
    assert wd.observe(0, None, False, True) is None
    verdict = wd.observe(0, None, False, True)
    assert verdict is not None and "stuck" in verdict
    # progress resets the streak; idle (no work) never counts as stuck
    wd2 = ReplicaWatchdog(2, FTConfig(stuck_rounds=2))
    wd2.observe(0, None, False, True)
    wd2.observe(0, None, True, True)
    assert wd2.observe(0, None, False, True) is None
    assert wd2.observe(1, None, False, False) is None
    assert wd2.observe(1, None, False, False) is None


def test_fold_emitted_prefix_exactly_once_arithmetic():
    req = Request(uid=0, prompt=np.array([1, 2, 3], np.int32), max_new=8)
    req.out_tokens.extend([7, 8, 9])
    hwm = ft_lib.fold_emitted_prefix(req)
    assert hwm == 3
    assert list(req.prompt) == [1, 2, 3, 7, 8, 9]
    assert req.out_tokens == [7, 8, 9]      # never truncated
    # total token budget at finish is unchanged: prompt grew by hwm, the
    # engine's len(out_tokens) >= max_new check still stops at max_new
    assert len(req.prompt) + (req.max_new - hwm) == 3 + req.max_new


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_deadline_expires_overdue_waiting_requests():
    cfg, params, blue, _ = _setup("qwen3-4b")
    eng = Engine(cfg, params, batch_slots=2, max_len=64, seed=0)
    reqs = [Request(uid=i, prompt=blue[i][0].copy(), max_new=MAX_NEW,
                    deadline=(0.0 if i >= 4 else None))
            for i in range(N_REQ)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    # the two admitted immediately ran; the backlog expired while waiting
    assert all(r.done for r in reqs)
    assert [r.finish_reason for r in reqs[:4]] == ["length"] * 4
    assert all(r.finish_reason == "timeout" and r.out_tokens == []
               for r in reqs if r.deadline is not None)
    assert eng.metrics.value_sum("engine_expired_total") == 4
    assert eng.metrics.value_sum("sched_expired_total") == 4
    assert len([r for r in done if r.finish_reason == "length"]) == 4
    # expired requests still satisfy conservation (they count finished)
    _check_conservation(eng.metrics, [eng])


def test_rank_is_deadline_aware_edf():
    plan = plan_for(registry.reduced("qwen3-4b"))
    sched = Scheduler(SchedConfig(max_batch=4, prefill_batch=2,
                                  prefill_chunk=4, page_size=4,
                                  num_pages=13, table_width=4), plan)

    def req(uid, deadline_at=None):
        r = Request(uid=uid, prompt=np.ones(3, np.int32), max_new=2)
        r.deadline_at = deadline_at
        return r

    late = sched.submit(req(0))                  # arrives first, no deadline
    loose = sched.submit(req(1, deadline_at=90.0))
    tight = sched.submit(req(2, deadline_at=10.0))
    order = sorted(sched.waiting, key=sched._rank)
    assert [s.req.uid for s in order] == [2, 1, 0]
    # deadlined work is evicted last (victim order reverses the rank)
    assert sched._rank(tight) < sched._rank(loose) < sched._rank(late)
    # non-deadlined requests keep plain FCFS among themselves
    plain = sched.submit(req(3))
    assert sched._rank(late) < sched._rank(plain)


def test_fits_is_remaining_aware_for_replays():
    plan = plan_for(registry.reduced("qwen3-4b"))
    sched = Scheduler(SchedConfig(page_size=4, num_pages=13, table_width=4),
                      plan)                       # capacity 16 tokens
    req = Request(uid=0, prompt=np.ones(6, np.int32), max_new=8)
    assert sched.fits(req)                        # 6 + 8 <= 16
    req.out_tokens.extend([1, 2, 3, 4])
    ft_lib.fold_emitted_prefix(req)               # prompt now 10 tokens
    # naive accounting would say 10 + 8 = 18 > 16 and reject the rescue;
    # remaining-aware: 10 + (8 - 4) = 14 <= 16
    assert sched.fits(req)


# ---------------------------------------------------------------------------
# graceful degradation + router registry homing
# ---------------------------------------------------------------------------

def test_degraded_sheds_new_requests_then_recovers():
    cfg, params, blue, _ = _setup("qwen3-4b")
    reg = MetricsRegistry()
    # max_len=32 shrinks the pool to 16 pages of 8 per replica, so a
    # 24-request flood genuinely exhausts both replicas for several
    # rounds (the default pool absorbs it and never degrades)
    engines = [Engine(cfg, params, batch_slots=2, max_len=32, seed=i,
                      metrics=reg) for i in range(2)]
    router = Router(engines, metrics=reg, ft=FTConfig(degraded_rounds=2))
    flood = [Request(uid=100 + i, prompt=blue[i % N_REQ][0][:12].copy(),
                     max_new=MAX_NEW) for i in range(24)]
    for r in flood:
        router.submit(r)
    shed = None
    for _ in range(60):
        router.step()
        if router.state == "degraded":
            extra = Request(uid=999, prompt=blue[0][0][:12].copy(),
                            max_new=MAX_NEW)
            assert router.submit(extra) == -1     # reject-new, not evict
            shed = extra
            break
    assert shed is not None, "router never entered degraded state"
    assert shed.done and shed.finish_reason == "shed"
    assert not shed.out_tokens
    assert reg.value_sum("router_shed_total") == 1
    assert reg.value_sum("router_degraded") == 1
    done = router.run()
    # shedding is reject-NEW only: every request already admitted or
    # queued before degradation still finishes normally
    assert len(done) == len(flood)
    assert all(r.finish_reason in ("eos", "length") for r in flood)
    assert router.state == "ok"
    assert reg.value_sum("router_degraded") == 0


def test_router_counters_survive_replica0_quarantine():
    """Satellite: control-plane series must not live in engines[0]'s
    registry slot — kill replica 0 and the router's counters must keep
    counting."""
    cfg, params, blue, want = _setup("qwen3-4b")
    engines = [Engine(cfg, params, batch_slots=2, max_len=64, seed=i)
               for i in range(2)]
    engines[0] = ChaosEngine(engines[0], ChaosPlan("raise", at_step=3))
    router = Router(engines, ft=FTConfig())       # no shared registry
    assert router.metrics is not engines[1].metrics
    assert router.metrics is not _inner(engines[0]).metrics
    reqs = _requests(blue)
    for r in reqs:
        router.submit(r)
    router.run()
    assert all(r.done for r in reqs)
    assert {r.uid: list(r.out_tokens) for r in reqs} == want
    # counters incremented after replica 0 died — in the ROUTER registry
    assert router.metrics.value_sum("router_quarantined_total") == 1
    assert router.metrics.value_sum("router_submitted_total") == N_REQ
    # and none of them leaked into a replica's registry
    snap = engines[1].metrics.snapshot()["counters"]
    assert "router_quarantined_total" not in snap
