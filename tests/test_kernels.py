"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype, epilogue="identity"):
    if dtype == jnp.bfloat16:
        # trig/exp epilogues amplify bf16 pre-activation rounding by the
        # phase/magnitude |y| (~n^1/2); compare with widened tolerance.
        if epilogue in ("cos_sin", "exp"):
            return dict(rtol=5e-2, atol=1.5e-1)
        return dict(rtol=2e-2, atol=2e-2)
    return dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,n", [(1, 8), (4, 64), (16, 128), (5, 512),
                                 (300, 32)])
def test_fwht_kernel(b, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n)).astype(dtype)
    y = ops.fwht(x, use_pallas=True)
    yr = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("nb,n,b,m", [(1, 16, 4, 16), (2, 32, 8, 48),
                                      (4, 64, 16, 256), (1, 128, 300, 128),
                                      (2, 256, 7, 512)])
@pytest.mark.parametrize("epilogue", ["identity", "relu", "heaviside",
                                      "exp", "cos_sin"])
def test_circulant_kernel(nb, n, b, m, epilogue, dtype):
    g = jax.random.normal(jax.random.PRNGKey(1), (nb, n)).astype(dtype)
    x = (jax.random.normal(jax.random.PRNGKey(2), (b, n)) * 0.3).astype(dtype)
    sq = (0.5 * jnp.sum(x.astype(jnp.float32) ** 2, -1)).astype(dtype) \
        if epilogue == "exp" else None
    y = ops.circulant_project(g, x, m, epilogue, sq, use_pallas=True)
    yr = ref.circulant_project_ref(g, x, m, epilogue, sq)
    ya, yb = np.asarray(y, np.float32), np.asarray(yr, np.float32)
    if epilogue == "exp":
        # exp amplifies bf16 rounding by |y|; compare pre-exp (log space)
        ya, yb = np.log(ya + 1e-9), np.log(yb + 1e-9)
    np.testing.assert_allclose(ya, yb, **_tol(dtype, epilogue))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,h,m,dv", [(1, 1, 16, 8), (2, 3, 64, 32),
                                      (4, 2, 256, 128)])
def test_srf_decode_kernel(b, h, m, dv, dtype):
    k = jax.random.split(jax.random.PRNGKey(0), 5)
    s = jax.random.normal(k[0], (b, h, m, dv)).astype(dtype)
    z = jax.random.uniform(k[1], (b, h, m)).astype(dtype)
    pq = jax.random.uniform(k[2], (b, h, m)).astype(dtype)
    pk = jax.random.uniform(k[3], (b, h, m)).astype(dtype)
    v = jax.random.normal(k[4], (b, h, dv)).astype(dtype)
    s2, z2, o = ops.srf_decode(s, z, pq, pk, v, use_pallas=True)
    s2r, z2r, orr = ref.srf_decode_ref(s, z, pq, pk, v)
    for a, bb in [(s2, s2r), (z2, z2r), (o, orr)]:
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32), **_tol(dtype))


def test_kernel_vs_core_structured():
    """The Pallas circulant kernel == core.structured block-circulant."""
    from repro.core import structured as S
    nb, n, m = 2, 64, 128
    params = S.init(jax.random.PRNGKey(3), "circulant", m, n)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, n))
    y_core = S.matvec("circulant", params, x, m)
    y_pallas = ops.circulant_project(params["g"], x, m, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_core),
                               rtol=1e-4, atol=1e-4)


def test_auto_routing_large_falls_back():
    """Big shapes on CPU route to the jnp reference (no pallas interpret)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (1, 64))
    x = jax.random.normal(jax.random.PRNGKey(2), (1 << 17, 64))
    y = ops.circulant_project(g, x, 64)   # auto
    yr = ref.circulant_project_ref(g, x, 64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# fused structured spinner:  f(A . D1 H D0 . x)  vs the dense pmodel oracle
# ---------------------------------------------------------------------------

from repro.core import pmodel
from repro.core.pmodel import PModelSpec

# These tests predate the SpinnerPipeline API and deliberately keep the
# deprecated repro.core.pmodel shim as their independent oracle (the shim
# is pinned bit-identical, which is what makes it a good comparison
# target). pytest.ini escalates our own DeprecationWarnings to errors
# suite-wide; these shim-test modules are the sanctioned exception.
pytestmark = [
    pytest.mark.filterwarnings(
        "ignore:repro.core.pmodel:DeprecationWarning"),
    pytest.mark.filterwarnings(
        "ignore:passing \\w+ here is deprecated:DeprecationWarning"),
]


SPINNER_EPILOGUES = ["identity", "relu", "heaviside", "sign", "exp",
                     "cos_sin"]


def _spinner_oracle(spec, params, x, epilogue):
    """f(W x) with W = materialize(A . D1 H D0) — the dense ground truth."""
    w = pmodel.materialize(spec, params).astype(jnp.float32)
    y = x.astype(jnp.float32) @ w.T
    if epilogue == "identity":
        return np.asarray(y)
    if epilogue == "relu":
        return np.asarray(jnp.maximum(y, 0))
    if epilogue == "heaviside":
        return np.asarray((y >= 0).astype(jnp.float32))
    if epilogue == "sign":
        return np.asarray(jnp.sign(y))
    if epilogue == "exp":
        sq = 0.5 * jnp.sum(x.astype(jnp.float32) ** 2, -1, keepdims=True)
        return np.asarray(jnp.exp(y - sq))
    if epilogue == "cos_sin":
        return np.asarray(jnp.concatenate([jnp.cos(y), jnp.sin(y)], -1))
    raise ValueError(epilogue)


def _spinner_tol(dtype, epilogue):
    if dtype == jnp.bfloat16:
        if epilogue in ("cos_sin", "exp"):
            return dict(rtol=5e-2, atol=1.5e-1)
        return dict(rtol=2e-2, atol=3e-2)
    return dict(rtol=1e-4, atol=1e-4)   # acceptance: <= 1e-4 vs dense oracle


@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("kind", ["circulant", "skew_circulant", "toeplitz",
                                  "hankel", "unstructured", "ldr"])
@pytest.mark.parametrize("epilogue", SPINNER_EPILOGUES)
def test_spinner_all_kinds_epilogues(kind, epilogue, use_pallas):
    """Every P-model kind x epilogue against the dense pipeline oracle, on
    BOTH routes — the jnp ref path (use_pallas=False) is also the
    custom_vjp backward of every Pallas call, so it needs oracle coverage
    of its own (incl. the d1-folded skew path)."""
    b, n, m = 9, 64, 128
    spec = PModelSpec(kind=kind, m=m, n=n)
    params = pmodel.init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, n)) * 0.3
    y = ops.spinner_project(kind, params, x, m, epilogue=epilogue,
                            use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               _spinner_oracle(spec, params, x, epilogue),
                               **_spinner_tol(jnp.float32, epilogue))


@pytest.mark.parametrize("kind", ["circulant", "toeplitz", "hankel"])
@pytest.mark.parametrize("b,n,m,bb,bm", [
    (5, 128, 80, 4, 32),      # m not a multiple of block_m; ragged batch
    (3, 32, 48, 8, 32),       # block-stacked m > n, ragged row tile
    (300, 32, 40, 128, 16),   # batch not a multiple of block_b
    (2, 64, 256, 2, 256),     # m > n whole-m row tile
])
def test_spinner_awkward_shapes(kind, b, n, m, bb, bm):
    spec = PModelSpec(kind=kind, m=m, n=n)
    params = pmodel.init(jax.random.PRNGKey(2), spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, n)) * 0.3
    y = ops.spinner_project(kind, params, x, m, epilogue="relu",
                            use_pallas=True, block_b=bb, block_m=bm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               _spinner_oracle(spec, params, x, "relu"),
                               **_spinner_tol(jnp.float32, "relu"))


@pytest.mark.parametrize("epilogue", ["identity", "exp", "cos_sin"])
def test_spinner_bf16(epilogue):
    spec = PModelSpec(kind="circulant", m=256, n=128)
    p32 = pmodel.init(jax.random.PRNGKey(4), spec)
    p16 = jax.tree_util.tree_map(lambda t: t.astype(jnp.bfloat16), p32)
    x = (jax.random.normal(jax.random.PRNGKey(5), (16, 128)) * 0.3
         ).astype(jnp.bfloat16)
    y = ops.spinner_project("circulant", p16, x, 256, epilogue=epilogue,
                            use_pallas=True)
    assert y.dtype == jnp.bfloat16
    yr = _spinner_oracle(spec, p32, x, epilogue)
    ya = np.asarray(y, np.float32)
    if epilogue == "exp":       # exp amplifies bf16 rounding; log-space cmp
        ya, yr = np.log(ya + 1e-9), np.log(yr + 1e-9)
    np.testing.assert_allclose(ya, yr, **_spinner_tol(jnp.bfloat16, epilogue))


def test_spinner_no_hd():
    """use_hd=False (e.g. non-pow2 head dims): projection + epilogue only."""
    spec = PModelSpec(kind="toeplitz", m=96, n=48, use_hd=False)
    params = pmodel.init(jax.random.PRNGKey(6), spec)
    x = jax.random.normal(jax.random.PRNGKey(7), (7, 48)) * 0.3
    y = ops.spinner_project("toeplitz", params, x, 96, epilogue="exp",
                            use_pallas=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               _spinner_oracle(spec, params, x, "exp"),
                               **_spinner_tol(jnp.float32, "exp"))


def test_spinner_grouped_matches_per_group():
    """(G, B, n) grouped call == G independent single calls (per-head SRF)."""
    gcount, b, n, m = 3, 6, 64, 96
    spec = PModelSpec(kind="circulant", m=m, n=n)
    keys = jax.random.split(jax.random.PRNGKey(8), gcount)
    gp = jax.vmap(lambda k: pmodel.init(k, spec))(keys)
    x = jax.random.normal(jax.random.PRNGKey(9), (gcount, b, n)) * 0.3
    y = ops.spinner_project("circulant", gp, x, m, epilogue="cos_sin",
                            grouped=True, use_pallas=True)
    for i in range(gcount):
        pi = jax.tree_util.tree_map(lambda t: t[i], gp)
        np.testing.assert_allclose(
            np.asarray(y[i], np.float32),
            _spinner_oracle(spec, pi, x[i], "cos_sin"),
            **_spinner_tol(jnp.float32, "cos_sin"))


def test_spinner_grad_matches_ref():
    """Pallas route carries a jnp-reference VJP: grads match the ref route."""
    spec = PModelSpec(kind="circulant", m=64, n=32)
    params = pmodel.init(jax.random.PRNGKey(10), spec)
    x = jax.random.normal(jax.random.PRNGKey(11), (5, 32)) * 0.3

    def loss(p, xx, up):
        y = ops.spinner_project("circulant", p, xx, 64, epilogue="relu",
                                use_pallas=up)
        return jnp.sum(jnp.sin(y))

    gp_pal, gx_pal = jax.grad(loss, argnums=(0, 1))(params, x, True)
    gp_ref, gx_ref = jax.grad(loss, argnums=(0, 1))(params, x, False)
    np.testing.assert_allclose(np.asarray(gx_pal), np.asarray(gx_ref),
                               rtol=1e-4, atol=1e-5)
    for k in gp_ref:
        np.testing.assert_allclose(np.asarray(gp_pal[k]),
                                   np.asarray(gp_ref[k]),
                                   rtol=1e-4, atol=1e-5)


def test_spinner_vs_project_fused():
    """pmodel.project / project_fused are thin wrappers over the kernel."""
    spec = PModelSpec(kind="skew_circulant", m=128, n=64)
    params = pmodel.init(jax.random.PRNGKey(12), spec)
    x = jax.random.normal(jax.random.PRNGKey(13), (4, 3, 64)) * 0.3
    y = pmodel.project(spec, params, x)
    np.testing.assert_allclose(np.asarray(y).reshape(12, 128),
                               _spinner_oracle(spec, params,
                                               x.reshape(12, 64), "identity"),
                               rtol=1e-4, atol=1e-4)


def test_spinner_force_env(monkeypatch):
    """REPRO_FORCE_PALLAS=ref forces the jnp reference route."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "ref")
    assert ops._route(True, 10) == "ref"
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "interpret")
    assert ops._route(False, 10) == "interpret"
    monkeypatch.delenv("REPRO_FORCE_PALLAS")
    assert ops._route(False, 10) == "ref"


# ---------------------------------------------------------------------------
# seed mode: zero-storage spinner regenerated in-kernel from a uint32 seed
# ---------------------------------------------------------------------------

from repro.kernels import seedgen

SEED_KINDS = ["circulant", "skew_circulant", "toeplitz", "hankel",
              "unstructured", "ldr"]


@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("kind", SEED_KINDS)
@pytest.mark.parametrize("epilogue", SPINNER_EPILOGUES)
def test_seeded_bitmatches_materialized_oracle(kind, epilogue, use_pallas):
    """Acceptance: the seeded spinner is BIT-identical to the materialized
    spinner running on the generator-oracle params
    (``seedgen.seeded_params``) on the same route, for every registered
    kind — the kernel regenerates exactly the bits the oracle
    materializes, it never approximates them. Identical explicit block
    sizes pin both calls to the same tiling so the comparison is
    tile-for-tile."""
    b, n, m = 9, 64, 96
    seed = jnp.uint32(1234)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, n)) * 0.3
    y_seeded = ops.spinner_project_seeded(
        kind, seed, x, m, epilogue=epilogue, use_pallas=use_pallas,
        block_b=16, block_m=32)
    params = seedgen.seeded_params(kind, n, m, seed)
    y_mat = ops.spinner_project(kind, params, x, m, epilogue=epilogue,
                                use_pallas=use_pallas, block_b=16, block_m=32)
    assert y_seeded.dtype == y_mat.dtype
    np.testing.assert_array_equal(np.asarray(y_seeded), np.asarray(y_mat))


@pytest.mark.parametrize("use_pallas", [True, False])
def test_seeded_vs_dense_oracle(use_pallas):
    """Seeded output also matches the dense materialized W within the
    standard kernel tolerance (routes through a different matmul shape,
    so exactness is not expected — correctness of the regenerated matrix
    is)."""
    b, n, m = 7, 64, 128
    seed = jnp.uint32(77)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, n)) * 0.3
    y = ops.spinner_project_seeded("circulant", seed, x, m,
                                   epilogue="cos_sin", use_pallas=use_pallas)
    params = seedgen.seeded_params("circulant", n, m, seed)
    spec = PModelSpec(kind="circulant", m=m, n=n)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               _spinner_oracle(spec, params, x, "cos_sin"),
                               **_spinner_tol(jnp.float32, "cos_sin"))


def test_seeded_distinct_seeds_distinct_projections():
    n, m = 64, 96
    x = jax.random.normal(jax.random.PRNGKey(3), (4, n)) * 0.3
    ya = ops.spinner_project_seeded("circulant", jnp.uint32(1), x, m)
    yb = ops.spinner_project_seeded("circulant", jnp.uint32(2), x, m)
    assert not np.allclose(np.asarray(ya), np.asarray(yb))


@pytest.mark.parametrize("use_pallas", [True, False])
def test_seeded_grouped_matches_per_group(use_pallas):
    """(G, B, n) grouped seeded call == G independent single-seed calls
    (the per-head SRF layout), bit for bit."""
    gcount, b, n, m = 3, 5, 64, 96
    seeds = jnp.asarray([11, 22, 33], jnp.uint32)
    x = jax.random.normal(jax.random.PRNGKey(4), (gcount, b, n)) * 0.3
    y = ops.spinner_project_seeded("toeplitz", seeds, x, m,
                                   epilogue="cos_sin", grouped=True,
                                   use_pallas=use_pallas,
                                   block_b=16, block_m=32)
    for i in range(gcount):
        yi = ops.spinner_project_seeded("toeplitz", seeds[i], x[i], m,
                                        epilogue="cos_sin",
                                        use_pallas=use_pallas,
                                        block_b=16, block_m=32)
        np.testing.assert_array_equal(np.asarray(y[i]), np.asarray(yi))


def test_seeded_no_hd():
    """use_hd=False seeded == materialized oracle without the HD sandwich."""
    b, n, m = 6, 48, 80
    seed = jnp.uint32(9)
    x = jax.random.normal(jax.random.PRNGKey(5), (b, n)) * 0.3
    y = ops.spinner_project_seeded("toeplitz", seed, x, m, use_hd=False,
                                   epilogue="relu", use_pallas=True,
                                   block_b=8, block_m=32)
    params = seedgen.seeded_params("toeplitz", n, m, seed, use_hd=False)
    y_mat = ops.spinner_project("toeplitz", params, x, m, epilogue="relu",
                                use_pallas=True, block_b=8, block_m=32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_mat))


@pytest.mark.parametrize("epilogue", ["identity", "exp", "cos_sin"])
def test_seeded_bf16(epilogue):
    """bf16 activations: output dtype follows x; values match the f32
    dense oracle within the standard bf16 tolerance (generation itself is
    always f32 — only the matmul inputs/epilogue round)."""
    b, n, m = 8, 128, 192
    seed = jnp.uint32(42)
    x32 = jax.random.normal(jax.random.PRNGKey(6), (b, n)) * 0.3
    x16 = x32.astype(jnp.bfloat16)
    y = ops.spinner_project_seeded("circulant", seed, x16, m,
                                   epilogue=epilogue, use_pallas=True)
    assert y.dtype == jnp.bfloat16
    params = seedgen.seeded_params("circulant", n, m, seed)
    spec = PModelSpec(kind="circulant", m=m, n=n)
    yr = _spinner_oracle(spec, params, x16, epilogue)
    ya = np.asarray(y, np.float32)
    if epilogue == "exp":
        ya, yr = np.log(ya + 1e-9), np.log(yr + 1e-9)
    np.testing.assert_allclose(ya, yr, **_spinner_tol(jnp.bfloat16, epilogue))


def test_seeded_grad_matches_ref():
    """The seeded Pallas route carries a regenerate-then-differentiate
    reference VJP: dx matches the pure ref route and is finite. Seeds are
    integers — no cotangent flows into them."""
    n, m = 32, 64
    seed = jnp.uint32(5)
    x = jax.random.normal(jax.random.PRNGKey(7), (5, n)) * 0.3

    def loss(xx, up):
        y = ops.spinner_project_seeded("circulant", seed, xx, m,
                                       epilogue="relu", use_pallas=up)
        return jnp.sum(jnp.sin(y))

    gx_pal = jax.grad(loss)(x, True)
    gx_ref = jax.grad(loss)(x, False)
    assert np.all(np.isfinite(np.asarray(gx_pal)))
    np.testing.assert_allclose(np.asarray(gx_pal), np.asarray(gx_ref),
                               rtol=1e-4, atol=1e-5)


def test_seeded_tiling_invariant():
    """Regeneration is indexed by flat global position, so the SAME bits
    come out of any block decomposition — different (block_b, block_m)
    choices agree bit-for-bit on the ref-checked matrix."""
    b, n, m = 10, 64, 96
    seed = jnp.uint32(314)
    x = jax.random.normal(jax.random.PRNGKey(8), (b, n)) * 0.3
    yref = ops.spinner_project_seeded("circulant", seed, x, m,
                                      use_pallas=False)
    for tb, tm in [(4, 32), (16, 96), (8, 64)]:
        y = ops.spinner_project_seeded("circulant", seed, x, m,
                                       use_pallas=True, block_b=tb,
                                       block_m=tm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-5, atol=1e-5)
