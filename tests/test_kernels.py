"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype, epilogue="identity"):
    if dtype == jnp.bfloat16:
        # trig/exp epilogues amplify bf16 pre-activation rounding by the
        # phase/magnitude |y| (~n^1/2); compare with widened tolerance.
        if epilogue in ("cos_sin", "exp"):
            return dict(rtol=5e-2, atol=1.5e-1)
        return dict(rtol=2e-2, atol=2e-2)
    return dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,n", [(1, 8), (4, 64), (16, 128), (5, 512),
                                 (300, 32)])
def test_fwht_kernel(b, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n)).astype(dtype)
    y = ops.fwht(x, use_pallas=True)
    yr = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("nb,n,b,m", [(1, 16, 4, 16), (2, 32, 8, 48),
                                      (4, 64, 16, 256), (1, 128, 300, 128),
                                      (2, 256, 7, 512)])
@pytest.mark.parametrize("epilogue", ["identity", "relu", "heaviside",
                                      "exp", "cos_sin"])
def test_circulant_kernel(nb, n, b, m, epilogue, dtype):
    g = jax.random.normal(jax.random.PRNGKey(1), (nb, n)).astype(dtype)
    x = (jax.random.normal(jax.random.PRNGKey(2), (b, n)) * 0.3).astype(dtype)
    sq = (0.5 * jnp.sum(x.astype(jnp.float32) ** 2, -1)).astype(dtype) \
        if epilogue == "exp" else None
    y = ops.circulant_project(g, x, m, epilogue, sq, use_pallas=True)
    yr = ref.circulant_project_ref(g, x, m, epilogue, sq)
    ya, yb = np.asarray(y, np.float32), np.asarray(yr, np.float32)
    if epilogue == "exp":
        # exp amplifies bf16 rounding by |y|; compare pre-exp (log space)
        ya, yb = np.log(ya + 1e-9), np.log(yb + 1e-9)
    np.testing.assert_allclose(ya, yb, **_tol(dtype, epilogue))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,h,m,dv", [(1, 1, 16, 8), (2, 3, 64, 32),
                                      (4, 2, 256, 128)])
def test_srf_decode_kernel(b, h, m, dv, dtype):
    k = jax.random.split(jax.random.PRNGKey(0), 5)
    s = jax.random.normal(k[0], (b, h, m, dv)).astype(dtype)
    z = jax.random.uniform(k[1], (b, h, m)).astype(dtype)
    pq = jax.random.uniform(k[2], (b, h, m)).astype(dtype)
    pk = jax.random.uniform(k[3], (b, h, m)).astype(dtype)
    v = jax.random.normal(k[4], (b, h, dv)).astype(dtype)
    s2, z2, o = ops.srf_decode(s, z, pq, pk, v, use_pallas=True)
    s2r, z2r, orr = ref.srf_decode_ref(s, z, pq, pk, v)
    for a, bb in [(s2, s2r), (z2, z2r), (o, orr)]:
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32), **_tol(dtype))


def test_kernel_vs_core_structured():
    """The Pallas circulant kernel == core.structured block-circulant."""
    from repro.core import structured as S
    nb, n, m = 2, 64, 128
    params = S.init(jax.random.PRNGKey(3), "circulant", m, n)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, n))
    y_core = S.matvec("circulant", params, x, m)
    y_pallas = ops.circulant_project(params["g"], x, m, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_core),
                               rtol=1e-4, atol=1e-4)


def test_auto_routing_large_falls_back():
    """Big shapes on CPU route to the jnp reference (no pallas interpret)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (1, 64))
    x = jax.random.normal(jax.random.PRNGKey(2), (1 << 17, 64))
    y = ops.circulant_project(g, x, 64)   # auto
    yr = ref.circulant_project_ref(g, x, 64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
