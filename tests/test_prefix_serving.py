"""Prefix-sharing subsystem: radix cache + copy-on-write paged KV +
chunked prefill (``serving/prefix/``).

Unit layers pin the refcounted allocator (share / decref / double-free),
the page-granularity trie (nesting, divergence, pinned-LRU eviction,
defrag remap), the COW planner, and the chunk policy. The engine matrix
runs {kv, hybrid, enc-dec} x {hit, partial hit, miss,
evict-under-pressure, COW divergence} and holds ONE contract across all
cells: greedy outputs are bit-identical to the cold-cache engine —
prefix reuse, forks, chunked prefill and cache eviction may change how
tokens are computed, never which tokens come out. A chaos cell kills a
replica mid-decode with shared prefixes live and requires the rescue to
leak zero pages.

Hybrid/ssd caveat pinned here: slot-bearing plans only hit at a donor's
exact state point (KV pages without the matching SSM state are useless),
so mid-prompt divergence is a MISS for hybrid while kv/enc-dec still
reuse the common full pages.
"""
import numpy as np
import pytest

import jax

from repro.configs import registry
from repro.models import transformer as T
from repro.obs import MetricsRegistry
from repro.serving import (BlockAllocator, ChunkConfig, Engine, FTConfig,
                           PrefixConfig, Request, Router, RouterConfig,
                           SchedConfig)
from repro.serving.chaos import ChaosEngine, ChaosPlan
from repro.serving.prefix import (ChunkPolicy, PrefixCache, RadixTrie,
                                  cow)

ARCHS = {"kv": "qwen3-4b", "hybrid": "hymba-1.5b",
         "encdec": "seamless-m4t-large-v2"}
SCENARIOS = ["hit", "partial", "miss", "evict", "cow"]

_setup_cache = {}


def _setup(fam):
    if fam not in _setup_cache:
        cfg = registry.reduced(ARCHS[fam], n_layers=2)
        params = T.init(jax.random.PRNGKey(0), cfg)
        _setup_cache[fam] = (cfg, params)
    return _setup_cache[fam]


def _enc(cfg, rng):
    if not cfg.is_encdec:
        return None
    from repro.models import frontends
    return frontends.synthetic_audio_features(rng, cfg)


def _drive(eng, reqs):
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    for r in done:
        if r.trace is not None:
            assert r.trace.monotonic(), r.trace.events
    return {r.uid: list(r.out_tokens) for r in done}


def _assert_no_leaks(eng):
    """After a drain the ONLY live references are the cache's; dropping
    it must return the pool to exactly zero used pages."""
    sched = eng.sched
    if eng.prefix is not None:
        assert sched.alloc.used_pages == eng.prefix.pages
        assert sched.alloc.total_refs == eng.prefix.pages
        eng.prefix.drop_all()
    assert sched.alloc.used_pages == 0
    assert sched.alloc.total_refs == 0
    if sched.slot_alloc is not None:
        assert sched.slot_alloc.used_pages == 0


# ---------------------------------------------------------------------------
# refcounted allocator (satellite: double-free regression)
# ---------------------------------------------------------------------------

def test_allocator_double_free_raises_not_relists():
    """Regression: ``free`` used to silently re-list a page, so a buggy
    caller could hand the same page to two requests. Now the second free
    of a dead page must raise, and the free list must never contain a
    live or duplicated id."""
    a = BlockAllocator(num_pages=8, page_size=4)
    p = a.alloc(2)
    assert a.free(p) == sorted(p)
    with pytest.raises(ValueError, match="double free"):
        a.free(p)
    assert a.free_pages == 7
    with pytest.raises(ValueError, match="double free|foreign"):
        a.free([99])


def test_allocator_share_and_refcounted_free():
    a = BlockAllocator(num_pages=8, page_size=4)
    (pg,) = a.alloc(1)
    a.share([pg])
    assert a.refcount(pg) == 2 and a.is_shared(pg)
    assert a.free([pg]) == []            # decref only: still referenced
    assert a.used_pages == 1
    assert not a.is_shared(pg)
    assert a.free([pg]) == [pg]          # last ref: actually released
    assert a.used_pages == 0
    with pytest.raises(ValueError):
        a.free([pg])
    with pytest.raises(ValueError, match="unallocated"):
        a.share([pg])


def test_allocator_defrag_remaps_refcounts():
    a = BlockAllocator(num_pages=16, page_size=4)
    p1 = a.alloc(3)
    p2 = a.alloc(2)
    a.share(p2)
    a.free(p1)
    moves = a.defrag_plan()
    live = [moves.get(p, p) for p in p2]
    assert all(a.refcount(p) == 2 for p in live)
    assert a.total_refs == 4


# ---------------------------------------------------------------------------
# radix trie
# ---------------------------------------------------------------------------

def test_trie_nesting_and_divergence():
    t = RadixTrie(page_size=4)
    new, node = t.insert(0, [1, 2, 3, 4, 5, 6], [10, 11])
    assert new == [10, 11] and node.key == (5, 6)
    # longer prompt nests: shared full page reused, fresh tail diverges
    new2, _ = t.insert(0, [1, 2, 3, 4, 9, 9], [10, 12])
    assert new2 == [12]
    assert t.n_nodes == 3
    m = t.walk(0, [1, 2, 3, 4, 5, 6, 7, 8])
    assert m.tokens == 6 and m.pages == [10] and m.boundary_page == 11
    # divergence INSIDE page one: sibling partial leaves, zero sharing
    m = t.walk(0, [1, 2, 9, 9])
    assert m.tokens == 2 and m.pages == [] and m.boundary_page == 10
    # namespaces partition: same tokens, other ns, no match
    assert t.walk(7, [1, 2, 3, 4]).tokens == 0


def test_trie_insert_page_count_validated():
    t = RadixTrie(page_size=4)
    with pytest.raises(ValueError):
        t.insert(0, [1, 2, 3, 4, 5], [10])
    with pytest.raises(ValueError):
        t.insert(0, [], [])


def test_trie_remove_leaf_only_and_remap():
    t = RadixTrie(page_size=2)
    t.insert(0, [1, 2, 3], [5, 6])
    (inner, leaf) = (t.walk(0, [1, 2, 3]).nodes)
    with pytest.raises(ValueError):
        t.remove(inner)
    assert t.remove(leaf) == 6
    assert t.n_nodes == 1
    t.remap({5: 9})
    assert t.walk(0, [1, 2]).pages == [9]


def test_trie_lru_order_and_pinning():
    alloc = BlockAllocator(num_pages=8, page_size=2)
    cache = PrefixCache(alloc, page_size=2, page_bytes=16)
    pa = alloc.alloc(1)
    pb = alloc.alloc(1)
    cache.insert(0, [1, 2], pa)
    cache.insert(0, [3, 4], pb)
    alloc.free(pa)                       # cache now sole owner of pa
    alloc.free(pb)
    m = cache.lookup(0, [3, 4, 5])       # pins pb (refcount 2)
    assert m is not None and m.tokens == 2
    cache.trie.walk(0, [1, 2])           # touch pa: pinned pb is now LRU
    # pressure eviction takes the LRU UNPINNED leaf — pa, not pinned pb
    assert cache.evict_for(1) == 1
    assert cache.trie.walk(0, [3, 4]).tokens == 2
    assert cache.trie.walk(0, [1, 2]).tokens == 0
    cache.release(m)
    _ = cache.evict_for(1)
    assert alloc.used_pages == 0


def test_cache_byte_budget_lru():
    alloc = BlockAllocator(num_pages=16, page_size=2)
    cache = PrefixCache(alloc, page_size=2, page_bytes=100,
                        cfg=PrefixConfig(cache_bytes=250))
    for i, toks in enumerate(([1, 2], [3, 4], [5, 6])):
        pg = alloc.alloc(1)
        cache.insert(7, toks, pg)
        alloc.free(pg)
    # 3 pages = 300 bytes > 250: the OLDEST insert was evicted
    assert cache.pages == 2
    assert cache.bytes <= 250
    assert cache.trie.walk(7, [1, 2]).tokens == 0
    assert cache.trie.walk(7, [5, 6]).tokens == 2


# ---------------------------------------------------------------------------
# COW planning
# ---------------------------------------------------------------------------

def test_cow_plan_match_and_decode_fork_index():
    t = RadixTrie(page_size=4)
    t.insert(0, list(range(10)), [3, 4, 5])
    raw = t.walk(0, list(range(10)))
    shared, fork = cow.plan_match(raw.nodes, 9, page_size=4)
    assert shared == [3, 4] and fork == 5     # 9 = 2 full pages + 1
    shared, fork = cow.plan_match(raw.nodes, 8, page_size=4)
    assert shared == [3, 4] and fork is None  # aligned: no boundary
    a = BlockAllocator(num_pages=8, page_size=4)
    (pg,) = a.alloc(1)
    assert cow.decode_fork_index(a, [pg], 2, 4) is None
    a.share([pg])
    assert cow.decode_fork_index(a, [pg], 2, 4) == 0
    with pytest.raises(AssertionError):
        cow.assert_writable(a, [pg], 0, 4, 4)
    a.free([pg])
    cow.assert_writable(a, [pg], 0, 4, 4)


# ---------------------------------------------------------------------------
# chunk policy
# ---------------------------------------------------------------------------

def test_chunk_policy_decode_cadence_and_budget():
    pol = ChunkPolicy(ChunkConfig(chunk_tokens=6, decode_every=3))
    turns = [pol.decode_turn() for _ in range(6)]
    assert turns == [False, False, True, False, False, True]
    assert ChunkPolicy(ChunkConfig(decode_every=0)).decode_turn() is False

    class S:
        def __init__(self, plen, pos):
            self.prompt_len, self.prefill_pos = plen, pos
    work = [S(20, 0), S(20, 16), S(8, 0)]
    plan = ChunkPolicy(ChunkConfig(chunk_tokens=6)).plan(
        work, per_row=8, max_rows=4)
    # greedy in rank order: head row takes the whole budget
    assert [(id(s), n) for s, n in plan] == [(id(work[0]), 6)]
    plan = ChunkPolicy(ChunkConfig(chunk_tokens=10)).plan(
        work, per_row=8, max_rows=4)
    assert [n for _, n in plan] == [8, 2]
    # zero budget still guarantees head progress
    plan = ChunkPolicy(ChunkConfig(chunk_tokens=1)).plan(
        work, per_row=8, max_rows=4)
    assert [n for _, n in plan] == [1]


# ---------------------------------------------------------------------------
# engine matrix: {kv, hybrid, encdec} x scenarios, bit-identical greedy
# ---------------------------------------------------------------------------

def _scenario_waves(fam, cfg, scenario):
    """Two request waves (warm-up donors, then the measured wave) built
    so each scenario exercises its path for this family. Prompts are
    copied per engine run."""
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab, 36).astype(np.int32)
    enc = _enc(cfg, rng)
    tails = [rng.integers(1, cfg.vocab, 3 + i).astype(np.int32)
             for i in range(5)]
    donors = [Request(uid=100, prompt=shared.copy(), max_new=2,
                      enc_emb=enc)]
    if scenario in ("hit", "evict", "cow"):
        wave = [Request(uid=i, prompt=np.concatenate([shared, t]),
                        max_new=6, enc_emb=enc)
                for i, t in enumerate(tails)]
    elif scenario == "partial":
        # diverge INSIDE the donor's second page: kv/enc-dec reuse the
        # first full page, hybrid misses (no state at the divergence)
        wave = [Request(uid=i,
                        prompt=np.concatenate([shared[:20], t, t]),
                        max_new=6, enc_emb=enc)
                for i, t in enumerate(tails)]
    elif scenario == "miss":
        wave = [Request(uid=i,
                        prompt=rng.integers(1, cfg.vocab,
                                            20 + i).astype(np.int32),
                        max_new=6, enc_emb=enc)
                for i in range(5)]
    return donors, wave


def _fresh(reqs):
    return [Request(uid=r.uid, prompt=r.prompt.copy(), max_new=r.max_new,
                    enc_emb=r.enc_emb) for r in reqs]


@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("fam", sorted(ARCHS))
def test_prefix_matrix_bit_identical_greedy(fam, scenario):
    cfg, params = _setup(fam)
    donors, wave = _scenario_waves(fam, cfg, scenario)
    kw = dict(batch_slots=4, max_len=64)
    if scenario == "evict":
        # tight paged pool: wave admissions must reclaim cached pages
        kw = dict(batch_slots=4, max_len=64,
                  sched=SchedConfig(max_batch=2, prefill_batch=2,
                                    prefill_chunk=16, page_size=8,
                                    num_pages=12, table_width=7))

    cold = Engine(cfg, params, **kw)
    _drive(cold, _fresh(donors))
    want = _drive(cold, _fresh(wave))
    _assert_no_leaks(cold)

    warm = Engine(cfg, params, prefix=PrefixConfig(
        chunk=ChunkConfig(chunk_tokens=16)), **kw)
    _drive(warm, _fresh(donors))
    got = _drive(warm, _fresh(wave))
    v = warm.metrics.value_sum

    assert got == want, f"{fam}/{scenario}: warm cache changed tokens"
    hit_toks = v("prefix_hit_tokens_total")
    if scenario in ("hit", "cow"):
        assert hit_toks > 0
    elif scenario == "partial":
        if fam == "hybrid":
            # slot-bearing plans need a donor state point: divergence
            # inside the prompt means NO usable state -> full prefill
            assert hit_toks == 0
        else:
            # kv/enc-dec reuse the common full pages (16 of 20 shared
            # tokens sit in page one; the rest re-prefills)
            assert hit_toks > 0
    elif scenario == "miss":
        assert hit_toks == 0
        assert v("prefix_lookups_total") > 0
    elif scenario == "evict":
        assert v("prefix_evictions_total") > 0
    if scenario == "cow":
        # boundary forks at admission (36 % 16 != 0) and/or the donor
        # forking its own tail page at first decode after donating
        assert v("prefix_cow_forks_total") > 0
    _assert_no_leaks(warm)


@pytest.mark.parametrize("fam", sorted(ARCHS))
def test_prefix_trace_milestones(fam):
    """A hit request's trace carries ``prefix_hit`` between admission and
    prefill; a long chunked cold prompt carries ``chunked_prefill``
    continuations. Both must keep the lifecycle monotonic."""
    cfg, params = _setup(fam)
    donors, wave = _scenario_waves(fam, cfg, "hit")
    eng = Engine(cfg, params, batch_slots=4, max_len=64,
                 prefix=PrefixConfig(chunk=ChunkConfig(chunk_tokens=8)))
    _drive(eng, _fresh(donors))
    reqs = _fresh(wave)
    _drive(eng, reqs)
    hits = [r for r in reqs if r.trace.count("prefix_hit")]
    assert hits, "no request hit the warmed cache"
    for r in hits:
        assert r.trace.count("prefix_hit") == 1
        assert r.trace.monotonic()
    # a 39+-token prompt at chunk_tokens=8 needs >= 2 chunks even after
    # the prefix hit; cold donors need >= 4
    chunked = [r for r in reqs if r.trace.count("chunked_prefill")]
    assert chunked
    _assert_no_leaks(eng)


def test_prefix_cache_disabled_for_pure_constant_state():
    """srf/ssd plans have no paged domain — nothing to share; the engine
    must serve with the cache off rather than build a useless trie."""
    cfg = registry.reduced("mamba2-2.7b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=2, max_len=64,
                 prefix=PrefixConfig())
    assert eng.prefix is None
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(1, cfg.vocab, 8)
                    .astype(np.int32), max_new=4) for i in range(3)]
    out = _drive(eng, reqs)
    assert all(len(t) == 4 for t in out.values())


def test_exact_duplicate_prompt_hits_and_bit_matches():
    """plen-1 cap: an exact duplicate still shares every full page below
    the cap but MUST re-prefill at least the last token to produce its
    own first-token logits."""
    cfg, params = _setup("kv")
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab, 33).astype(np.int32)

    def run(prefix):
        eng = Engine(cfg, params, batch_slots=2, max_len=64, prefix=prefix)
        a = _drive(eng, [Request(uid=0, prompt=prompt.copy(), max_new=6)])
        b = _drive(eng, [Request(uid=1, prompt=prompt.copy(), max_new=6)])
        if prefix is not None:
            v = eng.metrics.value_sum
            assert v("prefix_hit_tokens_total") == 32   # 33 - 1
            _assert_no_leaks(eng)
        return a[0], b[1]

    assert run(None) == run(PrefixConfig())


# ---------------------------------------------------------------------------
# chaos: replica death with shared prefixes live
# ---------------------------------------------------------------------------

def test_chaos_kill_replica_with_shared_prefixes_leaks_nothing():
    """PR 7 failover x prefix sharing: kill a replica mid-decode while
    its cache donates pages to running requests. Rescued requests replay
    on the survivor (re-attaching through ITS cache at admission) with
    bit-identical greedy outputs, and neither replica leaks a page."""
    cfg, params = _setup("kv")
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab, 36).astype(np.int32)
    blue = [np.concatenate([shared,
                            rng.integers(1, cfg.vocab, 3 + i)
                            .astype(np.int32)]) for i in range(8)]

    def mk_reqs():
        return [Request(uid=i, prompt=p.copy(), max_new=8)
                for i, p in enumerate(blue)]

    # undisturbed single-engine reference (cold cache)
    ref = Engine(cfg, params, batch_slots=2, max_len=64)
    want = _drive(ref, mk_reqs())

    reg = MetricsRegistry()
    engines = [Engine(cfg, params, batch_slots=2, max_len=64, seed=i,
                      metrics=reg, prefix=PrefixConfig())
               for i in range(2)]
    inner = list(engines)
    engines[1] = ChaosEngine(engines[1], ChaosPlan("raise", at_step=6))
    router = Router(engines, cfg=RouterConfig(migrate=False), metrics=reg,
                    ft=FTConfig(grace_steps=2, stuck_rounds=3))
    reqs = mk_reqs()
    for r in reqs:
        router.submit(r)
    router.run()

    assert all(r.done for r in reqs)
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    assert {r.uid: list(r.out_tokens) for r in reqs} == want
    assert reg.value_sum("router_quarantined_total") == 1
    assert reg.value_sum("prefix_hit_tokens_total") > 0
    # zero leaked pages on BOTH replicas: after the drain every live
    # reference is cache-held; dropping the caches empties the pools
    for eng in inner:
        _assert_no_leaks(eng)


def test_router_prefers_prefix_affinity():
    """Equal-headroom replicas: the one whose cache already holds the
    prompt's prefix must win placement."""
    cfg, params = _setup("kv")
    rng = np.random.default_rng(1)
    shared = rng.integers(1, cfg.vocab, 36).astype(np.int32)
    engines = [Engine(cfg, params, batch_slots=2, max_len=64, seed=i,
                      prefix=PrefixConfig()) for i in range(2)]
    router = Router(engines, cfg=RouterConfig(migrate=False))
    # warm both caches with equal page counts (equal raw headroom) but
    # only replica 1 holds THIS prompt's prefix
    other = rng.integers(1, cfg.vocab, 36).astype(np.int32)
    engines[0].submit(Request(uid=49, prompt=other, max_new=2))
    engines[0].run()
    engines[1].submit(Request(uid=50, prompt=shared.copy(), max_new=2))
    engines[1].run()
    assert engines[1].prefix_peek(
        Request(uid=51, prompt=shared.copy(), max_new=2)) > 0
    tail = rng.integers(1, cfg.vocab, 4).astype(np.int32)
    dest = router.submit(Request(uid=0,
                                 prompt=np.concatenate([shared, tail]),
                                 max_new=4))
    assert dest == 1
    router.run()
    for eng in engines:
        _assert_no_leaks(eng)
