"""MoE: scatter dispatch == dense oracle, capacity drops, aux loss, grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import moe as M


def _cfg(**kw):
    return registry.reduced("deepseek-v2-lite-16b", **kw)


def test_dispatch_matches_dense_reference_no_drops():
    cfg = _cfg(moe_capacity_factor=8.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model)) * 0.5
    y, aux = M.moe_apply(p, cfg, x)
    yr = M.moe_dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-5)
    assert float(aux) > 0.0


def test_capacity_drops_reduce_output():
    """With a tiny capacity factor tokens are dropped (outputs differ from
    the dropless oracle) but everything stays finite."""
    cfg = _cfg(moe_capacity_factor=0.25)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, aux = M.moe_apply(p, cfg, x)
    yr = M.moe_dense_reference(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.abs(y - yr).max()) > 1e-4   # drops actually happened


def test_moe_grads_flow_to_router_and_experts():
    cfg = _cfg(moe_capacity_factor=4.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

    def loss(pp):
        y, aux = M.moe_apply(pp, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux
    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["wi"]).max()) > 0
    assert float(jnp.abs(g["shared"]["wi"]).max()) > 0


def test_load_balance_aux_range():
    """Uniform router -> aux ~ 1; degenerate router -> aux ~ E."""
    cfg = _cfg()
    e = cfg.moe_experts
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))       # uniform
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    _, aux_uniform = M.moe_apply(p, cfg, x)
    assert 0.5 < float(aux_uniform) < 2.0
    biased = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, aux_biased = M.moe_apply(dict(p, router=biased), cfg, x)
    assert float(aux_biased) > float(aux_uniform)
