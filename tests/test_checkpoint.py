"""Checkpointing: roundtrip (incl. bf16), atomicity, keep-k, integrity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b16": jnp.arange(6, dtype=jnp.bfloat16)},
            "opt": {"mu": jnp.ones((3,)), "count": jnp.zeros((), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree()
    mgr.save(7, tree, metadata={"loss": 1.5})
    restored, step, meta = mgr.restore(_tree(seed=1))
    assert step == 7 and meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree())
    assert mgr.available_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_uncommitted_checkpoints_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    # simulate a crash mid-write: step dir without COMMITTED marker
    os.makedirs(tmp_path / "step_00000009")
    assert mgr.latest_step() == 1


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    path = tmp_path / "step_00000001" / "arrays.npz"
    data = bytearray(path.read_bytes())
    data[-20] ^= 0xFF
    path.write_bytes(bytes(data))
    with pytest.raises(Exception):
        mgr.restore(_tree())


def test_async_save_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5
