"""Mesh-sharded paged serving: layout/degradation rules, router placement
and migration logic (host-side, single device), int8 paged KV pools, and
an 8-device (forced host platform) subprocess end-to-end run — all four
cache families served by 2 router-managed sharded replicas with greedy
outputs matching the single-host paged engine, plus scheduler
preemption/eviction and router migration under sharded pools."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import registry
from repro.models import transformer as T
from repro.serving import (Engine, PagedConfig, Request, Router,
                           RouterConfig, SchedConfig)
from repro.serving.mesh import shard as mesh_shard

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _fake_mesh(width):
    devs = np.array(jax.devices() * width)[:width].reshape(1, width)
    return Mesh(devs, ("data", "model"))


# ---------------------------------------------------------------------------
# layout rules (no multi-device needed: specs are pure functions)
# ---------------------------------------------------------------------------

def test_paged_tp_gates_by_family_and_divisibility():
    cfg_kv = registry.reduced("qwen3-4b")            # 4 q / 2 kv heads
    assert mesh_shard.paged_tp(cfg_kv, _fake_mesh(2)) == 2
    assert mesh_shard.paged_tp(cfg_kv, _fake_mesh(4)) == 1   # 2 kv heads % 4
    cfg_srf = registry.reduced("qwen3-4b", attn_impl="srf")
    assert mesh_shard.paged_tp(cfg_srf, _fake_mesh(2)) == 2
    cfg_mla = registry.reduced("deepseek-v2-lite-16b")
    assert mesh_shard.paged_tp(cfg_mla, _fake_mesh(2)) == 1  # latents replicate
    cfg_ssd = registry.reduced("mamba2-2.7b")
    assert mesh_shard.paged_tp(cfg_ssd, _fake_mesh(2)) == 1
    # hybrid / enc-dec gate on their ATTENTION component's head counts
    cfg_hy = registry.reduced("hymba-1.5b")
    assert mesh_shard.paged_tp(cfg_hy, _fake_mesh(2)) == 2
    assert mesh_shard.paged_tp(cfg_hy, _fake_mesh(4)) == 1   # 2 kv heads % 4
    cfg_ed = registry.reduced("seamless-m4t-large-v2")
    assert mesh_shard.paged_tp(cfg_ed, _fake_mesh(2)) == 2


def test_pool_specs_shard_head_dim_only():
    mesh = _fake_mesh(2)
    cfg = registry.reduced("qwen3-4b")
    specs = mesh_shard.pool_specs(cfg, mesh)
    attn = specs["paged"][0]["attn"]
    assert attn["k"] == P(None, None, None, "model", None)
    assert attn["v"] == P(None, None, None, "model", None)
    assert specs["slot"] == [None]
    # int8 layout: values shard, the tiny per-row scales replicate
    specs_q = mesh_shard.pool_specs(cfg, mesh, PagedConfig(quantize_kv=True))
    assert specs_q["paged"][0]["attn"]["k"] == P(None, None, None, "model",
                                                 None)
    assert specs_q["paged"][0]["attn"]["k_scale"] == P(None, None, None, None)
    cfg_srf = registry.reduced("qwen3-4b", attn_impl="srf")
    specs_s = mesh_shard.pool_specs(cfg_srf, mesh)
    assert specs_s["slot"][0]["attn"]["s"] == P(None, None, "model", None,
                                                None)
    assert specs_s["slot"][0]["attn"]["z"] == P(None, None, "model", None)
    assert specs_s["paged"] == [None]
    # degradation: everything replicated
    cfg_mla = registry.reduced("deepseek-v2-lite-16b")
    for s in mesh_shard.pool_specs(cfg_mla, mesh)["paged"][0]["attn"].values():
        assert all(e is None for e in s)


def test_pool_specs_mixed_families():
    """Hybrid: kv sub-pool shards on the kv-head dim, the ssd sub-pool of
    the SAME layer replicates; enc-dec: kv shards, memory replicates."""
    mesh = _fake_mesh(2)
    cfg = registry.reduced("hymba-1.5b")
    specs = mesh_shard.pool_specs(cfg, mesh)
    seg_p, seg_s = specs["paged"][0], specs["slot"][0]
    assert seg_p["attn"]["k"] == P(None, None, None, "model", None)
    for s in seg_s["ssm"].values():
        assert all(e is None for e in s)
    cfg_ed = registry.reduced("seamless-m4t-large-v2")
    specs_ed = mesh_shard.pool_specs(cfg_ed, mesh)
    assert specs_ed["paged"][0]["attn"]["k"] == \
        P(None, None, None, "model", None)
    assert specs_ed["memory"] == P()


def test_serving_param_specs_attention_only():
    mesh = _fake_mesh(2)
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
    specs = mesh_shard.serving_param_specs(params, cfg, mesh)
    seg = specs["segments"][0]
    assert seg["attn"]["wq"] == P(None, None, "model")   # stacked + col
    assert seg["attn"]["wk"] == P(None, None, "model")
    # wo REPLICATED by design (bit-identical greedy; see shard.py)
    assert seg["attn"]["wo"] == P(None, None, None)
    assert seg["mlp"]["wi"] == P(None, None, None)       # mlp replicated
    assert all(e is None for e in specs["embed"]["tok"])


# ---------------------------------------------------------------------------
# int8 paged KV (single device)
# ---------------------------------------------------------------------------

def test_int8_paged_kv_close_to_fp_and_smaller():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 20)))
               .astype(np.int32) for _ in range(8)]

    def drive(paged):
        eng = Engine(cfg, params, batch_slots=8, max_len=64, paged=paged)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p.copy(), max_new=8))
        done = eng.run()
        return {r.uid: r.out_tokens for r in done}, eng.cache_report()

    out_fp, rep_fp = drive(None)
    out_q, rep_q = drive(PagedConfig(quantize_kv=True))
    assert len(out_q) == 8
    # int8 pool (+ scales) is smaller than the f32 pool
    assert rep_q["pool_bytes"] < 0.5 * rep_fp["pool_bytes"]
    assert rep_q["bytes_per_token_per_layer"] < \
        rep_fp["bytes_per_token_per_layer"]
    # quantization is lossy; greedy tokens still mostly agree on a
    # random-init reduced model (sanity that dequant is wired right)
    agree = sum(a == b for u in out_fp
                for a, b in zip(out_fp[u], out_q[u]))
    total = sum(len(v) for v in out_fp.values())
    assert agree / total > 0.5, (agree, total)


def test_int8_quantize_kv_only_affects_kv_family():
    from repro.serving import paged_cache
    cfg = registry.reduced("mamba2-2.7b")
    pools = paged_cache.init_pools(cfg, 4, 8, num_slots=4,
                                   paged=PagedConfig(quantize_kv=True))
    assert pools["paged"] == [None]
    assert set(pools["slot"][0]["ssm"]) == {"conv", "ssm"}
    # hybrid: the kv sub-pool quantizes, the ssd sub-pool next to it not
    cfg_hy = registry.reduced("hymba-1.5b", n_layers=2)
    pools_hy = paged_cache.init_pools(cfg_hy, 4, 8, num_slots=4,
                                      paged=PagedConfig(quantize_kv=True))
    assert "k_scale" in pools_hy["paged"][0]["attn"]
    assert set(pools_hy["slot"][0]) == {"ssm"}


# ---------------------------------------------------------------------------
# router logic (single device, no mesh: pure host-side control plane)
# ---------------------------------------------------------------------------

def test_router_spreads_by_free_page_pressure():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    engines = [Engine(cfg, params, batch_slots=4, max_len=64)
               for _ in range(2)]
    router = Router(engines)
    for i in range(8):
        router.submit(Request(uid=i, prompt=np.arange(1, 6, dtype=np.int32),
                              max_new=4))
    homes = [router.home[i] for i in range(8)]
    assert set(homes) == {0, 1}                  # both replicas used
    done = router.run()
    assert len(done) == 8
    assert all(e.stats["requests"] > 0 for e in engines)


def test_router_migrates_waiting_off_saturated_replica():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    # replica 0: tiny pool (1 request at a time); replica 1: roomy
    tight = SchedConfig(max_batch=1, prefill_batch=1, prefill_chunk=8,
                        page_size=8, num_pages=3, table_width=2)
    roomy = SchedConfig(max_batch=4, prefill_batch=4, prefill_chunk=8,
                        page_size=8, num_pages=33, table_width=2)
    e0 = Engine(cfg, params, sched=tight)
    e1 = Engine(cfg, params, sched=roomy)
    router = Router([e0, e1], RouterConfig(migrate=True))
    # submit straight into replica 0's queue to create a local backlog
    # (bypassing placement, as if the pressure estimate had been stale)
    for i in range(5):
        e0.submit(Request(uid=i, prompt=np.arange(1, 7, dtype=np.int32),
                          max_new=4))
        router.home[i] = 0
    done = router.run()
    assert len(done) == 5
    assert router.stats["migrations"] > 0
    assert e1.stats["requests"] > 0              # migrated work really ran
    assert all(len(r.out_tokens) == 4 for r in done)


def test_migrated_outputs_match_unmigrated():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(3, 12)))
               .astype(np.int32) for _ in range(5)]

    solo = Engine(cfg, params, batch_slots=4, max_len=64)
    for i, p in enumerate(prompts):
        solo.submit(Request(uid=i, prompt=p.copy(), max_new=5))
    want = {r.uid: r.out_tokens for r in solo.run()}

    tight = SchedConfig(max_batch=1, prefill_batch=1, prefill_chunk=8,
                        page_size=8, num_pages=3, table_width=2)
    e0 = Engine(cfg, params, sched=tight)
    e1 = Engine(cfg, params, batch_slots=4, max_len=64)
    router = Router([e0, e1])
    for i, p in enumerate(prompts):
        e0.submit(Request(uid=i, prompt=p.copy(), max_new=5))
        router.home[i] = 0
    got = {r.uid: r.out_tokens for r in router.run()}
    assert router.stats["migrations"] > 0
    assert got == want


def test_router_single_replica_is_passthrough():
    """A 1-replica router must behave exactly like the bare engine: same
    outputs, every request homed on replica 0, zero migrations."""
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(3, 12)))
               .astype(np.int32) for _ in range(5)]

    solo = Engine(cfg, params, batch_slots=4, max_len=64)
    for i, p in enumerate(prompts):
        solo.submit(Request(uid=i, prompt=p.copy(), max_new=5))
    want = {r.uid: r.out_tokens for r in solo.run()}

    router = Router([Engine(cfg, params, batch_slots=4, max_len=64)])
    for i, p in enumerate(prompts):
        router.submit(Request(uid=i, prompt=p.copy(), max_new=5))
    got = {r.uid: r.out_tokens for r in router.run()}
    assert got == want
    assert set(router.home.values()) == {0}
    assert router.stats["migrations"] == 0
    assert router.migrate() == 0                 # no-op fast path


def test_router_all_replicas_saturated_no_thrash():
    """When EVERY replica is saturated there is nowhere meaningfully
    roomier: a migration pass moves nothing, and the router still drains
    the backlog by normal admission as pages free up."""
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    tight = SchedConfig(max_batch=1, prefill_batch=1, prefill_chunk=8,
                        page_size=8, num_pages=3, table_width=2)
    engines = [Engine(cfg, params, sched=tight) for _ in range(2)]
    router = Router(engines)
    prompt = np.arange(1, 7, dtype=np.int32)
    for i in range(8):                   # 4-deep backlog on each replica
        engines[i % 2].submit(Request(uid=i, prompt=prompt.copy(),
                                      max_new=4))
        router.home[i] = i % 2
    for e in engines:                    # admit the head of each queue
        e.sched.admit()
    assert all(router._headroom(e) < 0 for e in engines)  # both saturated
    assert router.migrate() == 0         # symmetric pressure: no move
    done = router.run()
    assert len(done) == 8
    assert all(len(r.out_tokens) == 4 for r in done)


def test_router_skips_replica_with_zero_free_pages():
    """Placement must not pick a replica whose pool is fully allocated."""
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    tight = SchedConfig(max_batch=2, prefill_batch=1, prefill_chunk=8,
                        page_size=8, num_pages=3, table_width=2)
    e0 = Engine(cfg, params, sched=tight)
    e1 = Engine(cfg, params, batch_slots=4, max_len=64)
    router = Router([e0, e1])
    # occupy replica 0 completely: 9 prompt tokens -> both usable pages
    e0.submit(Request(uid=0, prompt=np.arange(1, 10, dtype=np.int32),
                      max_new=4))
    router.home[0] = 0
    e0.sched.admit()
    assert e0.free_pages == 0
    idx = router.submit(Request(uid=1,
                                prompt=np.arange(1, 6, dtype=np.int32),
                                max_new=4))
    assert idx == 1                      # zero-free-page replica skipped
    done = router.run()
    assert len(done) == 2


# ---------------------------------------------------------------------------
# 8-device subprocess: sharded pools end to end
# ---------------------------------------------------------------------------

_SUBPROC = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax, numpy as np
    from repro.configs import registry
    from repro.launch import mesh as mesh_lib
    from repro.models import transformer as T
    from repro.serving import (Engine, PagedConfig, Request, Router,
                               SchedConfig)
    from repro.serving.mesh import shard as mesh_shard

    FAMS = [("kv", "qwen3-4b", {}),
            ("srf", "qwen3-4b", {"attn_impl": "srf"}),
            ("mla", "deepseek-v2-lite-16b", {}),
            ("ssd", "mamba2-2.7b", {}),
            ("hybrid", "hymba-1.5b", {}),
            ("encdec", "seamless-m4t-large-v2", {})]
    rng = np.random.default_rng(0)
    for fam, arch, over in FAMS:
        from repro.models import frontends
        cfg = registry.reduced(arch, n_layers=2, **over)
        params = T.init(jax.random.PRNGKey(0), cfg)
        spec = [(int(rng.integers(2, 20)), int(rng.integers(3, 8)))
                for _ in range(16)]
        prompts = [rng.integers(0, cfg.vocab, pl).astype(np.int32)
                   for pl, _ in spec]
        encs = [frontends.synthetic_audio_features(rng, cfg)
                if cfg.is_encdec else None for _ in spec]

        single = Engine(cfg, params, batch_slots=8, max_len=64)
        for i, ((pl, mn), p, e) in enumerate(zip(spec, prompts, encs)):
            single.submit(Request(uid=i, prompt=p, max_new=mn, enc_emb=e))
        want = {r.uid: r.out_tokens for r in single.run()}

        meshes = mesh_lib.make_serving_meshes(replicas=2, model_parallel=2)
        router = Router([Engine(cfg, params, batch_slots=8, max_len=64,
                                mesh=m) for m in meshes])
        for i, ((pl, mn), p, e) in enumerate(zip(spec, prompts, encs)):
            router.submit(Request(uid=i, prompt=p.copy(), max_new=mn,
                                  enc_emb=e))
        got = {r.uid: r.out_tokens for r in router.run()}

        assert got == want, f"{fam}: token mismatch"
        assert len(got) == 16, fam
        assert all(e.stats["requests"] > 0 for e in router.engines), fam
        tp = mesh_shard.paged_tp(cfg, meshes[0])
        pbd = router.engines[0].cache_report()["pool_bytes_per_device"]
        pb = single.cache_report()["pool_bytes"]
        if fam in ("hybrid", "encdec"):
            # mixed plans: the kv sub-pool shards (1/TP bytes), the ssd /
            # memory sub-pools replicate -> strictly between pb/tp and pb
            assert pb / tp < pbd < pb, (fam, pbd, pb)
        elif tp > 1:                    # kv / srf shard; mla / ssd exempt
            assert pbd * tp == pb, (fam, pbd, pb)
        else:
            assert pbd == pb, (fam, pbd, pb)
        print(f"FAM_OK {fam} tp={tp}")

    # preemption/eviction with sharded pools: tight pool forces evictions,
    # copy-on-preempt (async snapshots) + swap-in stays bit-exact
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params = T.init(jax.random.PRNGKey(0), cfg)
    mesh = mesh_lib.make_serving_meshes(replicas=1, model_parallel=2)[0]
    prompts = [rng.integers(0, cfg.vocab, 3).astype(np.int32)
               for _ in range(4)]
    def drive(s, m):
        e = Engine(cfg, params, batch_slots=4, max_len=16, sched=s, mesh=m)
        for i, p in enumerate(prompts):
            e.submit(Request(uid=i, prompt=p.copy(), max_new=10))
        d = e.run()
        return {r.uid: r.out_tokens for r in d}, e.stats["preemptions"]
    tight = SchedConfig(max_batch=4, prefill_batch=2, prefill_chunk=4,
                        page_size=4, num_pages=9, table_width=4)
    roomy = SchedConfig(max_batch=4, prefill_batch=2, prefill_chunk=4,
                        page_size=4, num_pages=33, table_width=4)
    out_tight, n_pre = drive(tight, mesh)
    out_roomy, _ = drive(roomy, None)
    assert n_pre > 0, "pool not tight enough to force preemption"
    assert out_tight == out_roomy
    print("PREEMPT_OK", n_pre)

    # int8 pools under sharding: quantized values shard on the head dim,
    # the pmax'd scales replicate — greedy tokens bit-match the
    # single-host int8 engine
    pc = PagedConfig(quantize_kv=True)
    q_ref = Engine(cfg, params, batch_slots=4, max_len=16, paged=pc)
    q_sh = Engine(cfg, params, batch_slots=4, max_len=16, paged=pc,
                  mesh=mesh)
    for i, p in enumerate(prompts):
        q_ref.submit(Request(uid=i, prompt=p.copy(), max_new=6))
        q_sh.submit(Request(uid=i, prompt=p.copy(), max_new=6))
    qw = {r.uid: r.out_tokens for r in q_ref.run()}
    qg = {r.uid: r.out_tokens for r in q_sh.run()}
    assert qg == qw, "int8 sharded tokens diverge from single host"
    assert q_sh.cache_report()["pool_bytes_per_device"] < \
        q_ref.cache_report()["pool_bytes"]
    print("INT8_MESH_OK")

    # router migration with sharded replicas: a single-slot replica with a
    # fresh-request backlog drains through the roomy one, outputs unchanged
    # (page geometries differ, so the router's _can_place gate keeps any
    # snapshot-carrying sequence home and migrates the fresh ones)
    meshes = mesh_lib.make_serving_meshes(replicas=2, model_parallel=2)
    slot1 = SchedConfig(max_batch=1, prefill_batch=1, prefill_chunk=4,
                        page_size=4, num_pages=5, table_width=4)
    e0 = Engine(cfg, params, sched=slot1, mesh=meshes[0])
    e1 = Engine(cfg, params, batch_slots=4, max_len=16, mesh=meshes[1])
    router = Router([e0, e1])
    for i, p in enumerate(prompts):
        e0.submit(Request(uid=i, prompt=p.copy(), max_new=10))
        router.home[i] = 0
    got = {r.uid: r.out_tokens for r in router.run()}
    assert got == out_roomy
    assert router.stats["migrations"] > 0
    assert e1.stats["requests"] > 0
    print("MIGRATE_OK", router.stats["migrations"])
""")


@pytest.mark.slow
def test_mesh_serving_subprocess_end_to_end():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    tail = out.stdout + out.stderr[-3000:]
    for fam in ("kv", "srf", "mla", "ssd", "hybrid", "encdec"):
        assert f"FAM_OK {fam}" in out.stdout, tail
    assert "PREEMPT_OK" in out.stdout, tail
    assert "INT8_MESH_OK" in out.stdout, tail
    assert "MIGRATE_OK" in out.stdout, tail
