"""Deterministic synthetic data pipeline.

Streams are reproducible functions of (seed, step, shard) — a restarted or
re-sharded job regenerates byte-identical batches, which is what makes the
checkpoint/restart and elastic tests exact.

The LM stream has learnable structure (affine token recurrences with
segment resets + noise), so integration tests can assert loss decreases.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    ss = np.random.SeedSequence([seed, step, shard, 0x5EED])
    return np.random.Generator(np.random.Philox(ss))


def lm_batch(vocab: int, batch: int, seq: int, step: int, seed: int = 0,
             shard: int = 0, noise: float = 0.05) -> Dict[str, np.ndarray]:
    """tokens[t+1] = (a * tokens[t] + b) % vocab within random segments."""
    g = _rng(seed, step, shard)
    a = 5
    toks = np.empty((batch, seq + 1), np.int64)
    toks[:, 0] = g.integers(0, vocab, batch)
    bvec = g.integers(1, 17, batch)
    resets = g.random((batch, seq)) < 0.02
    rnd = g.integers(0, vocab, (batch, seq))
    for t in range(seq):
        nxt = (a * toks[:, t] + bvec) % vocab
        toks[:, t + 1] = np.where(resets[:, t], rnd[:, t], nxt)
    noise_mask = g.random((batch, seq)) < noise
    noisy = np.where(noise_mask, g.integers(0, vocab, (batch, seq)),
                     toks[:, :-1])
    return {"tokens": noisy.astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def frontend_features(batch: int, length: int, dim: int, step: int,
                      seed: int = 0, shard: int = 0) -> np.ndarray:
    g = _rng(seed, step, shard ^ 0xF00D)
    return (g.standard_normal((batch, length, dim)) * 0.2).astype(np.float32)


def full_batch(cfg, batch: int, seq: int, step: int, seed: int = 0,
               shard: int = 0) -> Dict[str, np.ndarray]:
    """Batch matching configs.shapes.batch_specs for any arch family."""
    from repro.models import frontends  # local import: avoid cycle
    out: Dict[str, np.ndarray] = {}
    if cfg.is_encdec:
        out.update(lm_batch(cfg.vocab, batch, seq, step, seed, shard))
        out["enc_emb"] = frontend_features(batch, cfg.enc_len,
                                           frontends.AUDIO_FEAT_DIM,
                                           step, seed, shard)
    elif cfg.frontend == "vision_stub":
        nv = min(cfg.n_vision_tokens, seq // 2)
        out.update(lm_batch(cfg.vocab, batch, seq - nv, step, seed, shard))
        out["vision_emb"] = frontend_features(batch, nv,
                                              frontends.VISION_FEAT_DIM,
                                              step, seed, shard)
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32), (batch, seq))
        out["pos3"] = np.broadcast_to(pos, (3, batch, seq)).copy()
    else:
        out.update(lm_batch(cfg.vocab, batch, seq, step, seed, shard))
    return out
