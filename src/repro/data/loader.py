"""Sharded, prefetching data loader.

Each *data shard* (a host group on the `pod` x `data` axes) generates its
slice of the global batch locally — no central dispenser, O(1) host memory,
and deterministic restart (stream is a function of (seed, step, shard)).

Prefetch runs on a background thread (depth-k queue) so host-side batch
synthesis overlaps device compute — the standard input-pipeline overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    def __init__(self, make_batch: Callable[[int, int], Dict[str, np.ndarray]],
                 n_shards: int = 1, shard_id: int = 0, prefetch: int = 2,
                 start_step: int = 0):
        """make_batch(step, shard_id) -> dict of np arrays (the LOCAL slice)."""
        self.make_batch = make_batch
        self.n_shards = n_shards
        self.shard_id = shard_id
        self.prefetch = prefetch
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.make_batch(step, self.shard_id)
            except Exception as e:   # surface producer errors to consumers
                self._q.put(("__error__", e))
                return
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def __iter__(self) -> Iterator:
        self.start()
        while True:
            step, batch = self._q.get()
            if step == "__error__":
                raise RuntimeError("data producer failed") from batch
            yield step, batch

    def reset(self, step: int):
        """Elastic/restart: resume the stream from a checkpointed step."""
        self.stop()
        self._stop = threading.Event()
        self._q = queue.Queue(maxsize=max(1, self.prefetch))
        self._step = step
        return self


def device_batch(batch: Dict[str, np.ndarray], sharding=None) -> Dict:
    """Host batch -> device arrays (optionally with a NamedSharding)."""
    if sharding is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)
