"""repro.data subsystem."""
