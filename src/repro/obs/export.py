"""Chrome-trace-event export for span timelines.

``chrome_trace`` turns one or more :class:`~repro.obs.spans.SpanRecorder`
rings into the Trace Event JSON format that chrome://tracing and
Perfetto load directly: duration spans as paired ``B``/``E`` events,
instants as ``i`` events, one *process* row per replica (``pid`` =
replica id), with ``process_name`` metadata so the UI labels rows
``replica 0``, ``replica 1``, ...

All recorders in a deployment share the ``time.perf_counter`` epoch, so
merging is just concatenation; timestamps are normalized to the global
minimum and emitted in microseconds (the format's unit), putting every
replica on one clock axis.

Begin/end pairs must nest properly per (pid, tid). Spans from a single
recorder nest by construction (stack discipline), so the emitter sorts
each process's spans by start time and replays them through an explicit
stack, closing any span that ends before the next one starts — the
resulting event stream is monotone in ``ts`` and properly paired, which
is exactly what the golden test pins.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List

from .spans import Span, SpanRecorder

__all__ = ["chrome_trace", "dump_chrome_trace"]


def _collect(source) -> List[Span]:
    """Accept a recorder, an iterable of recorders, or an iterable of
    Span records (mixing is fine)."""
    if isinstance(source, SpanRecorder):
        return source.snapshot()
    out: List[Span] = []
    for item in source:
        if isinstance(item, SpanRecorder):
            out.extend(item.snapshot())
        else:
            out.append(item)
    return out


def _args(rec: Span) -> Dict[str, Any]:
    a = dict(rec.args)
    if rec.uid is not None:
        a["uid"] = rec.uid
    return a


def chrome_trace(source) -> Dict[str, Any]:
    """Build a Chrome Trace Event JSON object (``{"traceEvents": [...]}``)
    from recorders / span records. Loadable by Perfetto as-is."""
    records = _collect(source)
    events: List[Dict[str, Any]] = []
    if not records:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    t_zero = min(r.t0 for r in records)
    us = lambda t: round((t - t_zero) * 1e6, 3)  # noqa: E731

    by_pid: Dict[int, List[Span]] = {}
    for r in records:
        by_pid.setdefault(r.replica if r.replica is not None else 0,
                          []).append(r)

    for pid in sorted(by_pid):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"replica {pid}"}})

    for pid in sorted(by_pid):
        group = by_pid[pid]
        spans = sorted((r for r in group if r.kind == "span"),
                       key=lambda r: (r.t0, r.sid))
        stack: List[Span] = []

        def _close(top: Span) -> None:
            events.append({"name": top.name, "ph": "E", "pid": pid,
                           "tid": 0, "ts": us(top.t1)})

        for r in spans:
            while stack and stack[-1].t1 <= r.t0:
                _close(stack.pop())
            events.append({"name": r.name, "ph": "B", "pid": pid, "tid": 0,
                           "ts": us(r.t0), "args": _args(r)})
            stack.append(r)
        while stack:
            _close(stack.pop())

        for r in group:
            if r.kind != "instant":
                continue
            events.append({"name": r.name, "ph": "i", "pid": pid, "tid": 0,
                           "ts": us(r.t0), "s": "t", "args": _args(r)})

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump_chrome_trace(path, source) -> int:
    """Write the trace JSON to ``path``; returns the event count."""
    doc = chrome_trace(source)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return len(doc["traceEvents"])
