"""Labelled metrics registry: Counter / Gauge / Histogram, snapshot +
Prometheus-style text export, and a bounded JSONL event stream.

Design points, in order of importance for the serving hot path:

* **Cheap when enabled.** A bound metric (``metric.labels(...)``) is a
  tiny object holding a direct reference into the parent's value table;
  ``inc`` / ``set`` / ``observe`` are one attribute update each. The
  engine binds its children once at construction, so the per-step cost
  is a handful of float adds — the same work as the ad-hoc ``stats``
  dict writes the registry replaced.
* **Free when disabled.** ``MetricsRegistry(enabled=False)`` hands out
  a shared no-op metric whose mutators do nothing and whose reads
  return zero; no value tables are built, no events are kept.
* **Readable back.** Legacy ``.stats`` dicts survive as
  :class:`StatsView`, a read-only Mapping whose values are computed
  from the live registry on access — nothing is double-counted.

Label values are stringified; each (metric, label-values) pair is one
child. Histograms keep raw observations (bounded ring, default 64k per
child) so percentiles are exact for serving-scale runs; export emits
Prometheus summary-style ``{quantile=...}`` rows. Everything is
single-threaded by design — the serving control plane runs on one
thread, matching the scheduler/engine contract.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, IO, Iterable, List, Mapping, Optional, Tuple

try:                                       # Mapping ABC for StatsView
    from collections.abc import Mapping as _MappingABC
except ImportError:                        # pragma: no cover
    _MappingABC = object


# ---------------------------------------------------------------------------
# no-op metric (disabled registries hand this out)
# ---------------------------------------------------------------------------

class _NoopMetric:
    """Answers the full Counter/Gauge/Histogram surface with nothing."""

    def labels(self, **_kw):
        return self

    def inc(self, amount=1, **_kw):
        pass

    def dec(self, amount=1, **_kw):
        pass

    def set(self, value, **_kw):
        pass

    def observe(self, value, **_kw):
        pass

    def value(self, **_kw):
        return 0

    def count(self, **_kw):
        return 0

    def sum(self, **_kw):
        return 0.0

    def percentile(self, q, **_kw):
        return float("nan")

    def all_values(self):
        return []


NOOP = _NoopMetric()


# ---------------------------------------------------------------------------
# live metrics
# ---------------------------------------------------------------------------

def _label_key(labelnames: Tuple[str, ...], kw: Dict) -> Tuple[str, ...]:
    if set(kw) != set(labelnames):
        raise ValueError(f"expected labels {labelnames}, got {tuple(kw)}")
    return tuple(str(kw[n]) for n in labelnames)


class _Bound:
    """One (metric, label-values) child; holds its own scalar/list."""
    __slots__ = ("_metric", "_key")

    def __init__(self, metric, key):
        self._metric = metric
        self._key = key


class _BoundCounter(_Bound):
    def inc(self, amount=1):
        if amount < 0:
            raise ValueError("counters only go up")
        v = self._metric._values
        v[self._key] = v.get(self._key, 0) + amount

    def value(self):
        return self._metric._values.get(self._key, 0)


class _BoundGauge(_Bound):
    def set(self, value):
        self._metric._values[self._key] = value

    def inc(self, amount=1):
        v = self._metric._values
        v[self._key] = v.get(self._key, 0) + amount

    def dec(self, amount=1):
        self.inc(-amount)

    def value(self):
        return self._metric._values.get(self._key, 0)


class _BoundHistogram(_Bound):
    def observe(self, value):
        m = self._metric
        obs, meta = m._series(self._key)
        meta[0] += 1                       # count
        meta[1] += value                   # sum
        if len(obs) >= m.max_observations:
            obs[meta[0] % m.max_observations] = value     # ring overwrite
        else:
            obs.append(value)

    def count(self):
        return self._metric._meta.get(self._key, (0, 0.0))[0]

    def sum(self):
        return self._metric._meta.get(self._key, (0, 0.0))[1]

    def values(self):
        return list(self._metric._obs.get(self._key, ()))

    def percentile(self, q):
        obs = self._metric._obs.get(self._key)
        if not obs:
            return float("nan")
        srt = sorted(obs)
        idx = min(len(srt) - 1, max(0, round(q / 100.0 * (len(srt) - 1))))
        return srt[idx]


class _Metric:
    kind = "untyped"
    _bound_cls = _Bound

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], _Bound] = {}

    def labels(self, **kw):
        key = _label_key(self.labelnames, kw)
        child = self._children.get(key)
        if child is None:
            child = self._bound_cls(self, key)
            self._children[key] = child
        return child

    def _default(self):
        """The unlabelled child (only valid when labelnames is empty)."""
        return self.labels()

    # convenience pass-throughs for label-less metrics
    def inc(self, amount=1):
        self._default().inc(amount)

    def value(self, **kw):
        return self.labels(**kw).value() if kw or not self.labelnames \
            else self._no_labels_error()

    def _no_labels_error(self):
        raise ValueError(f"{self.name} has labels {self.labelnames}; "
                         "use .labels(...)")


class Counter(_Metric):
    kind = "counter"
    _bound_cls = _BoundCounter

    def __init__(self, name, help, labelnames):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def total(self):
        return sum(self._values.values())

    def items(self):
        return dict(self._values)


class Gauge(_Metric):
    kind = "gauge"
    _bound_cls = _BoundGauge

    def __init__(self, name, help, labelnames):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def set(self, value):
        self._default().set(value)

    def total(self):
        return sum(self._values.values())

    def items(self):
        return dict(self._values)


class Histogram(_Metric):
    kind = "histogram"
    _bound_cls = _BoundHistogram

    def __init__(self, name, help, labelnames, max_observations: int = 65536):
        super().__init__(name, help, labelnames)
        self.max_observations = max_observations
        self._obs: Dict[Tuple[str, ...], List[float]] = {}
        self._meta: Dict[Tuple[str, ...], List[float]] = {}  # [count, sum]

    def _series(self, key):
        obs = self._obs.get(key)
        if obs is None:
            obs = self._obs[key] = []
            self._meta[key] = [0, 0.0]
        return obs, self._meta[key]

    def observe(self, value):
        self._default().observe(value)

    def all_values(self) -> List[float]:
        """Every observation across all label children (merged)."""
        out: List[float] = []
        for obs in self._obs.values():
            out.extend(obs)
        return out

    def total_count(self):
        return sum(m[0] for m in self._meta.values())

    def items(self):
        return {k: (m[0], m[1]) for k, m in self._meta.items()}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Holds every metric plus a bounded JSONL event stream.

    ``enabled=False`` makes every factory return the shared no-op metric
    and drops events — the cheap-off switch the overhead bench pins.
    Metric factories are idempotent by name; re-registering with a
    different type or label set is an error (it would silently fork the
    series).
    """

    def __init__(self, enabled: bool = True, max_events: int = 100_000):
        self.enabled = enabled
        self.max_events = max_events
        self._metrics: Dict[str, _Metric] = {}
        self.events: List[Dict] = []
        self.events_dropped = 0
        self._t0 = time.perf_counter()

    # -- factories -----------------------------------------------------------

    def _get(self, cls, name, help, labelnames, **kw):
        if not self.enabled:
            return NOOP
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, tuple(labelnames), **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls) or m.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind} with "
                f"labels {m.labelnames}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  max_observations: int = 65536) -> Histogram:
        return self._get(Histogram, name, help, labelnames,
                         max_observations=max_observations)

    # -- events --------------------------------------------------------------

    def event(self, name: str, **fields) -> None:
        """Append one lifecycle event (JSONL-exportable). Bounded: past
        ``max_events`` the newest events are dropped and counted."""
        if not self.enabled:
            return
        if len(self.events) >= self.max_events:
            self.events_dropped += 1
            return
        self.events.append(
            {"event": name, "t": time.perf_counter() - self._t0, **fields})

    def dump_events_jsonl(self, fp: IO[str]) -> int:
        """Write the event stream as JSON lines; returns lines written."""
        for ev in self.events:
            fp.write(json.dumps(ev) + "\n")
        return len(self.events)

    # -- export --------------------------------------------------------------

    def snapshot(self) -> Dict:
        """{kind: {name: {label-string: value}}} plus event accounting.
        Histogram values are (count, sum) pairs; use :meth:`percentiles`
        or ``histogram(...).all_values()`` for the distribution."""
        out: Dict = {"counters": {}, "gauges": {}, "histograms": {},
                     "events": len(self.events),
                     "events_dropped": self.events_dropped}
        for name, m in self._metrics.items():
            if isinstance(m, (Counter, Gauge)):
                sect = "counters" if isinstance(m, Counter) else "gauges"
                out[sect][name] = {self._lbl(m, k): v
                                   for k, v in m.items().items()}
            elif isinstance(m, Histogram):
                out["histograms"][name] = {
                    self._lbl(m, k): {"count": c, "sum": s}
                    for k, (c, s) in m.items().items()}
        return out

    @staticmethod
    def _escape_label_value(v: str) -> str:
        """Prometheus exposition escaping: backslash, double-quote and
        newline must be escaped inside label values or the scrape line
        is unparseable (tenant namespaces are user-supplied strings)."""
        return (v.replace("\\", r"\\").replace("\n", r"\n")
                 .replace('"', r'\"'))

    @classmethod
    def _lbl(cls, m: _Metric, key: Tuple[str, ...]) -> str:
        return ",".join(f'{n}="{cls._escape_label_value(v)}"'
                        for n, v in zip(m.labelnames, key))

    def prometheus_text(self, quantiles=(0.5, 0.95, 0.99)) -> str:
        """Prometheus exposition format; histograms export summary-style
        quantile rows computed from the retained observations."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            kind = "summary" if isinstance(m, Histogram) else m.kind
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, (Counter, Gauge)):
                for key, v in sorted(m.items().items()):
                    lbl = self._lbl(m, key)
                    lines.append(f"{name}{{{lbl}}} {v}" if lbl
                                 else f"{name} {v}")
            else:
                for key, (c, s) in sorted(m.items().items()):
                    lbl = self._lbl(m, key)
                    child = m._children.get(key)
                    for q in quantiles:
                        ql = (f'{lbl},quantile="{q}"' if lbl
                              else f'quantile="{q}"')
                        pv = child.percentile(q * 100) if child else 0.0
                        lines.append(f"{name}{{{ql}}} {pv}")
                    sfx = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}_sum{sfx} {s}")
                    lines.append(f"{name}_count{sfx} {c}")
        return "\n".join(lines) + ("\n" if lines else "")

    # -- aggregation helpers (reporter / tests) ------------------------------

    def value_sum(self, name: str) -> float:
        """Sum of a counter/gauge across all label children (0 if the
        metric does not exist — reporters read optimistically)."""
        m = self._metrics.get(name)
        if m is None or not isinstance(m, (Counter, Gauge)):
            return 0
        return m.total()

    def percentiles(self, name: str, qs=(50, 95, 99)) -> Dict[str, float]:
        """Merged-percentile summary of a histogram across label children."""
        m = self._metrics.get(name)
        vals = m.all_values() if isinstance(m, Histogram) else []
        from .trace import percentiles as _p
        return _p(vals, qs)


# ---------------------------------------------------------------------------
# legacy `.stats` compatibility
# ---------------------------------------------------------------------------

class StatsView(_MappingABC):
    """Read-only dict-like view: legacy stat names -> live registry reads.

    ``engine.stats["preemptions"]`` (and ``dict(engine.stats)``,
    ``.items()``, ``in``) keep working, but the numbers come from the
    registry — there is exactly one copy of every count.
    """

    def __init__(self, getters: Mapping[str, Callable[[], float]]):
        self._getters = dict(getters)

    def __getitem__(self, key: str):
        return self._getters[key]()

    def __iter__(self):
        return iter(self._getters)

    def __len__(self):
        return len(self._getters)

    def __repr__(self):
        return repr({k: g() for k, g in self._getters.items()})
