"""Span timelines: ring-buffered begin/end spans over the serving hot
path.

Counters/histograms (``obs.metrics``) answer *how much*; spans answer
*where the time went inside a step*. A :class:`SpanRecorder` keeps a
bounded ring of completed :class:`Span` records — begin/end pairs with
implicit parent links (the serving control plane is single-threaded per
replica, so an open-span stack gives correct nesting for free), plus
zero-duration *instant* marks for point events (a prefix hit, a COW
fork, a quarantine). Every record can carry a request ``uid`` and the
recorder's ``replica`` id, so one request's life can be followed across
an admission on replica 0, a chaos kill, and a replay on replica 1.

Timestamps are ``time.perf_counter()`` — NOT the engine's injected
``clock`` (the chaos harness's stalled clock must see exactly its two
reads per step; spans never touch it). All recorders in one process
share the perf_counter epoch, which is what lets ``obs.export`` merge
multi-replica timelines onto one axis.

Disabled recorders (``SpanRecorder(enabled=False)``, or the shared
module-level :data:`NOOP`) make every call a cheap early return — the
``span()`` context manager hands back one shared singleton, no
allocation per call.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = ["Span", "SpanRecorder", "NOOP"]


@dataclass
class Span:
    """One completed span (``kind='span'``) or point event
    (``kind='instant'``, where ``t1 == t0``)."""
    name: str
    t0: float
    t1: float
    sid: int                          # process-unique span id
    parent: Optional[int]             # sid of the enclosing open span
    uid: Optional[int] = None         # request uid, when one is in scope
    replica: Optional[int] = None     # recorder's replica id
    kind: str = "span"
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _Token:
    """Mutable handle for an open span; ``tok.args[...] = v`` annotates
    the span before it closes."""
    __slots__ = ("name", "t0", "sid", "parent", "uid", "args")

    def __init__(self, name, t0, sid, parent, uid, args):
        self.name = name
        self.t0 = t0
        self.sid = sid
        self.parent = parent
        self.uid = uid
        self.args = args


# Shared token handed out by disabled recorders. Its args dict is shared
# and never read — instrumentation sites may write a bounded set of keys
# into it without allocating anything per call.
_NOOP_TOKEN = _Token("", 0.0, 0, None, None, {})


class _SpanCtx:
    __slots__ = ("_rec", "tok")

    def __init__(self, rec, tok):
        self._rec = rec
        self.tok = tok

    def __enter__(self):
        return self.tok

    def __exit__(self, *exc):
        self._rec.end(self.tok)
        return False


class _NoopCtx:
    __slots__ = ()

    def __enter__(self):
        return _NOOP_TOKEN

    def __exit__(self, *exc):
        return False


_NOOP_CTX = _NoopCtx()

_SIDS = itertools.count(1)   # process-unique so merged exports never collide


class SpanRecorder:
    """Bounded ring of completed spans for one replica's control plane.

    Single-threaded by design (one recorder per replica, used from that
    replica's step loop); the open-span stack provides parent links.
    """

    def __init__(self, enabled: bool = True, maxlen: int = 65536,
                 replica: Optional[int] = None, clock=time.perf_counter):
        self.enabled = bool(enabled)
        self.replica = replica
        self._clock = clock
        self._ring: deque = deque(maxlen=maxlen)
        self._stack: List[_Token] = []
        self.n_recorded = 0          # total ever; drops = n_recorded - len()

    # -- recording -----------------------------------------------------------

    def begin(self, name: str, uid: Optional[int] = None, **args) -> _Token:
        if not self.enabled:
            return _NOOP_TOKEN
        tok = _Token(name, self._clock(), next(_SIDS),
                     self._stack[-1].sid if self._stack else None,
                     uid, dict(args) if args else {})
        self._stack.append(tok)
        return tok

    def end(self, tok: _Token) -> None:
        if not self.enabled or tok is _NOOP_TOKEN:
            return
        t1 = self._clock()
        if self._stack and self._stack[-1] is tok:
            self._stack.pop()
        else:                        # tolerate out-of-order ends
            try:
                self._stack.remove(tok)
            except ValueError:
                pass
        self._append(Span(tok.name, tok.t0, t1, tok.sid, tok.parent,
                          uid=tok.uid, replica=self.replica, kind="span",
                          args=tok.args))

    def span(self, name: str, uid: Optional[int] = None, **args):
        """Context manager; yields the token (annotate via ``tok.args``)."""
        if not self.enabled:
            return _NOOP_CTX
        return _SpanCtx(self, self.begin(name, uid=uid, **args))

    def instant(self, name: str, uid: Optional[int] = None, **args) -> None:
        if not self.enabled:
            return
        t = self._clock()
        self._append(Span(name, t, t, next(_SIDS),
                          self._stack[-1].sid if self._stack else None,
                          uid=uid, replica=self.replica, kind="instant",
                          args=dict(args) if args else {}))

    def complete(self, name: str, t0: float, t1: float,
                 uid: Optional[int] = None, parent: Optional[int] = None,
                 **args) -> Optional[int]:
        """Record a span retroactively from explicit timestamps (used
        when the decision to record is only known after the fact, and by
        golden tests that need deterministic times). Returns the sid."""
        if not self.enabled:
            return None
        sid = next(_SIDS)
        self._append(Span(name, float(t0), float(t1), sid, parent,
                          uid=uid, replica=self.replica, kind="span",
                          args=dict(args) if args else {}))
        return sid

    def _append(self, rec: Span) -> None:
        self._ring.append(rec)
        self.n_recorded += 1

    # -- reading -------------------------------------------------------------

    def snapshot(self) -> List[Span]:
        """Completed records, oldest first (open spans are not included)."""
        return list(self._ring)

    @property
    def dropped(self) -> int:
        return self.n_recorded - len(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self._stack.clear()


#: Shared disabled recorder — the default for every instrumented class,
#: so un-armed deployments pay one ``if not self.enabled`` per call site.
NOOP = SpanRecorder(enabled=False, maxlen=1)
