"""Serving observability: a labelled metrics registry, per-request
lifecycle traces, kernel profiling hooks, a live embedding-quality
probe, and the launcher's reporter.

The registry (``obs.metrics``) is the single source of truth for every
counter the serving stack used to keep in ad-hoc ``stats`` dicts —
those dicts survive as :class:`~repro.obs.metrics.StatsView` compat
views reading straight from the registry. Traces (``obs.trace``) stamp
each request's queued → admitted → prefill → first-token → decode →
done lifecycle (plus preemption / restore / migration events) and
derive TTFT / TPOT / queue-time / e2e latencies. ``obs.profiling``
annotates kernel dispatches with ``jax.named_scope`` and, opt-in,
times each eager dispatch into the registry. ``obs.quality`` samples
the paper's row-statistics (Def. 1 calibration) from live serving
params. ``obs.report`` owns all human-facing printing for the serving
launcher. ``obs.spans`` records ring-buffered begin/end span timelines
over the serving hot path and ``obs.export`` renders them as
Chrome-trace JSON that Perfetto loads directly.
"""
from .metrics import (Counter, Gauge, Histogram,        # noqa: F401
                      MetricsRegistry, StatsView)
from .trace import Trace, latency_summary, percentiles  # noqa: F401
from .profiling import (annotate, dispatch,             # noqa: F401
                        disable_kernel_timing, enable_kernel_timing)
from .spans import Span, SpanRecorder                   # noqa: F401
from .export import chrome_trace, dump_chrome_trace     # noqa: F401
