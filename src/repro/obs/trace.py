"""Per-request lifecycle traces and latency aggregation.

A :class:`Trace` is an append-only list of ``(event, t)`` stamps taken
with ``time.perf_counter()`` (monotonic — wall-clock ``time.time()``
steps corrupt TTFT/TPOT, which is why the engines stamp perf_counter
everywhere). The canonical lifecycle is

    queued -> admitted [-> prefix_hit] -> prefill [-> chunked_prefill...]
           -> first_token -> decode -> done

with ``preempted`` / ``restored`` / ``migrated`` free to interleave
(possibly repeatedly) between ``admitted`` and ``done``. ``prefix_hit``
marks a fresh admission that attached cached prefix pages (stamped once,
right after ``admitted``); ``chunked_prefill`` marks every prefill
continuation chunk under a chunk policy (repeatable, but its FIRST
occurrence still sits between ``prefill`` and ``first_token``). Derived
latencies:

    queue_time = first admitted - queued       (admission wait)
    ttft       = first_token    - queued       (time to first token)
    tpot       = (done - first_token) / (n_tokens - 1)
    e2e        = done           - queued

``latency_summary`` folds a batch of finished requests into
p50/p95/p99 percentiles of each — the numbers SLO-aware scheduling and
the serving bench report.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# lifecycle order used by monotonicity checks. ``prefix_hit`` and
# ``chunked_prefill`` are optional milestones (prefix-sharing subsystem);
# ``chunked_prefill`` repeats per continuation chunk, but like ``decode``
# its first occurrence is still pinned in canonical order.
LIFECYCLE = ("queued", "admitted", "prefix_hit", "prefill",
             "chunked_prefill", "first_token", "decode", "done")


@dataclass
class Trace:
    """Append-only event stamps for one request."""
    uid: int = -1
    events: List[Tuple[str, float]] = field(default_factory=list)

    def stamp(self, name: str, t: Optional[float] = None) -> float:
        t = time.perf_counter() if t is None else t
        self.events.append((name, t))
        return t

    def first(self, name: str) -> Optional[float]:
        for n, t in self.events:
            if n == name:
                return t
        return None

    def last(self, name: str) -> Optional[float]:
        for n, t in reversed(self.events):
            if n == name:
                return t
        return None

    def count(self, name: str) -> int:
        return sum(1 for n, _ in self.events if n == name)

    # -- derived latencies ---------------------------------------------------

    def _delta(self, a: str, b: str) -> Optional[float]:
        ta, tb = self.first(a), self.first(b)
        return None if ta is None or tb is None else tb - ta

    @property
    def queue_time(self) -> Optional[float]:
        return self._delta("queued", "admitted")

    @property
    def ttft(self) -> Optional[float]:
        return self._delta("queued", "first_token")

    @property
    def e2e(self) -> Optional[float]:
        return self._delta("queued", "done")

    def tpot(self, n_tokens: int) -> Optional[float]:
        d = self._delta("first_token", "done")
        if d is None or n_tokens <= 1:
            return None
        return d / (n_tokens - 1)

    # -- validation ----------------------------------------------------------

    def monotonic(self) -> bool:
        """All stamps non-decreasing in arrival order AND the lifecycle
        milestones (first occurrence each) appear in canonical order —
        checked by event POSITION, not just time, so two milestones
        stamped in the same instant still must arrive in order."""
        times = [t for _, t in self.events]
        if any(b < a for a, b in zip(times, times[1:])):
            return False
        pos = {}
        for i, (n, _) in enumerate(self.events):
            pos.setdefault(n, i)
        idx = [pos[n] for n in LIFECYCLE if n in pos]
        return all(b > a for a, b in zip(idx, idx[1:]))


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------

def percentiles(values: Iterable[float],
                qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """{'p50': ..., 'p95': ...} via nearest-rank on the sorted sample;
    NaNs for an empty sample (the caller prints/serializes them as-is)."""
    srt = sorted(values)
    out: Dict[str, float] = {}
    for q in qs:
        key = f"p{q:g}"
        if not srt:
            out[key] = float("nan")
        else:
            idx = min(len(srt) - 1, max(0, round(q / 100.0 * (len(srt) - 1))))
            out[key] = srt[idx]
    return out


def latency_summary(requests, qs: Sequence[float] = (50, 95, 99)) -> Dict:
    """Percentile summary over finished requests (uses traces when
    present, the ``t_submit``/``t_first``/``t_done`` stamps otherwise).
    All values in seconds."""
    ttft, tpot, queue, e2e = [], [], [], []
    n_tokens = 0
    for r in requests:
        if not getattr(r, "done", False):
            continue
        n = len(getattr(r, "out_tokens", ()) or ())
        n_tokens += n
        tr = getattr(r, "trace", None)
        if tr is not None and tr.first("done") is not None:
            if tr.ttft is not None:
                ttft.append(tr.ttft)
            tp = tr.tpot(n)
            if tp is not None:
                tpot.append(tp)
            if tr.queue_time is not None:
                queue.append(tr.queue_time)
            if tr.e2e is not None:
                e2e.append(tr.e2e)
        else:
            ttft.append(r.t_first - r.t_submit)
            if n > 1:
                tpot.append((r.t_done - r.t_first) / (n - 1))
            e2e.append(r.t_done - r.t_submit)
    return {"requests": len(ttft), "tokens": n_tokens,
            "ttft_s": percentiles(ttft, qs),
            "tpot_s": percentiles(tpot, qs),
            "queue_s": percentiles(queue, qs),
            "e2e_s": percentiles(e2e, qs)}
