"""Live embedding-quality probe: the paper's row statistics sampled
from *serving* params.

``bench_coherence`` measures the structured-spinner quality parameters
(chi / mu / mu~, Defs. 2-4 of the paper) offline; a live engine has
until now had no signal that the projections it is actually serving
are still calibrated. This probe samples the cheap Def. 1 row
statistics — per-row mean and variance of the materialized structured
block, which must look N(0, I)-row-like for the concentration theorem
(Thm 10) to hold — from one representative head of the live SRF
pipeline params, and the engine publishes them as gauges
(``srf_row_mean_abs_max`` / ``srf_row_var_err_max``): a drift away
from (0, 1) rows means drifted embedding quality, visible per scrape
instead of per offline bench.

The expensive coherence-graph parameters stay available behind
``full=True`` (one ``core.coherence.pmodel_stats`` jacobian per block)
for offline/debug use; the engine's periodic sampling uses the cheap
path only.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

#: Default drift tolerance on the Def. 1 row moments: rows of a healthy
#: structured block are ~N(0, 1), so |row mean| and |row var - 1| both
#: sit well under this for any calibrated pipeline.
DRIFT_TOL = 0.5


def moments_drifted(stats: Optional[Dict[str, float]],
                    tol: float = DRIFT_TOL) -> bool:
    """Whether a probe's row-gaussianity moments are out of tolerance
    (the engine emits a ``quality_drift`` registry event when so)."""
    if not stats:
        return False
    return (stats.get("srf_row_mean_abs_max", 0.0) > tol
            or stats.get("srf_row_var_err_max", 0.0) > tol)


def _find_srf_params(params):
    """First layer's per-head SRF pipeline params inside a serving
    param tree (leaves stacked (layers, heads, ...)), or None."""
    for seg in params.get("segments", []):
        attn = seg.get("attn") if isinstance(seg, dict) else None
        if isinstance(attn, dict) and "srf" in attn:
            return attn["srf"]
    return None


def srf_quality_probe(cfg, params, full: bool = False,
                      layer: int = 0, head: int = 0
                      ) -> Optional[Dict[str, float]]:
    """Row-statistics report for the SRF embedding a live engine serves.

    Returns None for non-SRF configs. Cheap by default (one block
    materialization per pipeline block, no jacobians):

      srf_row_mean_abs_max — max over blocks of max |row mean|
      srf_row_var_err_max  — max over blocks of max |row var - 1|

    ``full=True`` adds chi / mu / mu~ per block via
    ``core.coherence.pmodel_stats`` (EXPENSIVE: jacfwd over the budget
    of randomness; offline use only).
    """
    if getattr(cfg, "attn_impl", None) != "srf":
        return None
    sp = _find_srf_params(params)
    if sp is None:
        return None
    from repro.models.attention import srf_cfg     # lazy: avoid cycles
    pipe = srf_cfg(cfg).pipeline
    # one representative (layer, head): quality parameters are identical
    # in distribution across heads (independent same-spec pipelines)
    one = jax.tree_util.tree_map(lambda a: np.asarray(a)[layer, head], sp)
    moments = pipe.row_gaussianity_moments(tuple(dict(p) for p in one))
    mean_abs = max(float(np.max(np.abs(np.asarray(m)))) for m, _ in moments)
    var_err = max(float(np.max(np.abs(np.asarray(v) - 1.0)))
                  for _, v in moments)
    out = {"srf_row_mean_abs_max": mean_abs,
           "srf_row_var_err_max": var_err}
    if full:
        from repro.core import coherence
        for i, (blk, p) in enumerate(zip(pipe.blocks,
                                         tuple(dict(p) for p in one))):
            st = coherence.block_stats(blk, p)
            for k in ("chi", "mu", "mu_tilde"):
                out[f"block{i}_{k}"] = st[k]
    return out
