"""Serving reporter: the ONE place the serving stack prints from.

``launch/serve.py`` and everything under ``serving/`` are lint-pinned
print-free (``tests/test_obs.py::test_no_bare_print_in_serving``); all
human-facing output routes through a :class:`Reporter` so the metrics
report and the old ad-hoc summary lines cannot drift apart — both read
the same registry.

Usage (what ``serve.py --metrics`` does):

    reporter = Reporter()
    on_step = reporter.periodic(registry, every_s=2.0)
    engine.run(on_step=on_step)            # one-line report every 2 s
    reporter.final(registry, done)         # latency percentiles + dump
"""
from __future__ import annotations

import math
import sys
import time
from typing import Callable, IO, Iterable, Optional

from . import trace as trace_lib


def _fmt_ms(v: float) -> str:
    return "nan" if v is None or math.isnan(v) else f"{v * 1e3:.1f}"


class Reporter:
    """Formats and prints serving telemetry read from a registry."""

    def __init__(self, stream: Optional[IO[str]] = None, prefix: str = ""):
        self.stream = stream or sys.stdout
        self.prefix = prefix

    def line(self, msg: str) -> None:
        print(self.prefix + msg, file=self.stream, flush=True)

    # -- periodic one-liner --------------------------------------------------

    def periodic(self, registry, every_s: float = 2.0
                 ) -> Callable[[object], None]:
        """Returns an ``on_step`` callback: every ``every_s`` seconds of
        engine stepping, print one line of live registry state."""
        state = {"t0": time.perf_counter(), "last": time.perf_counter(),
                 "last_tokens": 0}

        def on_step(_engine) -> None:
            now = time.perf_counter()
            if now - state["last"] < every_s:
                return
            tokens = registry.value_sum("engine_tokens_total")
            dt = now - state["last"]
            rate = (tokens - state["last_tokens"]) / dt if dt > 0 else 0.0
            state["last"], state["last_tokens"] = now, tokens
            self.line(
                f"[metrics] t={now - state['t0']:.1f}s tokens={int(tokens)} "
                f"tok/s={rate:.1f} "
                f"done={int(registry.value_sum('engine_requests_total'))} "
                f"running={int(registry.value_sum('sched_running'))} "
                f"waiting={int(registry.value_sum('sched_waiting'))} "
                f"free_pages={int(registry.value_sum('sched_free_pages'))} "
                f"preempt={int(registry.value_sum('engine_preemptions_total'))} "
                f"migrations="
                f"{int(registry.value_sum('router_migrations_total'))}"
                + self._prefix_fragment(registry)
                + self._ft_fragment(registry))
        return on_step

    @staticmethod
    def _prefix_fragment(registry) -> str:
        """Prefix-cache hit rate for the periodic line — only printed
        once any lookup has happened, so cache-less runs keep the exact
        pre-prefix line format."""
        lookups = registry.value_sum("prefix_lookups_total")
        if not lookups:
            return ""
        hits = registry.value_sum("prefix_hits_total")
        return f" hit_rate={hits / lookups:.2f}"

    @staticmethod
    def _ft_fragment(registry) -> str:
        """Fault-tolerance tail for the periodic line — only printed once
        any FT transition has happened, so non-FT runs keep the exact
        pre-FT line format."""
        dead = registry.value_sum("router_dead_replicas")
        degraded = registry.value_sum("router_degraded")
        counts = {k: int(registry.value_sum(f"router_{k}_total"))
                  for k in ("quarantined", "rescued", "replayed", "shed",
                            "revived", "failed")}
        counts["expired"] = int(registry.value_sum("engine_expired_total"))
        if not dead and not degraded and not any(counts.values()):
            return ""
        frag = (f" dead={int(dead)}"
                f" state={'degraded' if degraded else 'ok'}")
        frag += "".join(f" {k}={v}" for k, v in counts.items() if v)
        return frag

    # -- final dump ----------------------------------------------------------

    def final(self, registry, requests: Iterable = (),
              dump_path: Optional[str] = None) -> None:
        """Per-request latency percentiles + counter totals, all from the
        single registry / the finished requests' traces. ``dump_path``
        additionally writes the Prometheus text exposition there and the
        JSONL event stream to ``<dump_path>.events.jsonl``."""
        summ = trace_lib.latency_summary(requests)
        self.line("[metrics] ---- final ----")
        self.line(
            f"[metrics] requests={int(registry.value_sum('engine_requests_total'))} "
            f"tokens={int(registry.value_sum('engine_tokens_total'))} "
            f"prefill_steps="
            f"{int(registry.value_sum('engine_prefill_steps_total'))} "
            f"decode_steps="
            f"{int(registry.value_sum('engine_decode_steps_total'))} "
            f"preemptions="
            f"{int(registry.value_sum('engine_preemptions_total'))}")
        for kind in ("ttft", "tpot", "queue", "e2e"):
            pct = summ[f"{kind}_s"]
            self.line(f"[metrics] {kind}_ms " + " ".join(
                f"{k}={_fmt_ms(v)}" for k, v in pct.items()))
        mig = registry.value_sum("router_migrations_total")
        sub = registry.value_sum("router_submitted_total")
        if sub:
            heads = registry.snapshot()["gauges"].get("router_headroom", {})
            self.line(f"[metrics] router submitted={int(sub)} "
                      f"migrations={int(mig)} headroom={heads}")
        lookups = registry.value_sum("prefix_lookups_total")
        if lookups:
            self.line(
                f"[metrics] prefix lookups={int(lookups)} "
                f"hits={int(registry.value_sum('prefix_hits_total'))} "
                f"hit_rate={registry.value_sum('prefix_hits_total') / lookups:.2f} "
                f"hit_tokens="
                f"{int(registry.value_sum('prefix_hit_tokens_total'))}")
        ft = self._ft_fragment(registry)
        if ft:
            self.line("[metrics] ft" + ft)
        qual = registry.snapshot()["gauges"].get("srf_quality", {})
        if qual:
            self.line(f"[metrics] srf_quality {qual}")
        kern = registry.snapshot()["histograms"].get(
            "kernel_dispatch_seconds", {})
        for lbl, cs in sorted(kern.items()):
            self.line(f"[metrics] kernel {lbl} n={cs['count']} "
                      f"mean_ms={_fmt_ms(cs['sum'] / max(1, cs['count']))}")
        if dump_path:
            with open(dump_path, "w") as f:
                f.write(registry.prometheus_text())
            with open(dump_path + ".events.jsonl", "w") as f:
                n = registry.dump_events_jsonl(f)
            self.line(f"[metrics] dumped {dump_path} "
                      f"(+{n} events -> {dump_path}.events.jsonl)")
