"""Kernel profiling hooks for ``kernels/ops.py`` dispatch sites.

Two layers, both zero-cost on the jit'd serving hot path:

* :func:`annotate` / :func:`dispatch` wrap every kernel call in a
  ``jax.named_scope`` so the op shows up named in HLO dumps, profiler
  timelines (``jax.profiler.trace``) and ``jax.debug`` output. Scopes
  are trace-time only — compiled programs pay nothing.
* **Opt-in per-dispatch timing**: after :func:`enable_kernel_timing`,
  every *eager* kernel dispatch is timed to completion
  (``block_until_ready``) and recorded into the registry's
  ``kernel_dispatch_seconds{kernel=...}`` histogram. Calls under a jit
  trace are detected (tracer leaves) and skipped — a Python timer
  around an abstract trace is meaningless, and blocking inside a trace
  would be wrong. This is a debugging/bench instrument: forcing a sync
  per dispatch serializes the device pipeline, so it stays off unless
  explicitly enabled (see serving/README.md for overhead expectations).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Optional

import jax

_timing_registry = None                    # None = timing off


def enable_kernel_timing(registry) -> None:
    """Route per-dispatch timings into ``registry`` (a
    ``MetricsRegistry``). Eager dispatches only; jit traces skip."""
    global _timing_registry
    _timing_registry = registry


def disable_kernel_timing() -> None:
    global _timing_registry
    _timing_registry = None


def kernel_timing_enabled() -> bool:
    return _timing_registry is not None


@contextmanager
def annotate(name: str):
    """Named-scope annotation for a kernel region (profiler-visible)."""
    with jax.named_scope(name):
        yield


def _has_tracer(tree) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(tree))


def dispatch(name: str, fn: Callable[[], object],
             registry: Optional[object] = None):
    """Run one kernel dispatch under ``jax.named_scope(name)``; when
    timing is enabled and the call is eager (no tracer in the result),
    block until the result is ready and record the wall time.

    ``fn`` is a zero-arg closure so the timer brackets the actual
    dispatch, not argument preparation in the caller.
    """
    reg = registry if registry is not None else _timing_registry
    timing = reg is not None
    t0 = time.perf_counter() if timing else 0.0
    with jax.named_scope(name):
        out = fn()
    if timing and not _has_tracer(out):
        jax.block_until_ready(out)
        reg.histogram(
            "kernel_dispatch_seconds",
            "eager kernel dispatch wall time (opt-in, serializing)",
            ("kernel",),
        ).labels(kernel=name).observe(time.perf_counter() - t0)
    return out
