"""Explicit cross-pod collectives: compressed gradient all-reduce.

Under plain pjit the cross-pod gradient mean is an XLA-inserted all-reduce
over the full gradient bytes — the dominant DCN cost at multi-pod scale.
``compressed_pod_mean`` replaces it with the paper's structured sketch:

    shard_map over 'pod' (data/model stay auto-partitioned):
        y   = sketch(grad + err)        m/n of the bytes
        y'  = pmean(y, 'pod')           the ONLY cross-pod traffic
        g'  = unsketch(y')              unbiased; err absorbs the residual

Wire bytes drop by cc.ratio; the sketch projection itself is O(n log n)
FFT (or the Pallas implicit-tile kernel on TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import compression as C


def pod_mean_plain(grads, mesh):
    """Baseline: uncompressed cross-pod mean via shard_map (for A/B)."""
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def f(g):
        return jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), g)
    return jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                         axis_names={"pod"})(grads)


def compressed_pod_mean(grads, err, mesh, cc: C.CompressionConfig,
                        step: int = 0) -> Tuple[Dict, Dict]:
    """-> (mean_grads_reconstructed, new_error). Requires a 'pod' axis.
    ``step`` (traced ok) rotates the sketch so the null space is re-drawn
    every step (error feedback then covers all directions over time)."""
    def f(g, e):
        sk, recon, new_err = C.roundtrip_with_feedback(g, e, cc, step)
        sk_mean = jax.tree.map(lambda y: jax.lax.pmean(y, "pod"), sk)
        g_mean = C.decompress_tree(sk_mean, g, cc, step)
        g_mean = jax.tree.map(lambda a, b: a.astype(b.dtype), g_mean, g)
        return g_mean, new_err

    return jax.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                         out_specs=(P(), P()), axis_names={"pod"})(grads, err)
