"""Explicit cross-pod collectives: compressed gradient all-reduce.

Under plain pjit the cross-pod gradient mean is an XLA-inserted all-reduce
over the full gradient bytes — the dominant DCN cost at multi-pod scale.
``compressed_pod_mean`` replaces it with the paper's structured sketch:

    shard_map over 'pod' (data/model stay auto-partitioned):
        y   = sketch(grad + err)        m/n of the bytes
        y'  = pmean(y, 'pod')           the ONLY cross-pod traffic
        g'  = unsketch(y')              unbiased; err absorbs the residual

Wire bytes drop by cc.ratio; the sketch projection itself is O(n log n)
FFT (or the Pallas implicit-tile kernel on TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import compression as C


def _pod_shard_map(f, mesh, in_specs, out_specs):
    """shard_map manual over 'pod' only, across jax API generations:
    ``jax.shard_map(..., axis_names=...)`` (new) vs
    ``jax.experimental.shard_map.shard_map(..., auto=...)`` (0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names={"pod"})
    from jax.experimental.shard_map import shard_map
    # 0.4.x: the auto-axes path is unimplemented in eager mode and its
    # SPMD lowering is unstable, so go fully manual: the body is local
    # compute + a pod-pmean, and with replicated in_specs the data/model
    # axes just repeat the same deterministic work — same results.
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def pod_mean_plain(grads, mesh):
    """Baseline: uncompressed cross-pod mean via shard_map (for A/B)."""
    def f(g):
        return jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), g)
    return _pod_shard_map(f, mesh, P(), P())(grads)


def compressed_pod_mean(grads, err, mesh, cc: C.CompressionConfig,
                        step: int = 0) -> Tuple[Dict, Dict]:
    """-> (mean_grads_reconstructed, new_error). Requires a 'pod' axis.
    ``step`` (traced ok) rotates the sketch so the null space is re-drawn
    every step (error feedback then covers all directions over time)."""
    def f(g, e):
        sk, recon, new_err = C.roundtrip_with_feedback(g, e, cc, step)
        sk_mean = jax.tree.map(lambda y: jax.lax.pmean(y, "pod"), sk)
        g_mean = C.decompress_tree(sk_mean, g, cc, step)
        g_mean = jax.tree.map(lambda a, b: a.astype(b.dtype), g_mean, g)
        return g_mean, new_err

    return _pod_shard_map(f, mesh, (P(), P()), (P(), P()))(grads, err)
