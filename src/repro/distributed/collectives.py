"""Explicit cross-pod collectives: compressed gradient all-reduce.

Under plain pjit the cross-pod gradient mean is an XLA-inserted all-reduce
over the full gradient bytes — the dominant DCN cost at multi-pod scale.
``compressed_pod_mean`` replaces it with the paper's structured sketch:

    shard_map over 'pod' (data/model stay auto-partitioned):
        y   = sketch(grad + err)        m/n of the bytes
        y'  = pmean(y, 'pod')           the ONLY cross-pod traffic
        g'  = unsketch(y')              unbiased; err absorbs the residual

Wire bytes drop by cc.ratio; the sketch projection itself is O(n log n)
FFT (or the Pallas implicit-tile kernel on TPU).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import compression as C


def axis_shard_map(f, mesh, in_specs, out_specs, axes):
    """shard_map manual over ``axes``, across jax API generations:
    ``jax.shard_map(..., axis_names=...)`` (new) vs
    ``jax.experimental.shard_map.shard_map(..., auto=...)`` (0.4.x).

    Used by the compressed cross-pod gradient mean (axes={'pod'}) and the
    mesh-sharded paged serving step (axes={'model', ...}); the body sees
    per-shard blocks of anything ``in_specs`` splits and stitches partial
    results with explicit collectives (``lax.psum`` / ``stitch_heads``).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(axes))
    from jax.experimental.shard_map import shard_map
    # 0.4.x: the auto-axes path is unimplemented in eager mode and its
    # SPMD lowering is unstable, so go fully manual: the body is local
    # compute + explicit collectives over ``axes``, and with replicated
    # in_specs the remaining axes just repeat the same deterministic
    # work — same results.
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def stitch_heads(x, axis: str = "model", head_dim: int = 1):
    """Concat-stitch per-shard head blocks back into the full head axis
    (shard order == contiguous global head order under the column-
    parallel q/k/v split). Used instead of a row-parallel wo + psum by
    the mesh serving step: the replicated wo contraction then runs in
    exactly the single-host reduction order, so greedy decode tokens are
    BIT-IDENTICAL to the unsharded engine — a psum re-associates the
    d_model sum and can flip near-tie argmaxes."""
    return jax.lax.all_gather(x, axis, axis=head_dim, tiled=True)


def _pod_shard_map(f, mesh, in_specs, out_specs):
    return axis_shard_map(f, mesh, in_specs, out_specs, ("pod",))


def pod_mean_plain(grads, mesh):
    """Baseline: uncompressed cross-pod mean via shard_map (for A/B)."""
    def f(g):
        return jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), g)
    return _pod_shard_map(f, mesh, P(), P())(grads)


def compressed_pod_mean(grads, err, mesh, cc: C.CompressionConfig,
                        step: int = 0) -> Tuple[Dict, Dict]:
    """-> (mean_grads_reconstructed, new_error). Requires a 'pod' axis.
    ``step`` (traced ok) rotates the sketch so the null space is re-drawn
    every step (error feedback then covers all directions over time)."""
    def f(g, e):
        sk, recon, new_err = C.roundtrip_with_feedback(g, e, cc, step)
        sk_mean = jax.tree.map(lambda y: jax.lax.pmean(y, "pod"), sk)
        g_mean = C.decompress_tree(sk_mean, g, cc, step)
        g_mean = jax.tree.map(lambda a, b: a.astype(b.dtype), g_mean, g)
        return g_mean, new_err

    return _pod_shard_map(f, mesh, (P(), P()), (P(), P()))(grads, err)
