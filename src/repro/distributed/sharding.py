"""Logical sharding rules: param/optimizer/batch/cache PartitionSpecs.

Axis roles (launch/mesh.py):
    pod    slow-link (DCN) data parallelism — compressed collectives
    data   ICI data parallelism + ZeRO-1 shards + long-context seq sharding
    model  tensor parallelism (heads / ff / vocab / experts)

Rules are path+shape based and DEGRADE to replication whenever a dim does
not divide the axis (e.g. hymba's 25 heads or qwen2-vl's 12 heads under
TP=16) — the framework never refuses an arch for divisibility.
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fits(shape, dim: int, mesh: Mesh, names) -> bool:
    if dim >= len(shape):
        return False
    total = 1
    for n in (names if isinstance(names, tuple) else (names,)):
        total *= axis_size(mesh, n)
    return shape[dim] % total == 0 and shape[dim] >= total


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


# --- parameter rules --------------------------------------------------------

# (regex on path, spec builder on the TRAILING dims). Stacked layer params
# (segments/* , encoder/*) get a leading None prepended automatically.
def _trailing_rule(path: str, shape, mesh: Mesh) -> P:
    mdl = "model"

    def col(dim_in=0, dim_out=1):           # column parallel (d, out)
        return _mk(shape, {dim_out: mdl}, mesh)

    def row(dim_in=0, dim_out=1):           # row parallel (in, d)
        return _mk(shape, {dim_in: mdl}, mesh)

    if re.search(r"embed/tok$", path):
        return _mk(shape, {0: mdl}, mesh)                    # (V, d)
    if re.search(r"(^|/)head$", path):
        return _mk(shape, {1: mdl}, mesh)                    # (d, V)
    if re.search(r"moe/router$", path):
        return P(*([None] * len(shape)))                     # tiny, replicated
    if re.search(r"moe/(wi|wg)$", path):
        return _mk(shape, {0: mdl}, mesh)                    # (E, d, ffe) EP
    if re.search(r"moe/wo$", path):
        return _mk(shape, {0: mdl}, mesh)                    # (E, ffe, d) EP
    if re.search(r"(mlp|shared)/(wi|wg)$", path):
        return col()                                         # (d, ff)
    if re.search(r"(mlp|shared)/wo$", path):
        return row()                                         # (ff, d)
    if re.search(r"(attn|cross)/(wq|wuk|wuv)$", path):
        return col()
    if re.search(r"(attn|cross)/(wk|wv)$", path):
        return col()
    if re.search(r"(attn|cross)/wo$", path):
        return row()
    if re.search(r"attn/(wdkv|wkpe)$", path):
        return P(*([None] * len(shape)))                     # small latents
    if re.search(r"ssm/(wz|wx)$", path):
        return col()
    if re.search(r"ssm/(wbc|wdt)$", path):
        return P(*([None] * len(shape)))
    if re.search(r"ssm/conv_x$", path):
        return _mk(shape, {1: mdl}, mesh)                    # (k, di)
    if re.search(r"ssm/out_proj$", path):
        return row()
    if re.search(r"srf/", path):
        return P(*([None] * len(shape)))                     # O(n) generators
    if re.search(r"frontend/adapter$", path):
        return col()
    return P(*([None] * len(shape)))                         # norms, biases


def _mk(shape, placements: Dict[int, str], mesh: Mesh) -> P:
    out = [None] * len(shape)
    for dim, name in placements.items():
        if _fits(shape, dim, mesh, name):
            out[dim] = name
    return P(*out)


_STACKED = re.compile(r"^(segments/\d+|encoder)/")


def param_specs(params, mesh: Mesh) -> Dict:
    def f(path, x):
        ps = _path_str(path)
        shape = x.shape
        if _STACKED.match(ps):
            inner = _trailing_rule(ps, shape[1:], mesh)
            return P(None, *inner)
        return _trailing_rule(ps, shape, mesh)
    return jax.tree_util.tree_map_with_path(f, params)


def zero1_specs(params, pspecs, mesh: Mesh) -> Dict:
    """Optimizer-moment specs: param spec + shard the first free dim over
    'data' (ZeRO-1). Falls back to the param spec if nothing divides."""
    data = axis_size(mesh, "data")

    def f(x, spec):
        if data <= 1:
            return spec
        entries = list(spec) + [None] * (x.ndim - len(spec))
        for dim in range(x.ndim):
            if entries[dim] is None and x.shape[dim] % data == 0 \
                    and x.shape[dim] >= 4 * data:
                entries[dim] = "data"
                return P(*entries)
        return spec
    return jax.tree.map(f, params, pspecs)


def opt_state_specs(opt_state, params, pspecs, mesh: Mesh) -> Dict:
    z = zero1_specs(params, pspecs, mesh)
    return {"mu": z, "nu": z, "count": P()}


# --- batch / cache / activation rules ----------------------------------------

def batch_specs_tree(batch_specs, mesh: Mesh) -> Dict:
    """Shard dim0 (global batch) over the dp axes when it divides."""
    dp = dp_axes(mesh)

    def f(s):
        if _fits(s.shape, 0, mesh, dp) and len(s.shape) >= 1:
            return P(dp, *([None] * (len(s.shape) - 1)))
        return P(*([None] * len(s.shape)))

    def g(path, s):
        ps = _path_str(path)
        if ps.endswith("pos3"):        # (3, B, L): batch is dim1
            if _fits(s.shape, 1, mesh, dp):
                return P(None, dp, None)
            return P(None, None, None)
        return f(s)
    return jax.tree_util.tree_map_with_path(g, batch_specs)


def cache_specs_tree(cache_specs, cfg, mesh: Mesh) -> Dict:
    """Decode caches: batch over dp; long axes (S for kv/mla, feature m for
    srf) over 'model' when they divide."""
    dp = dp_axes(mesh)

    def f(path, s):
        ps = _path_str(path)
        shape = s.shape
        stacked = 1 if ps.startswith("segments/") else 0   # leading layer dim
        ent = [None] * len(shape)
        if ps.endswith(("k", "v", "k_scale", "v_scale")) and \
                len(shape) - stacked == 4:
            # (L?, B, Hkv, S, hd|1): batch over dp, S over model
            if _fits(shape, stacked + 0, mesh, dp):
                ent[stacked + 0] = dp
            if _fits(shape, stacked + 2, mesh, "model"):
                ent[stacked + 2] = "model"
        elif ps.endswith(("s", "z")) and len(shape) - stacked >= 3:
            # SRF state (L?, B, H, m[, dv]): batch over dp, heads over model
            if _fits(shape, stacked + 0, mesh, dp):
                ent[stacked + 0] = dp
            if _fits(shape, stacked + 1, mesh, "model"):
                ent[stacked + 1] = "model"
        elif ps.endswith(("c", "kpe")) and len(shape) - stacked == 3:
            # MLA latent cache (L?, B, S, dim): batch over dp, S over model
            if _fits(shape, stacked + 0, mesh, dp):
                ent[stacked + 0] = dp
            if _fits(shape, stacked + 1, mesh, "model"):
                ent[stacked + 1] = "model"
        elif ps.endswith(("conv", "ssm")) and len(shape) - stacked >= 3:
            if _fits(shape, stacked + 0, mesh, dp):
                ent[stacked + 0] = dp
            if ps.endswith("ssm") and _fits(shape, stacked + 1, mesh, "model"):
                ent[stacked + 1] = "model"   # ssd heads
        elif ps.endswith("memory"):
            if _fits(shape, 0, mesh, dp):
                ent[0] = dp
        return P(*ent)
    return jax.tree_util.tree_map_with_path(f, cache_specs)


# --- activation constrainer (models/hooks.py) ---------------------------------

def make_constrainer(mesh: Mesh, cfg=None):
    dp = dp_axes(mesh)

    def fn(x, role: str):
        if role == "activation" and x.ndim >= 2:
            if _fits(x.shape, 0, mesh, dp):
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))))
            return x
        if role == "residual" and x.ndim == 3:
            # Megatron sequence parallelism: (B, T, d) -> (dp, 'model', -)
            ent = [None, None, None]
            if _fits(x.shape, 0, mesh, dp):
                ent[0] = dp
            if _fits(x.shape, 1, mesh, "model"):
                ent[1] = "model"
            if ent[1] is None:
                return fn(x, "activation")
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*ent)))
        if role == "logits" and x.ndim == 3:
            ent = [None, None, None]
            if _fits(x.shape, 0, mesh, dp):
                ent[0] = dp
            if _fits(x.shape, 2, mesh, "model"):
                ent[2] = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*ent)))
        if role == "moe_buf" and x.ndim == 4:
            # (B groups, E, C, d): groups on dp, experts on model (EP)
            ent = [None, None, None, None]
            if _fits(x.shape, 0, mesh, dp):
                ent[0] = dp
            if _fits(x.shape, 1, mesh, "model"):
                ent[1] = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*ent)))
        return x
    return fn


def named(mesh: Mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))
