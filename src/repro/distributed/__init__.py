"""repro.distributed subsystem."""
