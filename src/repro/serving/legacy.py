"""Legacy per-slot serving engine — TEST ORACLE ONLY (and the benchmark
baseline ``bench_serving`` measures the paged engine against).

The paged engine in ``serving.engine`` serves every registry family;
nothing routes here in production (``launch/serve.py`` keeps a
``--legacy`` flag purely for A/B runs). The per-slot loop survives
because its simplicity makes it a trustworthy independent
implementation: the cross-engine parity matrix
(``tests/test_engine_parity.py``) pins the paged engine's greedy decode
bit-exactly to this one for every config family.

Requests enter a queue; free slots are filled by prefilling the prompt
into that slot's cache region. All active slots decode in lock-step with
one jit'd serve_step per token (the standard continuous-batching loop,
single-host flavor). Works with every cache family — full KV, MLA latent,
SRF state (the paper's O(m d) cache), SSD state, hybrid, enc-dec (each
:class:`Request` may carry its own ``enc_emb`` frontend features).

For simplicity slots share a common max_len; prefill runs per-request
(batch-1) and writes into the slot. Sampling uses the SAME stateless
per-request keys as the paged engine (``sampler.sample_stateless``:
noise from ``(base_key, uid, token index)``, never from engine state) —
that is what lets the parity matrix pin sampled decode bit-exactly
paged-vs-legacy, not just greedy. EOS or max_new stops.
"""
from __future__ import annotations

import time
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as step_lib
from repro.models import transformer as model_lib
from .engine import Request
from .sampler import sample_stateless as _sample_stateless

warnings.warn(
    "repro.serving.legacy is deprecated; use the paged engine "
    "(repro.serving.Engine — continuous batching over pooled paged "
    "caches, mesh-shardable via Engine(mesh=...)). The per-slot "
    "lock-step engine is kept only as the benchmark baseline.",
    DeprecationWarning, stacklevel=2)


class Engine:
    def __init__(self, cfg, params, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self._prefill = jax.jit(step_lib.make_prefill_step(cfg))
        self._step = jax.jit(step_lib.make_serve_step(cfg))
        # per-slot independent caches (batch=1) stacked lazily
        self.caches = [model_lib.init_serve_cache(cfg, 1, max_len)
                       for _ in range(batch_slots)]
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.stats: Dict[str, float] = {"tokens": 0, "requests": 0}
        # stateless sampling keys: identical derivation to the paged
        # engine (fold_in(fold_in(base, uid), position)), so a request
        # sampled here and there draws the same noise at every token
        self._base_key = jax.random.PRNGKey(seed)

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _pick(self, req: Request, logits: jax.Array) -> int:
        """Sample one token for ``req`` from (V,) logits; batch-1 call of
        the shared stateless sampler (bit-identical to any batched call
        with the same (uid, position) — that is the whole point)."""
        toks = _sample_stateless(
            self._base_key,
            jnp.asarray([req.uid & 0xFFFFFFFF], jnp.uint32),
            jnp.asarray([len(req.out_tokens)], jnp.int32),
            logits[None, :],
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32),
            jnp.asarray([req.top_p], jnp.float32))
        return int(np.asarray(toks)[0])

    def _fill_slots(self, extra_batch: Optional[Dict] = None):
        for i in range(self.slots):
            # loop: a request whose FIRST token already satisfies
            # eos/max_new finishes at prefill and never occupies the slot
            # (matches the paged engine's finish-at-prefill path, so the
            # parity matrix holds at max_new=1 too)
            while self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                if getattr(req, "enc_emb", None) is not None:
                    batch["enc_emb"] = jnp.asarray(req.enc_emb)[None]
                if extra_batch:
                    batch.update(extra_batch)
                cache = model_lib.init_serve_cache(self.cfg, 1, self.max_len)
                logits, cache = self._prefill(self.params, batch, cache)
                nxt = self._pick(req, logits[0, -1, : self.cfg.vocab])
                req.out_tokens.append(nxt)
                now = time.perf_counter()
                req.t_first = now
                self.stats["tokens"] += 1
                if nxt == req.eos_id or len(req.out_tokens) >= req.max_new:
                    req.done = True
                    req.t_done = now
                    self.stats["requests"] += 1
                    continue
                self.caches[i] = cache
                self.active[i] = req

    def _decode_once(self):
        for i, req in enumerate(self.active):
            if req is None:
                continue
            tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            _, logits, cache = self._step(self.params, self.caches[i], tok)
            self.caches[i] = cache
            t = self._pick(req, logits[0])
            req.out_tokens.append(t)
            self.stats["tokens"] += 1
            if t == req.eos_id or len(req.out_tokens) >= req.max_new:
                req.done = True
                req.t_done = time.perf_counter()
                self.stats["requests"] += 1
                self.active[i] = None

    def run(self, extra_batch: Optional[Dict] = None) -> List[Request]:
        """Drain the queue; returns completed requests."""
        done: List[Request] = []
        pending = lambda: self.queue or any(a is not None for a in self.active)
        tracked: List[Request] = list(self.queue)
        while pending():
            self._fill_slots(extra_batch)
            self._decode_once()
        return [r for r in tracked if r.done]
