"""The prefix cache: radix trie + refcounted pages + metrics, one per
engine.

Lifecycle of a cached prefix (the paper's move — share the stored
object, pay only the delta):

* **insert** — when a request finishes prefill, its prompt pages (all of
  them, including an unaligned tail page) go into the trie; the cache
  takes ONE allocator reference per newly added page, so the pages
  survive the donor finishing. Slot-bearing plans (hybrid/ssd) attach a
  snapshot of the donor's constant-state slot to the final node — KV
  pages alone cannot resume an SSM.
* **lookup** — at admission the scheduler walks the trie with the new
  prompt. A match of ``m`` tokens (capped at ``plen - 1``: at least one
  token must prefill to produce first-token logits) pins ``m // P`` full
  pages (shared read-only into the request's table) plus, when ``m`` is
  unaligned, the boundary page as a COW-fork source. Slot-bearing plans
  only hit at a donor's exact state point (``payload_tokens``) — pages
  without the matching slot state are useless to them.
* **release / eviction** — dropping a trie leaf drops the cache's one
  reference; the allocator frees the page only when no request still
  holds it. LRU leaves go first; leaves whose page is still shared with
  a running request are pinned (evicting them frees nothing). An
  optional byte budget (``cache_bytes``) bounds the cache's footprint;
  allocator pressure (admission/growth failures) evicts on demand.

The cache is host-side bookkeeping only — device copies (COW forks,
payload restores) are the engine's job.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

from . import cow
from .chunk import ChunkConfig
from .trie import RadixTrie, TrieNode


@dataclass(frozen=True)
class PrefixConfig:
    """Engine-level knobs for the prefix subsystem. ``cache_bytes=0``
    means unbounded (the pool's page capacity is the only limit)."""
    enabled: bool = True
    cache_bytes: int = 0
    chunk: ChunkConfig = field(default_factory=ChunkConfig)


class PrefixCache:
    """One engine's prefix cache over its paged-domain allocator."""

    def __init__(self, alloc, page_size: int, page_bytes: int,
                 cfg: Optional[PrefixConfig] = None, metrics=None,
                 labels: Optional[Dict[str, str]] = None, spans=None):
        self.alloc = alloc
        self.page_size = page_size
        self.page_bytes = max(int(page_bytes), 1)
        self.cfg = cfg or PrefixConfig()
        self.spans = spans if spans is not None else obs_spans.NOOP
        self.trie = RadixTrie(page_size)
        self._payload_bytes: Dict[int, int] = {}     # node id -> bytes
        # invoked whenever the cache changes the ALLOCATOR's free/used
        # state (eviction, releasing pins) — the scheduler hooks its
        # gauge sync here so `sched_free_pages` never drifts from the
        # allocator while the cache breathes
        self.on_pool_change = lambda: None
        self._init_metrics(metrics, labels)

    # -- metrics -------------------------------------------------------------

    def _init_metrics(self, metrics, labels) -> None:
        self.metrics = metrics if metrics is not None \
            else obs_metrics.MetricsRegistry()
        labels = dict(labels or {"engine": "-"})
        ln = tuple(labels)
        c = lambda name, help: self.metrics.counter(  # noqa: E731
            name, help, ln).labels(**labels)
        g = lambda name, help: self.metrics.gauge(    # noqa: E731
            name, help, ln).labels(**labels)
        self._c_lookups = c("prefix_lookups_total", "prefix-cache lookups")
        self._c_hits = c("prefix_hits_total", "lookups that matched >= 1 "
                         "token (and pinned pages)")
        self._c_hit_tokens = c("prefix_hit_tokens_total",
                               "prompt tokens served from cached pages "
                               "instead of prefill")
        self._c_evictions = c("prefix_evictions_total",
                              "trie leaves evicted (LRU / pressure)")
        self._c_inserted = c("prefix_inserted_pages_total",
                             "pages newly referenced by the cache")
        self._g_bytes = g("prefix_cache_bytes", "bytes the cache currently "
                          "references (pages + slot-state payloads)")
        self._g_pages = g("prefix_cache_pages", "pages the cache holds a "
                          "reference on")
        self._g_hit_rate = g("prefix_hit_rate", "hits / lookups over the "
                             "engine's lifetime (derived gauge)")
        # per-tenant attribution: the existing unlabelled-by-tenant
        # counters stay the engine-level truth; these children break
        # the same probes down by namespace for fairness accounting
        self._labels = labels
        tl = tuple(labels) + ("tenant",)
        self._c_t_lookups = self.metrics.counter(
            "prefix_tenant_lookups_total",
            "prefix-cache lookups by tenant namespace", tl)
        self._c_t_hits = self.metrics.counter(
            "prefix_tenant_hits_total",
            "prefix-cache hits by tenant namespace", tl)
        self._tenant_children: Dict[str, tuple] = {}
        self._sync_gauges()

    def _tenant(self, tenant: str):
        pair = self._tenant_children.get(tenant)
        if pair is None:
            kw = dict(self._labels, tenant=tenant)
            pair = (self._c_t_lookups.labels(**kw),
                    self._c_t_hits.labels(**kw))
            self._tenant_children[tenant] = pair
        return pair

    def _update_hit_rate(self) -> None:
        lookups = self._c_lookups.value()
        if lookups:
            self._g_hit_rate.set(self._c_hits.value() / lookups)

    def _sync_gauges(self) -> None:
        self._g_bytes.set(self.bytes)
        self._g_pages.set(self.pages)

    # -- introspection -------------------------------------------------------

    @property
    def pages(self) -> int:
        """Pages the cache references (trie nodes are 1:1 with pages)."""
        return self.trie.n_nodes

    @property
    def bytes(self) -> int:
        return (self.trie.n_nodes * self.page_bytes
                + sum(self._payload_bytes.values()))

    def page_ids(self) -> List[int]:
        return self.trie.pages()

    # -- lookup / insert -----------------------------------------------------

    def lookup(self, ns: int, tokens, want_state: bool = False,
               tenant: str = "-", uid: Optional[int] = None
               ) -> Optional[cow.PrefixMatch]:
        """Longest usable match for a prompt; pins every returned page
        (one allocator reference each) until admission transfers or
        :meth:`release` drops them. Returns None on a miss."""
        self._c_lookups.inc()
        t_lookups, t_hits = self._tenant(tenant)
        t_lookups.inc()
        plen = len(tokens)
        raw = self.trie.walk(ns, tokens)
        m, payload, ptoks = self._usable(raw, plen, want_state)
        if m <= 0:
            self._update_hit_rate()
            return None
        shared, fork_src = cow.plan_match(raw.nodes, m, self.page_size)
        self.alloc.share(shared + ([fork_src] if fork_src is not None
                                   else []))
        self._c_hits.inc()
        t_hits.inc()
        self._c_hit_tokens.inc(m)
        self._update_hit_rate()
        self.spans.instant("prefix_hit", uid=uid, tokens=m,
                           pages=len(shared), tenant=tenant)
        return cow.PrefixMatch(ns=ns, tokens=m, pages=shared,
                               fork_src=fork_src, payload=payload,
                               payload_tokens=ptoks)

    def peek(self, ns: int, tokens, want_state: bool = False) -> int:
        """Matched token count WITHOUT pinning or LRU touching — the
        router's prefix-affinity probe (it peeks every replica; touching
        would distort every replica's LRU order identically, i.e. pure
        noise)."""
        raw = self.trie.walk(ns, tokens, touch=False)
        m, _, _ = self._usable(raw, len(tokens), want_state)
        return max(m, 0)

    @staticmethod
    def _usable(raw, plen: int, want_state: bool):
        """Cap a raw walk at the plan's usable match: at most ``plen - 1``
        tokens (>= 1 token must prefill for first-token logits), and for
        slot-bearing plans exactly a donor's state point — shared KV
        without the matching constant state would silently skip the SSM
        updates for those tokens."""
        if want_state:
            cands = [(t, p) for t, p in raw.payloads if t <= plen - 1]
            if not cands:
                return 0, None, 0
            t, p = max(cands)
            return t, p, t
        return min(raw.tokens, plen - 1), None, 0

    def release(self, match: cow.PrefixMatch) -> None:
        """Unpin a match that was not admitted (allocation failed)."""
        self.alloc.free(match.pinned)
        self.on_pool_change()

    def release_fork(self, src: int) -> None:
        """Drop the admission-fork pin after the device copy retired."""
        self.alloc.free([src])
        self.on_pool_change()

    def insert(self, ns: int, tokens, pages: List[int],
               payload=None, payload_tokens: int = 0) -> List[int]:
        """Cache a fully prefilled prompt; returns the pages the cache
        newly references (it ``share``s each — existing nodes on the
        path keep their canonical pages and cost nothing; the caller
        checks membership to learn whether its tail-copy page was
        adopted)."""
        new_pages, node = self.trie.insert(ns, tokens, pages)
        if new_pages:
            self.alloc.share(new_pages)
            self._c_inserted.inc(len(new_pages))
            self.spans.instant("prefix_insert", pages=len(new_pages),
                               tokens=len(tokens))
        if payload is not None and node.payload is None:
            node.payload = payload
            node.payload_tokens = payload_tokens
            self._payload_bytes[id(node)] = _payload_nbytes(payload)
        self.enforce_budget()
        self._sync_gauges()
        return new_pages

    # -- eviction ------------------------------------------------------------

    def _drop_leaf(self, leaf: TrieNode) -> int:
        pg = self.trie.remove(leaf)
        self._payload_bytes.pop(id(leaf), None)
        self._c_evictions.inc()
        self.spans.instant("prefix_evict")
        return len(self.alloc.free([pg]))

    def evict_for(self, n: int) -> int:
        """Allocator pressure: free at least ``n`` pages back to the
        pool by dropping LRU leaves whose page has no other owner
        (pinned leaves free nothing — skipped). Returns pages actually
        freed; dropping a leaf can expose its parent, so the scan
        repeats until satisfied or dry."""
        released, progress = 0, True
        while released < n and progress:
            progress = False
            for leaf in self.trie._leaves_lru():
                if self.alloc.is_shared(leaf.page):
                    continue
                released += self._drop_leaf(leaf)
                progress = True
                if released >= n:
                    break
        if released:
            self._sync_gauges()
            self.on_pool_change()
        return released

    def enforce_budget(self) -> int:
        """LRU-evict unpinned leaves until within ``cache_bytes``.
        Pinned leaves are never evicted (the running request holds the
        page anyway — dropping the cache reference frees nothing and
        only destroys reuse), so the budget can transiently overshoot
        while donors run; it converges as they finish."""
        if self.cfg.cache_bytes <= 0:
            return 0
        dropped, progress = 0, True
        while self.bytes > self.cfg.cache_bytes and progress:
            progress = False
            for leaf in self.trie._leaves_lru():
                if self.alloc.is_shared(leaf.page):
                    continue
                self._drop_leaf(leaf)
                dropped += 1
                progress = True
                if self.bytes <= self.cfg.cache_bytes:
                    break
        if dropped:
            self._sync_gauges()
            self.on_pool_change()
        return dropped

    def drop_all(self) -> int:
        """Drop EVERY cache reference (pinned or not) — teardown/tests:
        after a drain the pool must return to zero used pages once the
        cache lets go."""
        dropped, progress = 0, True
        while progress:
            progress = False
            for leaf in self.trie._leaves_lru():
                self._drop_leaf(leaf)
                dropped += 1
                progress = True
        self._sync_gauges()
        self.on_pool_change()
        return dropped

    # -- maintenance ---------------------------------------------------------

    def remap(self, moves: Dict[int, int]) -> None:
        """Defrag moved pages; the trie's ids must follow."""
        self.trie.remap(moves)


def _payload_nbytes(payload) -> int:
    """Best-effort size of a slot-state payload (PendingSnapshot or any
    array pytree) for the byte budget."""
    try:
        import jax
        return sum(int(getattr(leaf, "nbytes", 0))
                   for leaf in jax.tree.leaves(
                       getattr(payload, "_dev", None)
                       or getattr(payload, "_host", None) or payload))
    except Exception:
        return 0
