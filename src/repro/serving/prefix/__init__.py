"""Prefix-sharing subsystem for the paged serving engine.

Production prompts are massively redundant (system prompts, few-shot
templates, encoder memories) — the serving-side mirror of the paper's
trick of recycling one stored random object across many embeddings.
This package shares the stored KV pages of a matched prompt prefix
across requests and pays only the delta:

* ``trie``  — page-granularity radix trie keyed on token ids
* ``cow``   — copy-on-write planning over the refcounted allocator
* ``chunk`` — budgeted chunked prefill interleaved with decode
* ``cache`` — the :class:`PrefixCache` facade + :class:`PrefixConfig`

Wiring: ``Engine(..., prefix=PrefixConfig())`` builds the cache, the
scheduler consults it at admission, and the router prefers replicas
already holding the longest match. Greedy outputs are bit-identical to
the cold-cache path (tested: ``tests/test_prefix_serving.py``).
"""
from .cache import PrefixCache, PrefixConfig          # noqa: F401
from .chunk import ChunkConfig, ChunkPolicy           # noqa: F401
from .cow import Fork, PrefixMatch                    # noqa: F401
from .trie import RadixTrie, TrieMatch, TrieNode      # noqa: F401
