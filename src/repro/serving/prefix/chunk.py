"""Chunked-prefill scheduling policy: budgeted prefill chunks that
interleave with decode steps.

The base engine is prefill-first: while ANY sequence is still
prefilling, decode waits. That maximizes prefill locality but lets one
long cold prompt starve every decoding request (TPOT spikes for the
whole batch). With a :class:`ChunkPolicy` attached the engine instead

* alternates: when both prefill and decode work exist, every
  ``decode_every``-th step runs decode first (prefill-only and
  decode-only phases are unaffected), and
* budgets: each prefill step spends at most ``chunk_tokens`` prompt
  tokens TOTAL across its batch rows, distributed greedily in rank
  order (each row still bounded by the jit shape's per-row chunk), so
  admission of a long prompt is spread over several smaller steps
  instead of one maximal one.

Greedy outputs are batch-composition independent (rows are masked and
independent in ``transformer.paged_step``; MoE capacity is sized on
valid tokens), so interleaving and re-budgeting chunks NEVER changes
tokens — only their timing. The policy is attached only when the prefix
subsystem is enabled; a cold engine keeps the exact legacy order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from repro.obs import spans as obs_spans


@dataclass(frozen=True)
class ChunkConfig:
    """``chunk_tokens=0`` means the full jit budget (prefill_batch x
    prefill_chunk — no extra splitting); ``decode_every=0`` disables
    interleaving (prefill-first, like the cold engine)."""
    chunk_tokens: int = 0
    decode_every: int = 2


class ChunkPolicy:
    """Host-side pacing state; one per engine."""

    def __init__(self, cfg: ChunkConfig, spans=None):
        self.cfg = cfg
        self.spans = spans if spans is not None else obs_spans.NOOP
        self._mixed_steps = 0

    def spans_steps(self, work, per_row: int, max_rows: int) -> bool:
        """True when the pending prefill work cannot finish in ONE step
        under the current budget. Only then is a decode detour worth it:
        a single quick prefill step delays decode less than a full
        interleave round, so yielding for it would tax steady-state TPOT
        (e.g. the tiny suffix prefills of prefix-cache hits) without
        protecting anything."""
        budget = self.cfg.chunk_tokens or per_row * max_rows
        if len(work) > max_rows:
            return True
        return sum(min(s.prompt_len - s.prefill_pos, per_row)
                   for s in work) > budget

    def decode_turn(self) -> bool:
        """Called once per step while BOTH prefill and decode work
        exist; True -> the engine runs decode this step. Every
        ``decode_every``-th mixed step yields to decode, so decoding
        sequences make progress at a bounded TPOT cost while long
        prompts chunk in."""
        if self.cfg.decode_every <= 0:
            return False
        self._mixed_steps += 1
        if self._mixed_steps % self.cfg.decode_every == 0:
            self.spans.instant("decode_yield", mixed_steps=self._mixed_steps)
            return True
        return False

    def plan(self, work, per_row: int,
             max_rows: int) -> List[Tuple[object, int]]:
        """Distribute the step's token budget over prefilling sequences
        (already rank-ordered): returns [(seq, n_tokens)] with
        ``n <= per_row`` each and ``sum(n) <= max(chunk_tokens,
        per_row)``. The head sequence always gets at least one token —
        a budget below one row must still make progress."""
        budget = self.cfg.chunk_tokens or per_row * max_rows
        out: List[Tuple[object, int]] = []
        for seq in work[:max_rows]:
            n = min(seq.prompt_len - seq.prefill_pos, per_row, budget)
            if n <= 0:
                break
            out.append((seq, n))
            budget -= n
            if budget <= 0:
                break
        if not out and work:
            seq = work[0]
            out.append((seq, min(seq.prompt_len - seq.prefill_pos,
                                 per_row, 1)))
        return out
