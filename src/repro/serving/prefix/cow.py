"""Copy-on-write glue between the prefix trie and the refcounted
allocator.

A cached prefix maps to pages with allocator refcount > 1 (the cache
holds one reference, every attached request another). Shared pages are
READ-ONLY by contract; the device step never checks — the host
guarantees no write position ever lands in a shared page, via exactly
two fork sites:

* **Admission fork** (:func:`plan_match`): when the matched token count
  ``m`` is not page-aligned, the boundary page holds ``m % page_size``
  reusable KV rows plus stale tail rows the request will overwrite as
  its suffix prefills. The request gets a private copy: its first
  freshly allocated page becomes the fork destination, the cached page
  stays pinned (one extra ref) until the engine's device copy retires.

* **Decode fork** (:func:`decode_fork_index`): a donor's own last
  partial prompt page becomes shared the moment its prompt is inserted
  into the cache; the donor's first decode write would land in it. The
  scheduler forks it before the write (``grow_for_decode``).

Both sites batch their device copies through
``paged_cache.copy_page_rows`` — one gather-then-scatter, so a fork
destination recycled from a page freed in the same scheduler round can
never be read after being clobbered.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class Fork:
    """One pending device page copy ``src -> dst``. ``pinned_src`` marks
    an admission fork, where the lookup holds an extra reference on
    ``src`` that the engine must drop AFTER the copy retires."""
    src: int
    dst: int
    pinned_src: bool = False


@dataclass
class PrefixMatch:
    """A pinned prefix-cache hit, held between lookup and admission.

    ``pages`` are the full shared pages (refcount bumped once each —
    ownership transfers to the request's block table at admission, whose
    release decrefs them uniformly). ``fork_src`` is the pinned boundary
    page when ``tokens`` is unaligned. ``payload``/``payload_tokens``
    carry a donor's constant-state snapshot for slot-bearing plans.
    """
    ns: int
    tokens: int
    pages: List[int] = field(default_factory=list)
    fork_src: Optional[int] = None
    payload: Optional[object] = None
    payload_tokens: int = 0

    @property
    def pinned(self) -> List[int]:
        """Every page this match holds a reference on."""
        return self.pages + ([self.fork_src]
                             if self.fork_src is not None else [])


def plan_match(nodes, m: int, page_size: int):
    """Split a capped match of ``m`` tokens over the walked trie
    ``nodes`` into (full shared pages, boundary fork source or None).
    ``nodes`` must cover at least ``ceil(m / page_size)`` pages (the
    walk matched >= m tokens)."""
    full = m // page_size
    shared = [nd.page for nd in nodes[:full]]
    fork_src = nodes[full].page if m % page_size else None
    return shared, fork_src


def decode_fork_index(alloc, table_pages: List[int], pos: int,
                      page_size: int) -> Optional[int]:
    """Index into ``table_pages`` of the page that must be COW-forked
    before writing token position ``pos``, or None when the write target
    is exclusively owned (or does not exist yet — growth, not a fork)."""
    idx = pos // page_size
    if idx < len(table_pages) and alloc.is_shared(table_pages[idx]):
        return idx
    return None


def assert_writable(alloc, table_pages: List[int], start: int, n: int,
                    page_size: int) -> None:
    """Debug guard for the read-only contract: every page a write of
    ``n`` tokens from position ``start`` touches must have exactly one
    owner. Cheap (a dict lookup per touched page), so the engine runs it
    on every batch row while a prefix cache is attached."""
    for idx in range(start // page_size,
                     min(-(-(start + n) // page_size), len(table_pages))):
        pg = table_pages[idx]
        if alloc.refcount(pg) != 1:
            raise AssertionError(
                f"write into page {pg} (table idx {idx}) with refcount "
                f"{alloc.refcount(pg)} — shared pages are read-only; "
                "a COW fork was missed")
