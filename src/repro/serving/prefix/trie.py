"""Page-granularity radix trie over token ids.

Each node covers EXACTLY ONE page of the paged KV pool: its ``key`` is
the tuple of tokens cached in that page (up to ``page_size`` of them)
and its ``page`` is the pool page id holding their KV rows. Only
full-page nodes (``len(key) == page_size``) may have children; a node
whose key is shorter — the unaligned tail of some donor prompt — is
always a leaf. Because a prompt is inserted page by page, the classic
radix-tree edge-splitting never arises: two prompts diverging inside a
page simply produce two sibling partial leaves (each holding its own
page), and the shared part up to the last common FULL page is one path.

The trie stores ids, never device data: the engine owns the pools, the
allocator owns the refcounts (the cache holds ONE reference per node
page), and lookup returns page ids + the matched token count for the
scheduler to attach to a request's block table.

Namespaces partition the trie: decoder KV depends on the enc-dec
encoder memory, so token-equal prompts under different encoder inputs
must never share pages — the engine keys enc-dec requests by a hash of
the encoder features (``namespace 0`` otherwise).

Eviction is LRU over leaves (a monotonic touch counter stamps every
node on the lookup/insert path): evicting an interior node would orphan
its children's path, and a leaf whose page is still shared with a
running request (allocator refcount > 1) is pinned — dropping the cache
reference would free nothing and only destroy reuse while the donor is
live. Dropping a leaf may expose its parent as the next LRU candidate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

Key = Tuple[int, ...]


@dataclass(eq=False)                    # identity eq/hash: nodes are places
class TrieNode:
    """One cached page: ``key`` tokens -> pool page ``page``."""
    key: Key
    page: int
    parent: Optional["TrieNode"] = None
    children: Dict[Key, "TrieNode"] = field(default_factory=dict)
    stamp: int = 0                      # LRU touch tick
    payload: Optional[object] = None    # slot-state snapshot (hybrid/ssd)
    payload_tokens: int = 0             # prompt tokens the payload covers

    @property
    def is_leaf(self) -> bool:
        return not self.children


@dataclass
class TrieMatch:
    """Result of one lookup walk (token counts, page ids — no pins)."""
    tokens: int                         # matched tokens (raw lcp)
    pages: List[int]                    # full shared pages, in order
    boundary_page: Optional[int]        # page holding the unaligned tail
    # (payload_tokens, payload) per fully-matched node carrying one,
    # shallowest first — the cache picks the deepest under its cap
    payloads: List[Tuple[int, object]] = field(default_factory=list)
    nodes: List[TrieNode] = field(default_factory=list)


def _lcp(a, b) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class RadixTrie:
    """Token-id trie with one page per node; ids only, no device state."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._roots: Dict[int, TrieNode] = {}
        self._tick = 0
        self.n_nodes = 0

    # -- internals -----------------------------------------------------------

    def _touch(self, node: TrieNode) -> None:
        self._tick += 1
        node.stamp = self._tick

    def _best_child(self, node: TrieNode,
                    rest: Tuple[int, ...]) -> Tuple[Optional[TrieNode], int]:
        """Child with the longest key-prefix match against ``rest``.
        Exact full-page matches are a dict hit; otherwise every child key
        is scanned (children of one node are few in practice — siblings
        only exist where prompts actually diverge)."""
        P = self.page_size
        if len(rest) >= P:
            child = node.children.get(tuple(rest[:P]))
            if child is not None:
                return child, P
        best, best_n = None, 0
        for key, child in node.children.items():
            n = _lcp(key, rest)
            if n > best_n:
                best, best_n = child, n
        return best, best_n

    # -- walk ----------------------------------------------------------------

    def walk(self, ns: int, tokens, touch: bool = True) -> TrieMatch:
        """Longest-prefix walk of ``tokens`` (raw: no caller caps applied
        here). ``pages``/``boundary_page`` describe the raw match:
        ``tokens // page_size`` full pages plus the node holding any
        unaligned remainder. Payloads are only collected from nodes whose
        ENTIRE key matched — a partially matched tail node's state
        describes tokens the walker does not have."""
        root = self._roots.get(ns)
        toks = tuple(int(t) for t in tokens)
        m = TrieMatch(tokens=0, pages=[], boundary_page=None)
        if root is None:
            return m
        node, d = root, 0
        while True:
            child, n = self._best_child(node, toks[d:])
            if child is None or n == 0:
                break
            if touch:
                self._touch(child)
            m.nodes.append(child)
            d += n
            if n == len(child.key) and child.payload is not None:
                m.payloads.append((child.payload_tokens, child.payload))
            if n < len(child.key) or len(child.key) < self.page_size:
                # partial match, or a partial-key leaf: cannot descend
                m.boundary_page = child.page
                break
            node = child
        m.tokens = d
        # a trailing exactly-full node is a full page, not a boundary
        full = d // self.page_size
        m.pages = [nd.page for nd in m.nodes[:full]]
        if d % self.page_size and m.boundary_page is None:
            m.boundary_page = m.nodes[full].page
        return m

    # -- insert --------------------------------------------------------------

    def insert(self, ns: int, tokens, pages: List[int]) -> Tuple[
            List[int], TrieNode]:
        """Record a fully prefilled prompt: page i of ``pages`` caches
        tokens ``[i*P, min((i+1)*P, len))``. Existing nodes on the path
        are reused (their pages stay canonical); NEW nodes take the
        donor's pages. Returns (newly referenced pages, final node) —
        the caller must ``share`` the new pages into the allocator and
        may attach a slot-state payload to the final node."""
        P = self.page_size
        toks = tuple(int(t) for t in tokens)
        if not toks:
            raise ValueError("cannot insert an empty prompt")
        if len(pages) != -(-len(toks) // P):
            raise ValueError(f"{len(pages)} pages cannot cover "
                             f"{len(toks)} tokens at page_size {P}")
        root = self._roots.setdefault(ns, TrieNode(key=(), page=0))
        node, new_pages = root, []
        for i in range(0, len(toks), P):
            key = toks[i:i + P]
            child = node.children.get(key)
            if child is None:
                child = TrieNode(key=key, page=pages[i // P], parent=node)
                node.children[key] = child
                new_pages.append(child.page)
                self.n_nodes += 1
            self._touch(child)
            node = child
        return new_pages, node

    # -- eviction ------------------------------------------------------------

    def _leaves_lru(self, skip=frozenset()) -> Iterator[TrieNode]:
        leaves = [nd for root in self._roots.values()
                  for nd in _iter_nodes(root) if nd.is_leaf
                  and nd not in skip]
        leaves.sort(key=lambda nd: nd.stamp)
        return iter(leaves)

    def remove(self, node: TrieNode) -> int:
        """Unlink a LEAF node; returns its page id (the caller drops the
        cache's allocator reference)."""
        if node.children:
            raise ValueError("evicting an interior node would orphan "
                             "its children")
        node.parent.children.pop(node.key)
        node.parent = None
        self.n_nodes -= 1
        return node.page

    def pages(self) -> List[int]:
        """Every page the cache currently references (one ref each)."""
        return [nd.page for root in self._roots.values()
                for nd in _iter_nodes(root)]

    def remap(self, moves: Dict[int, int]) -> None:
        """Apply a defrag move map {old: new} to every node's page id."""
        if not moves:
            return
        for root in self._roots.values():
            for nd in _iter_nodes(root):
                nd.page = moves.get(nd.page, nd.page)


def _iter_nodes(root: TrieNode) -> Iterator[TrieNode]:
    """All real nodes under (excluding) a namespace root."""
    stack = list(root.children.values())
    while stack:
        nd = stack.pop()
        yield nd
        stack.extend(nd.children.values())
