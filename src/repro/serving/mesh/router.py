"""Cross-host request router over paged-serving engine replicas.

Each replica is one :class:`~repro.serving.engine.Engine` — on a real
deployment one host (or one model-parallel mesh slice of hosts), in
tests a device-subset mesh of the forced host platform. The router is
pure host-side control plane, mirroring the scheduler/engine split one
level up: engines own device state, the router decides *which* engine a
request lives on.

Placement policy: free-page **pressure**. A request is admitted to the
replica whose pool has the most free pages per queued demand (each
waiting request discounts its page need from the replica's headroom), so
short bursts spread instead of piling onto replica 0. While draining,
the router also *migrates* waiting requests off saturated replicas —
any sequence still in a replica's admission queue holds no device pages
(fresh requests trivially; evicted ones only a host-side snapshot), so
moving it is a scheduler hand-off (``Scheduler.release_waiting`` /
``adopt``), never a device copy.

Fault tolerance (``Router(ft=FTConfig())``, see ``serving/ft.py``): a
replica is **quarantined** when an exception escapes its ``step`` or the
:class:`~repro.serving.ft.ReplicaWatchdog` flags it (slow per the
recorded ``engine_step_seconds``, or stuck with work queued). Its
sequences are **rescued** — waiting ones re-homed through the migration
hand-off, running ones (device state lost) **replayed** on a survivor
with their emitted tokens folded in as a forced prefix — and the
placement set shrinks to the survivors, the serving analogue of
``ft/elastic.shrink_plan``. ``revive()`` rejoins a repaired replica
after a probe request completes. Under sustained pool exhaustion the
router enters ``degraded`` state and sheds NEW requests deterministically
(reject-new before evict-running) instead of thrashing the
evict/restore path. Every transition is a counter + event:
``router_{quarantined,rescued,replayed,failed,shed,revived}_total`` and
gauges ``router_degraded`` / ``router_dead_replicas``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Set

import jax

from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans
from repro.obs import trace as obs_trace

from .. import ft as ft_lib
from ..engine import Engine, Request
from ..scheduler import Sequence, tenant_of


@dataclass(frozen=True)
class RouterConfig:
    migrate: bool = True
    # a replica is "saturated" when its discounted headroom is below this
    # fraction of the pool while another replica has at least twice the
    # absolute headroom — the hysteresis keeps requests from ping-ponging.
    saturation: float = 0.125
    migrate_per_round: int = 4       # bound control-plane work per step


class Router:
    """Spread requests across engine replicas; migrate under pressure;
    optionally (``ft``) detect dead replicas and rescue their work."""

    def __init__(self, engines: List[Engine],
                 cfg: Optional[RouterConfig] = None,
                 metrics: Optional[obs_metrics.MetricsRegistry] = None,
                 ft: Optional[ft_lib.FTConfig] = None, spans=None):
        if not engines:
            raise ValueError("router needs >= 1 engine replica")
        fam = engines[0].plan.name
        if any(e.plan.name != fam for e in engines):
            raise ValueError("router replicas must serve one pool plan")
        self.engines = list(engines)
        self.cfg = cfg or RouterConfig()
        self.ft = ft
        self.home: Dict[int, int] = {}       # request uid -> replica index
        self.dead: Set[int] = set()          # quarantined replica indices
        self.state = "ok"                    # ok | degraded
        self._exhausted_rounds = 0
        # the router's control-plane series default into their OWN
        # registry: parking them in engines[0]'s registry orphaned every
        # router counter the moment replica 0 was quarantined. A serve
        # deployment passes the one shared registry explicitly, so a
        # single scrape still covers the whole deployment.
        self.metrics = metrics if metrics is not None \
            else obs_metrics.MetricsRegistry()
        self.spans = spans if spans is not None else obs_spans.NOOP
        self.watchdog = (ft_lib.ReplicaWatchdog(len(engines), ft,
                                                spans=self.spans)
                         if ft is not None else None)
        self._c_submitted = self.metrics.counter(
            "router_submitted_total", "requests routed to a replica")
        self._c_migrations = self.metrics.counter(
            "router_migrations_total", "waiting sequences moved between "
            "replicas under pressure")
        self._c_steps = self.metrics.counter(
            "router_steps_total", "router drive rounds")
        self._c_quarantined = self.metrics.counter(
            "router_quarantined_total", "replicas marked dead")
        self._c_rescued = self.metrics.counter(
            "router_rescued_total", "waiting sequences re-homed off a "
            "dead replica (snapshot/prefill progress kept)")
        self._c_replayed = self.metrics.counter(
            "router_replayed_total", "requests re-submitted with their "
            "emitted tokens as a forced prefix (device state lost)")
        self._c_failed = self.metrics.counter(
            "router_failed_total", "requests terminally failed (retry "
            "budget exhausted or no live replica fits)")
        self._c_shed = self.metrics.counter(
            "router_shed_total", "new requests rejected in degraded state")
        self._c_revived = self.metrics.counter(
            "router_revived_total", "quarantined replicas rejoined after "
            "a successful probe")
        self._c_tenant_shed = self.metrics.counter(
            "router_tenant_shed_total",
            "new requests rejected in degraded state, by tenant "
            "namespace", ("tenant",))
        self._g_headroom = self.metrics.gauge(
            "router_headroom", "discounted free capacity per replica "
            "(pages/slots minus queued demand)", ("replica",))
        self._g_degraded = self.metrics.gauge(
            "router_degraded", "1 while shedding new load (sustained "
            "pool exhaustion)")
        self._g_dead = self.metrics.gauge(
            "router_dead_replicas", "replicas currently quarantined")
        self.stats = obs_metrics.StatsView({
            "submitted": self._c_submitted.value,
            "migrations": self._c_migrations.value,
            "steps": self._c_steps.value,
            "quarantined": self._c_quarantined.value,
            "rescued": self._c_rescued.value,
            "replayed": self._c_replayed.value,
            "shed": self._c_shed.value,
            "revived": self._c_revived.value,
        })

    # -- pressure ------------------------------------------------------------

    def _live(self) -> List[int]:
        return [i for i in range(len(self.engines)) if i not in self.dead]

    def _demand_pages(self, eng: Engine, seq: Sequence) -> int:
        """Paged-domain pages the sequence needs at admission on this
        replica (slot-only plans: count the one slot instead, so pressure
        still reflects real demand)."""
        if not eng.plan.has_paged:
            return 1
        if seq.snapshot is not None:
            return max(len(seq.snapshot_pages), 1)
        return eng.sched._pages_for(max(seq.prompt_len, 1))

    def _demand_req(self, eng: Engine, req: Request) -> int:
        """Admission demand of a not-yet-submitted request."""
        if not eng.plan.has_paged:
            return 1
        return eng.sched._pages_for(max(len(req.prompt), 1))

    def _headroom(self, eng: Engine) -> int:
        """Free capacity minus the queued demand already bound for
        ``eng`` — the minimum over the domains the plan allocates from
        (pages for kv/mla state, slots for constant state): a hybrid
        replica with free pages but no free slots is still full."""
        hs = []
        if eng.plan.has_paged:
            queued = sum(self._demand_pages(eng, s)
                         for s in eng.sched.waiting)
            hs.append(eng.free_pages - queued)
        if eng.sched.slot_alloc is not None:
            hs.append(eng.free_slots - len(eng.sched.waiting))
        return min(hs)

    def pressure(self) -> List[int]:
        return [self._headroom(e) for e in self.engines]

    # -- submission ----------------------------------------------------------

    def _affinity_pages(self, eng: Engine, req: Request) -> int:
        """Prefix-cache affinity bonus in headroom units: pages of the
        prompt this replica could serve from its cache (0 when the
        engine has no cache). A hit saves exactly that many page
        allocations AND their prefill compute, so adding it to headroom
        prices affinity in the same currency as free capacity."""
        peek = getattr(eng, "prefix_peek", None)
        if peek is None:
            return 0
        return peek(req) // max(eng.sched_cfg.page_size, 1)

    def submit(self, req: Request) -> int:
        """Route to the live replica with the most discounted headroom
        that can hold the request at all; returns the replica index (-1
        when the request was shed in degraded state). Headroom is
        credited with prefix-cache affinity — a replica already holding
        the prompt's prefix admits it cheaper than its raw free pages
        suggest."""
        stok = self.spans.begin("router_score", uid=req.uid)
        try:
            hr = {i: self._headroom(self.engines[i])
                  + self._affinity_pages(self.engines[i], req)
                  for i in self._live()}
            fitting = [i for i in sorted(hr, key=lambda i: -hr[i])
                       if self.engines[i].sched.fits(req)]
            if not fitting:
                raise ValueError(
                    f"request uid={req.uid} fits no replica "
                    f"(prompt={len(req.prompt)} + max_new={req.max_new})")
            best = fitting[0]
            stok.args["replica"] = best
            if (self.ft is not None and self.state == "degraded"
                    and hr[best] < self._demand_req(self.engines[best],
                                                    req)):
                # degradation ladder, first rung: rejecting a NEW request
                # is strictly cheaper than queueing it into an exhausted
                # pool, where admitting it could only proceed by evicting
                # running work (reject-new before evict-running)
                stok.args["replica"] = -1
                return self._shed(req)
            eng = self.engines[best]
            eng.submit(req)
            self.home[req.uid] = best
            self._c_submitted.inc()
            self.metrics.event("routed", uid=req.uid, replica=best)
            return best
        finally:
            self.spans.end(stok)

    def _shed(self, req: Request) -> int:
        req.done = True
        req.finish_reason = "shed"
        now = time.perf_counter()
        req.t_submit = req.t_done = now
        if req.trace is None:
            req.trace = obs_trace.Trace(uid=req.uid)
        req.trace.stamp("queued", now)
        req.trace.stamp("done", now)
        self._c_shed.inc()
        self._c_tenant_shed.labels(tenant=tenant_of(req)).inc()
        self.spans.instant("shed", uid=req.uid, tenant=tenant_of(req))
        self.metrics.event("shed", uid=req.uid)
        return -1

    # -- migration -----------------------------------------------------------

    @staticmethod
    def _capacity(eng: Engine) -> int:
        """Units backing ``_headroom`` for saturation thresholds: the
        SMALLEST domain the plan allocates from, matching _headroom's
        min-over-domains — scaling a slot-bound headroom (<= usable
        slots) against the much larger page count would classify every
        mixed-geometry replica as permanently saturated."""
        caps = []
        if eng.plan.has_paged:
            caps.append(eng.usable_pages)
        if eng.sched.slot_alloc is not None:
            caps.append(eng.usable_slots)
        return min(caps)

    @staticmethod
    def _pool_signature(eng: Engine):
        """Per-domain, per-segment (leaf path, dtype, page-row shape) —
        everything a snapshot scatter must agree on except the pools'
        page/slot COUNTS. The enc-dec memory row shape is included (the
        snapshot carries the encoded memory)."""
        def seg_sig(seg, axis):
            if seg is None:
                return None
            leaves = jax.tree_util.tree_flatten_with_path(seg)[0]
            return tuple(sorted(
                (jax.tree_util.keystr(kp), str(v.dtype),
                 v.shape[:axis] + v.shape[axis + 1:])
                for kp, v in leaves))
        sig = tuple(tuple(seg_sig(s, 1) for s in eng.pools[dom])
                    for dom in ("paged", "slot"))
        mem = eng.pools.get("memory")
        if mem is not None:
            sig += ((str(mem.dtype), mem.shape[1:]),)
        return sig

    def _can_place(self, src: Engine, dst: Engine, seq: Sequence) -> bool:
        """Whether ``seq`` can be adopted by ``dst``. A preemption
        snapshot scatters page rows verbatim, so the full page geometry —
        page_size AND pool leaf structure/dtype/row shape (int8 vs fp
        pools, bf16 vs f32 configs) — must match exactly; heterogeneous
        pools can still serve together, but snapshot-carrying sequences
        are pinned to like-shaped replicas. Any non-constant-state
        sequence must also fit the destination's token capacity."""
        dc = dst.sched_cfg
        if seq.snapshot is not None:
            if src.sched_cfg.page_size != dc.page_size:
                return False
            if len(seq.snapshot_pages) > dc.table_width:
                return False
            if self._pool_signature(src) != self._pool_signature(dst):
                return False
        return dst.sched.fits(seq.req)

    def migrate(self) -> int:
        """Move waiting sequences from saturated live replicas to roomy
        live ones. Returns how many were moved this round."""
        live = self._live()
        if not self.cfg.migrate or len(live) < 2:
            return 0
        moved = 0
        for src_i in live:
            src = self.engines[src_i]
            if moved >= self.cfg.migrate_per_round:
                break
            src_hr = self._headroom(src)
            if src_hr >= self.cfg.saturation * self._capacity(src):
                continue
            # saturated: offload the tail of the waiting queue (the head
            # is closest to admission here; the tail pays the wait)
            for seq in sorted(src.sched.waiting, key=src.sched._rank,
                              reverse=True):
                if moved >= self.cfg.migrate_per_round:
                    break
                hr = {i: self._headroom(self.engines[i]) for i in live}
                dst_i = max(hr, key=lambda i: hr[i])
                dst = self.engines[dst_i]
                if dst_i == src_i or hr[dst_i] < max(2 * src_hr, 1):
                    break                    # nowhere meaningfully roomier
                if hr[dst_i] < self._demand_pages(dst, seq) or \
                        not self._can_place(src, dst, seq):
                    continue                 # THIS seq doesn't fit; smaller
                                             # ones behind it still might
                src.sched.release_waiting(seq)
                dst.sched.adopt(seq)
                if seq.req.trace is not None:
                    seq.req.trace.stamp("migrated")
                self.home[seq.req.uid] = dst_i
                self._c_migrations.inc()
                self.metrics.event("migrated", uid=seq.req.uid,
                                   src=src_i, dst=dst_i)
                moved += 1
                src_hr = self._headroom(src)
        return moved

    # -- fault tolerance -----------------------------------------------------

    def quarantine(self, idx: int, reason: str) -> None:
        """Mark a replica dead and rescue everything it holds. The
        placement set shrinks to the survivors (the serving analogue of
        ``ft/elastic.shrink_plan``); ``revive()`` grows it back."""
        if idx in self.dead:
            return
        self.dead.add(idx)
        if self.watchdog is not None:
            self.watchdog.mark_dead(idx)
        self._c_quarantined.inc()
        self._g_dead.set(len(self.dead))
        self.spans.instant("quarantine", replica_idx=idx, reason=reason)
        self.metrics.event("quarantined", replica=idx, reason=reason)
        self._rescue(idx)

    def _adoption_target(self, src_i: int, seq: Sequence) -> Optional[int]:
        order = sorted(self._live(),
                       key=lambda i: -self._headroom(self.engines[i]))
        for i in order:
            if self._can_place(self.engines[src_i], self.engines[i], seq):
                return i
        return None

    def _rescue(self, idx: int) -> None:
        """Move every sequence off a quarantined replica. Running ones
        lost their device state with the replica, so they are replayed;
        waiting ones hold at most a host-side snapshot and are re-homed
        through the migration hand-off. Exactly-once: a request object is
        only ever in ONE scheduler (release before adopt/submit), and
        replay never truncates ``out_tokens`` (serving/ft.py)."""
        eng = self.engines[idx]
        for seq in list(eng.sched.running):
            eng.sched.release_running(seq)
            self._replay(seq.req, idx)
        for seq in list(eng.sched.waiting):
            eng.sched.release_waiting(seq)
            if seq.req.uid < 0:              # a stale revive probe
                continue
            dst_i = self._adoption_target(idx, seq)
            if dst_i is not None:
                self.engines[dst_i].sched.adopt(seq)
                self.home[seq.req.uid] = dst_i
                self._c_rescued.inc()
                if seq.req.trace is not None:
                    seq.req.trace.stamp("rescued")
                self.spans.instant("rescue", uid=seq.req.uid,
                                   src=idx, dst=dst_i)
                self.metrics.event("rescued", uid=seq.req.uid,
                                   src=idx, dst=dst_i)
            else:
                # geometry mismatch pins the snapshot here; dropping it
                # and re-prefilling elsewhere beats losing the request
                seq.snapshot = None
                seq.snapshot_pages = []
                self._replay(seq.req, idx)

    def _replay(self, req: Request, src_i: int) -> None:
        """Re-submit a request whose device state is gone: emitted tokens
        become a forced prompt prefix, so a survivor re-prefills and
        greedy decode continues bit-identically — and since
        ``out_tokens`` is untouched, no token is ever emitted twice."""
        if req.retries >= req.max_retries:
            self._fail(req, f"retry budget exhausted "
                            f"({req.retries}/{req.max_retries})")
            return
        hwm = ft_lib.fold_emitted_prefix(req)
        # affinity counts double for replays: the folded prompt carries
        # every emitted token, so a survivor holding the original prefix
        # skips most of the re-prefill the failure forced
        order = sorted(self._live(),
                       key=lambda i: -(self._headroom(self.engines[i])
                                       + self._affinity_pages(
                                           self.engines[i], req)))
        for dst_i in order:
            eng = self.engines[dst_i]
            if not eng.sched.fits(req):
                continue
            req.retries += 1
            eng.submit(req)
            self.home[req.uid] = dst_i
            self._c_replayed.inc()
            if req.trace is not None:
                req.trace.stamp("replayed")
            self.spans.instant("replay", uid=req.uid, src=src_i,
                               dst=dst_i, prefix_tokens=hwm)
            self.metrics.event("replayed", uid=req.uid, src=src_i,
                               dst=dst_i, prefix_tokens=hwm)
            return
        self._fail(req, "no live replica can hold the request")

    def _fail(self, req: Request, why: str) -> None:
        req.done = True
        req.finish_reason = "failed"
        now = time.perf_counter()
        req.t_done = now
        if req.trace is not None:
            req.trace.stamp("done", now)
        self._c_failed.inc()
        self.spans.instant("rescue_failed", uid=req.uid, reason=why)
        self.metrics.event("rescue_failed", uid=req.uid, reason=why)

    def revive(self, idx: int) -> bool:
        """Probe a quarantined replica; rejoin it to the placement set on
        success. The underlying fault must have been repaired (host
        swapped; in tests ``ChaosEngine.heal()``) — a failing probe keeps
        the replica dead and may be retried later."""
        if idx not in self.dead:
            return True
        eng = self.engines[idx]
        probe = ft_lib.make_probe(
            eng.cfg, uid=-(idx + 1),
            max_new=self.ft.probe_max_new if self.ft is not None else 2)
        try:
            eng.submit(probe)
            for _ in range(256):
                if not eng.sched.has_work:
                    break
                eng.step()
            ok = probe.done and len(probe.out_tokens) >= 1
        except Exception as e:              # noqa: BLE001 — probe verdict
            self.metrics.event("probe_failed", replica=idx,
                               error=f"{type(e).__name__}: {e}")
            ok = False
        if ok:
            self.dead.discard(idx)
            if self.watchdog is not None:
                self.watchdog.revive(idx)
            self._c_revived.inc()
            self._g_dead.set(len(self.dead))
            self.spans.instant("revive", replica_idx=idx)
            self.metrics.event("revived", replica=idx)
        return ok

    def _update_degraded(self) -> None:
        """Sustained pool exhaustion (every live replica backlogged with
        zero discounted headroom for ``degraded_rounds`` rounds) flips
        the router to ``degraded``; the first round with headroom flips
        it back."""
        live = self._live()
        backlog = any(self.engines[i].sched.waiting for i in live)
        exhausted = bool(live) and backlog and all(
            self._headroom(self.engines[i]) <= 0 for i in live)
        self._exhausted_rounds = self._exhausted_rounds + 1 \
            if exhausted else 0
        if self.state == "ok" and \
                self._exhausted_rounds >= self.ft.degraded_rounds:
            self.state = "degraded"
            self._g_degraded.set(1)
            self.metrics.event("degraded", rounds=self._exhausted_rounds)
        elif self.state == "degraded" and not exhausted:
            self.state = "ok"
            self._g_degraded.set(0)
            self.metrics.event("recovered")

    # -- driving -------------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return any(self.engines[i].sched.has_work for i in self._live())

    def step(self) -> bool:
        """One round: each busy live replica takes one engine step (under
        ``ft``, watched and exception-guarded), then one migration pass.
        Returns whether anything progressed."""
        progressed = False
        for i in list(self._live()):
            eng = self.engines[i]
            had_work = eng.sched.has_work
            stepped = False
            if had_work:
                try:
                    stepped = eng.step()
                except Exception as e:      # noqa: BLE001 — replica loss
                    if self.ft is None:
                        raise
                    self.quarantine(
                        i, f"exception escaped Engine.step: "
                           f"{type(e).__name__}: {e}")
                    progressed = True       # rescue moved real work
                    continue
                progressed = stepped or progressed
            if self.watchdog is not None:
                dt = self.watchdog.poll_step_time(i, eng)
                verdict = self.watchdog.observe(i, dt, stepped, had_work)
                # never watchdog-quarantine the LAST live replica: slow
                # beats dead (a hard exception still quarantines above)
                if verdict is not None and len(self._live()) > 1:
                    self.quarantine(i, verdict)
                    progressed = True
        if self.migrate() > 0:
            progressed = True
        if self.ft is not None:
            self._update_degraded()
        self._c_steps.inc()
        for i, hr in enumerate(self.pressure()):
            self._g_headroom.labels(replica=i).set(hr)
        return progressed

    def run(self, on_step=None) -> List[Request]:
        """Drain all submitted requests; returns the completed ones.
        ``on_step(router)`` fires after every round (periodic reporter)."""
        tracked = [s.req for e in self.engines
                   for s in e.sched.waiting + e.sched.running]
        stall = 0
        while self.has_work:
            progressed = self.step()
            if on_step is not None:
                on_step(self)
            stall = 0 if progressed else stall + 1
            if stall > 2 + len(self.engines):
                free = [(e.free_pages, e.free_slots) for e in self.engines]
                raise RuntimeError(
                    f"router stalled: no replica can place the remaining "
                    f"requests (free (pages, slots) per replica: {free})")
        return [r for r in tracked if r.done]

    def describe(self) -> Dict:
        return {"replicas": len(self.engines),
                "dead": sorted(self.dead),
                "state": self.state,
                "free_pages": [e.free_pages for e in self.engines],
                "free_fraction": [round(e.free_fraction, 3)
                                  for e in self.engines],
                "per_engine_stats": [dict(e.stats) for e in self.engines],
                **{k: v for k, v in self.stats.items()}}
