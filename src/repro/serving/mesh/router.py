"""Cross-host request router over paged-serving engine replicas.

Each replica is one :class:`~repro.serving.engine.Engine` — on a real
deployment one host (or one model-parallel mesh slice of hosts), in
tests a device-subset mesh of the forced host platform. The router is
pure host-side control plane, mirroring the scheduler/engine split one
level up: engines own device state, the router decides *which* engine a
request lives on.

Placement policy: free-page **pressure**. A request is admitted to the
replica whose pool has the most free pages per queued demand (each
waiting request discounts its page need from the replica's headroom), so
short bursts spread instead of piling onto replica 0. While draining,
the router also *migrates* waiting requests off saturated replicas —
any sequence still in a replica's admission queue holds no device pages
(fresh requests trivially; evicted ones only a host-side snapshot), so
moving it is a scheduler hand-off (``Scheduler.release_waiting`` /
``adopt``), never a device copy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax

from repro.obs import metrics as obs_metrics

from ..engine import Engine, Request
from ..scheduler import Sequence


@dataclass(frozen=True)
class RouterConfig:
    migrate: bool = True
    # a replica is "saturated" when its discounted headroom is below this
    # fraction of the pool while another replica has at least twice the
    # absolute headroom — the hysteresis keeps requests from ping-ponging.
    saturation: float = 0.125
    migrate_per_round: int = 4       # bound control-plane work per step


class Router:
    """Spread requests across engine replicas; migrate under pressure."""

    def __init__(self, engines: List[Engine],
                 cfg: Optional[RouterConfig] = None,
                 metrics: Optional[obs_metrics.MetricsRegistry] = None):
        if not engines:
            raise ValueError("router needs >= 1 engine replica")
        fam = engines[0].plan.name
        if any(e.plan.name != fam for e in engines):
            raise ValueError("router replicas must serve one pool plan")
        self.engines = list(engines)
        self.cfg = cfg or RouterConfig()
        self.home: Dict[int, int] = {}       # request uid -> replica index
        # control-plane series live in replica 0's registry by default —
        # a serve deployment hands every engine ONE shared registry, so
        # the router's counters land next to the per-engine ones and a
        # single scrape covers the whole deployment
        self.metrics = metrics if metrics is not None else engines[0].metrics
        self._c_submitted = self.metrics.counter(
            "router_submitted_total", "requests routed to a replica")
        self._c_migrations = self.metrics.counter(
            "router_migrations_total", "waiting sequences moved between "
            "replicas under pressure")
        self._c_steps = self.metrics.counter(
            "router_steps_total", "router drive rounds")
        self._g_headroom = self.metrics.gauge(
            "router_headroom", "discounted free capacity per replica "
            "(pages/slots minus queued demand)", ("replica",))
        self.stats = obs_metrics.StatsView({
            "submitted": self._c_submitted.value,
            "migrations": self._c_migrations.value,
            "steps": self._c_steps.value,
        })

    # -- pressure ------------------------------------------------------------

    def _demand_pages(self, eng: Engine, seq: Sequence) -> int:
        """Paged-domain pages the sequence needs at admission on this
        replica (slot-only plans: count the one slot instead, so pressure
        still reflects real demand)."""
        if not eng.plan.has_paged:
            return 1
        if seq.snapshot is not None:
            return max(len(seq.snapshot_pages), 1)
        return eng.sched._pages_for(max(seq.prompt_len, 1))

    def _headroom(self, eng: Engine) -> int:
        """Free capacity minus the queued demand already bound for
        ``eng`` — the minimum over the domains the plan allocates from
        (pages for kv/mla state, slots for constant state): a hybrid
        replica with free pages but no free slots is still full."""
        hs = []
        if eng.plan.has_paged:
            queued = sum(self._demand_pages(eng, s)
                         for s in eng.sched.waiting)
            hs.append(eng.free_pages - queued)
        if eng.sched.slot_alloc is not None:
            hs.append(eng.free_slots - len(eng.sched.waiting))
        return min(hs)

    def pressure(self) -> List[int]:
        return [self._headroom(e) for e in self.engines]

    # -- submission ----------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Route to the replica with the most discounted headroom that can
        hold the request at all; returns the replica index."""
        hr = self.pressure()
        for idx in sorted(range(len(self.engines)), key=lambda i: -hr[i]):
            eng = self.engines[idx]
            if not eng.sched.fits(req):
                continue
            eng.submit(req)
            self.home[req.uid] = idx
            self._c_submitted.inc()
            self.metrics.event("routed", uid=req.uid, replica=idx)
            return idx
        raise ValueError(
            f"request uid={req.uid} fits no replica "
            f"(prompt={len(req.prompt)} + max_new={req.max_new})")

    # -- migration -----------------------------------------------------------

    @staticmethod
    def _capacity(eng: Engine) -> int:
        """Units backing ``_headroom`` for saturation thresholds: the
        SMALLEST domain the plan allocates from, matching _headroom's
        min-over-domains — scaling a slot-bound headroom (<= usable
        slots) against the much larger page count would classify every
        mixed-geometry replica as permanently saturated."""
        caps = []
        if eng.plan.has_paged:
            caps.append(eng.usable_pages)
        if eng.sched.slot_alloc is not None:
            caps.append(eng.usable_slots)
        return min(caps)

    @staticmethod
    def _pool_signature(eng: Engine):
        """Per-domain, per-segment (leaf path, dtype, page-row shape) —
        everything a snapshot scatter must agree on except the pools'
        page/slot COUNTS. The enc-dec memory row shape is included (the
        snapshot carries the encoded memory)."""
        def seg_sig(seg, axis):
            if seg is None:
                return None
            leaves = jax.tree_util.tree_flatten_with_path(seg)[0]
            return tuple(sorted(
                (jax.tree_util.keystr(kp), str(v.dtype),
                 v.shape[:axis] + v.shape[axis + 1:])
                for kp, v in leaves))
        sig = tuple(tuple(seg_sig(s, 1) for s in eng.pools[dom])
                    for dom in ("paged", "slot"))
        mem = eng.pools.get("memory")
        if mem is not None:
            sig += ((str(mem.dtype), mem.shape[1:]),)
        return sig

    def _can_place(self, src: Engine, dst: Engine, seq: Sequence) -> bool:
        """Whether ``seq`` can be adopted by ``dst``. A preemption
        snapshot scatters page rows verbatim, so the full page geometry —
        page_size AND pool leaf structure/dtype/row shape (int8 vs fp
        pools, bf16 vs f32 configs) — must match exactly; heterogeneous
        pools can still serve together, but snapshot-carrying sequences
        are pinned to like-shaped replicas. Any non-constant-state
        sequence must also fit the destination's token capacity."""
        dc = dst.sched_cfg
        if seq.snapshot is not None:
            if src.sched_cfg.page_size != dc.page_size:
                return False
            if len(seq.snapshot_pages) > dc.table_width:
                return False
            if self._pool_signature(src) != self._pool_signature(dst):
                return False
        return dst.sched.fits(seq.req)

    def migrate(self) -> int:
        """Move waiting sequences from saturated replicas to roomy ones.
        Returns how many were moved this round."""
        if not self.cfg.migrate or len(self.engines) < 2:
            return 0
        moved = 0
        for src_i, src in enumerate(self.engines):
            if moved >= self.cfg.migrate_per_round:
                break
            src_hr = self._headroom(src)
            if src_hr >= self.cfg.saturation * self._capacity(src):
                continue
            # saturated: offload the tail of the waiting queue (the head
            # is closest to admission here; the tail pays the wait)
            for seq in sorted(src.sched.waiting, key=src.sched._rank,
                              reverse=True):
                if moved >= self.cfg.migrate_per_round:
                    break
                hr = self.pressure()
                dst_i = max(range(len(self.engines)), key=lambda i: hr[i])
                dst = self.engines[dst_i]
                if dst_i == src_i or hr[dst_i] < max(2 * src_hr, 1):
                    break                    # nowhere meaningfully roomier
                if hr[dst_i] < self._demand_pages(dst, seq) or \
                        not self._can_place(src, dst, seq):
                    continue                 # THIS seq doesn't fit; smaller
                                             # ones behind it still might
                src.sched.release_waiting(seq)
                dst.sched.adopt(seq)
                if seq.req.trace is not None:
                    seq.req.trace.stamp("migrated")
                self.home[seq.req.uid] = dst_i
                self._c_migrations.inc()
                self.metrics.event("migrated", uid=seq.req.uid,
                                   src=src_i, dst=dst_i)
                moved += 1
                src_hr = self._headroom(src)
        return moved

    # -- driving -------------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return any(e.sched.has_work for e in self.engines)

    def step(self) -> bool:
        """One round: each busy replica takes one engine step, then one
        migration pass. Returns whether anything progressed."""
        progressed = False
        for eng in self.engines:
            if eng.sched.has_work:
                progressed = eng.step() or progressed
        if self.migrate() > 0:
            progressed = True
        self._c_steps.inc()
        for i, hr in enumerate(self.pressure()):
            self._g_headroom.labels(replica=i).set(hr)
        return progressed

    def run(self, on_step=None) -> List[Request]:
        """Drain all submitted requests; returns the completed ones.
        ``on_step(router)`` fires after every round (periodic reporter)."""
        tracked = [s.req for e in self.engines
                   for s in e.sched.waiting + e.sched.running]
        stall = 0
        while self.has_work:
            progressed = self.step()
            if on_step is not None:
                on_step(self)
            stall = 0 if progressed else stall + 1
            if stall > 2 + len(self.engines):
                free = [(e.free_pages, e.free_slots) for e in self.engines]
                raise RuntimeError(
                    f"router stalled: no replica can place the remaining "
                    f"requests (free (pages, slots) per replica: {free})")
        return [r for r in tracked if r.done]

    def describe(self) -> Dict:
        return {"replicas": len(self.engines),
                "free_pages": [e.free_pages for e in self.engines],
                "free_fraction": [round(e.free_fraction, 3)
                                  for e in self.engines],
                "per_engine_stats": [dict(e.stats) for e in self.engines],
                **{k: v for k, v in self.stats.items()}}
