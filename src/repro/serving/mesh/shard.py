"""Pool / parameter layout for mesh-sharded paged serving.

The contract mirrors ``distributed/sharding.py``'s dense-cache rules:
the *model* axis shards the head (or feature) dim of every pool family,
and any dim that does not divide the axis DEGRADES to replication — the
framework never refuses a config for divisibility. Page *tables* stay
host-local (they are scheduler bookkeeping; only the pools are device
state).

Per-family layout (leaf shapes carry a leading layer axis L):

=========  =========================================  ==================
family     pool leaf (global shape)                   model-axis dim
=========  =========================================  ==================
``kv``     k/v        (L, N, P, Hkv, hd)              3 (kv heads)
           k/v_scale  (L, N, P, 1)    [int8 pools]    replicated (tiny)
``srf``    s          (L, S, Hq, m, dv)               2 (q heads)
           z          (L, S, Hq, m)                   2 (q heads)
``mla``    c / kpe    (L, N, P, lora|rope)            replicated (the
                                                      latent IS the
                                                      compressed form)
``ssd``    conv / ssm (L, S, ...)                     replicated (O(1)
                                                      constant state)
``mem``    enc memory (S, enc_len, d_model)           replicated (read-
                                                      only, d_model dim)
=========  =========================================  ==================

Mixed-geometry plans compose these rules per component: a hybrid layer's
kv sub-pool shards on Hkv while its ssd sub-pool replicates (each shard
repeats the identical constant-state update inside the shard_map body);
an enc-dec model shards its self-attention kv pages and replicates the
encoder-memory pool, with the cross-attention projections column-sliced
like the self-attention ones.

Head-sharded pools only work when the q/kv head counts divide the model
axis AND the attention projections are sliced the same way (column-
parallel wq/wk/wv, row-parallel wo — the Megatron split), so
``paged_tp`` is the single gate: it returns the effective tensor-
parallel width (1 = fully replicated serving) and every other helper
derives from it.
"""
from __future__ import annotations

import re
from typing import Dict, List

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as S


def paged_tp(cfg, mesh) -> int:
    """Effective model-axis TP width for paged serving.

    The mesh's ``model`` axis size when the plan's ATTENTION component
    shards (kv / srf with dividing head counts), else 1 — the
    replication-degradation contract of ``distributed/sharding.py``
    applied to page pools. The whole layout degrades at once: a partially
    sharded attention (pools split but projections whole) cannot run
    per-shard. Pure-SSM stacks and MLA latents always replicate.
    """
    tp = S.axis_size(mesh, "model")
    if tp <= 1:
        return 1
    from repro.serving import paged_cache
    plan = paged_cache.plan_for(cfg)
    if plan.attn_family not in ("kv", "srf"):
        return 1                       # mla latents / pure ssm: replicate
    if cfg.n_heads % tp or cfg.n_kv_heads % tp:
        return 1
    if plan.attn_family == "srf":
        n_pm = cfg.n_heads if cfg.is_mla else cfg.n_kv_heads
        if n_pm % tp:                  # per-head P-model param stacks
            return 1
    return tp


# ---------------------------------------------------------------------------
# pool specs
# ---------------------------------------------------------------------------

def _pool_leaf_spec(fam: str, name: str, ndim: int, tp: int) -> P:
    ent = [None] * ndim
    if tp > 1:
        if fam == "kv" and name in ("k", "v") and ndim == 5:
            ent[3] = "model"                       # (L, N, P, Hkv, hd)
        elif fam == "srf" and name in ("s", "z") and ndim >= 4:
            ent[2] = "model"                       # (L, S, Hq, ...)
    return P(*ent)


def pool_specs(cfg, mesh, paged=None) -> Dict:
    """PartitionSpec pytree matching ``paged_cache.init_pools`` output
    (the {"paged", "slot"[, "memory"]} container, per-component specs)."""
    from repro.serving import paged_cache
    plan = paged_cache.plan_for(cfg)
    tp = paged_tp(cfg, mesh)
    specs: Dict = {"paged": [], "slot": []}
    for kind, count, comps in plan.segments:
        pseg: Dict = {}
        sseg: Dict = {}
        for comp, fam_name in comps:
            fam = paged_cache.FAMILIES[fam_name]
            one = jax.eval_shape(
                lambda f=fam: f.layer_pool(cfg, 2, 2, paged))
            d = {k: _pool_leaf_spec(fam_name, k, v.ndim + 1, tp)
                 for k, v in one.items()}
            (sseg if fam.constant_state else pseg)[comp] = d
        specs["paged"].append(pseg or None)
        specs["slot"].append(sseg or None)
    if plan.has_memory:
        specs["memory"] = P()
    return specs


def place_pools(pools: Dict, cfg, mesh, paged=None) -> Dict:
    """Lay freshly initialized pools out on the mesh (NamedSharding)."""
    specs = pool_specs(cfg, mesh, paged)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), pools, specs)


# ---------------------------------------------------------------------------
# param specs (serving flavor: TP on attention only)
# ---------------------------------------------------------------------------

_STACKED = re.compile(r"^segments/\d+/")

# column parallel only: slice the output (head-block) dim of q/k/v (both
# self- and cross-attention, and the MLA up-projections) so each shard
# computes its own heads. wo stays REPLICATED on purpose: the step
# all-gathers the per-shard head blocks (collectives.stitch_heads) and
# contracts the full wo locally, which reduces d_model in exactly the
# single-host order — greedy tokens stay bit-identical, where a
# row-parallel wo + psum re-associates the sum. MLP / SSM / embed / head
# / norms and the whole enc-dec ENCODER stay replicated too: serving
# batches are small, attention state is what scales.
_COL = re.compile(r"(attn|cross)/(wq|wk|wv|wuk|wuv)$")
_BIAS = re.compile(r"attn/(bq|bk|bv)$")
_SRF = re.compile(r"attn/srf/")


def _serving_rule(path: str, shape, tp: int) -> P:
    ent = [None] * len(shape)
    if tp <= 1:
        return P(*ent)
    if _COL.search(path) and len(shape) == 2 and shape[1] % tp == 0:
        ent[1] = "model"
    elif _BIAS.search(path) and len(shape) == 1 and shape[0] % tp == 0:
        ent[0] = "model"
    elif _SRF.search(path) and len(shape) >= 1 and shape[0] % tp == 0:
        ent[0] = "model"               # per-kv-head P-model param stacks
    return P(*ent)


def serving_param_specs(params, cfg, mesh) -> Dict:
    """Param specs for the shard_map'd paged step: attention projections
    sliced over 'model' (per-shard heads match the per-shard pool heads),
    everything else replicated. Fully replicated when ``paged_tp`` is 1.
    """
    tp = paged_tp(cfg, mesh)

    def f(path, x):
        ps = S._path_str(path)
        if ps.startswith("encoder/") or ps.startswith("enc_norm"):
            return P(*([None] * x.ndim))   # encoder runs outside the step
        if _STACKED.match(ps):
            inner = _serving_rule(ps, x.shape[1:], tp)
            return P(None, *inner)
        return _serving_rule(ps, x.shape, tp)
    return jax.tree_util.tree_map_with_path(f, params)


def place_params(params, cfg, mesh) -> Dict:
    specs = serving_param_specs(params, cfg, mesh)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), params, specs)


def local_cfg(cfg, tp: int):
    """The per-shard view of the model config inside the shard_map body:
    head counts divided by the TP width (q_dim/kv_dim are derived, so the
    sliced wq/wk/wv/wo — and cross-attention — shapes line up
    automatically; SSM dims derive from d_model and stay whole)."""
    if tp <= 1:
        return cfg
    import dataclasses
    return dataclasses.replace(cfg, n_heads=cfg.n_heads // tp,
                               n_kv_heads=cfg.n_kv_heads // tp)
