"""Mesh-sharded paged serving: distributed page pools, a cross-host
request router, and the shard_map-wrapped paged decode step.

``shard.py`` owns the layout contract (which pool/param dims go on the
mesh's ``model`` axis, and when a family degrades to replication);
``router.py`` spreads requests across per-host ``Engine`` replicas by
free-page pressure and migrates waiting requests off saturated hosts.
The shard_map step itself is built by ``launch.steps.make_paged_step``
so the engine keeps a single step-factory entry point.
"""
from .router import Router, RouterConfig                 # noqa: F401
from .shard import paged_tp, pool_specs, serving_param_specs  # noqa: F401
