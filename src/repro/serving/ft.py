"""Fault-tolerant serving: replica health detection + request rescue
primitives.

The control plane mirrors the paper's seed-recycling economics: request
state is cheaply reconstructible, so replica death never has to lose
work. Snapshots and prefill progress already travel between replicas
(``Scheduler.release_waiting``/``adopt``), and anything without a
current snapshot can be *replayed* — the already-emitted tokens are
folded into the prompt as a forced prefix (:func:`fold_emitted_prefix`),
so a survivor re-prefills deterministically and continues exactly where
the dead replica stopped. Exactly-once output is guaranteed by the
request uid plus the emitted-token high-water mark: ``out_tokens`` is
never truncated, the engine only ever appends past it.

:class:`ReplicaWatchdog` adapts ``ft/straggler.py``'s EMA-vs-median
detector to serving replicas, with two deliberate changes:

* step times are read from the PR 6 metrics registry
  (``engine_step_seconds{engine=...}``), not wall-clocked by the caller
  — so a simulated stall injected through the engine's step-time clock
  (``serving/chaos.py``) is detected exactly like a real one;
* each replica's EMA is compared against the median of its *peers*
  (the global median breaks down at 2 replicas: the slow replica IS the
  upper median and can never exceed ``threshold`` x itself).

A replica is marked dead after ``grace_steps`` consecutive slow flags,
``stuck_rounds`` consecutive no-progress rounds with work queued, or an
exception escaping ``Engine.step`` (the router handles that case
directly). ``serving/mesh/router.py`` owns quarantine / rescue /
revive; this module owns detection and the replay arithmetic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Set, Tuple

import numpy as np

from repro.obs import spans as obs_spans

from .engine import Request


@dataclass(frozen=True)
class FTConfig:
    """Knobs for the fault-tolerant router (``Router(ft=FTConfig())``)."""
    ema: float = 0.6            # smoothing of per-replica step time
    threshold: float = 4.0      # x peer-median EMA -> slow flag
    grace_steps: int = 3        # consecutive slow flags before quarantine
    stuck_rounds: int = 4       # no-progress rounds with work -> quarantine
    probe_max_new: int = 2      # tokens a revive() probe must produce
    degraded_rounds: int = 3    # exhausted rounds before shedding new load


class ReplicaWatchdog:
    """Per-replica health detector driven by the shared metrics registry.

    The router calls :meth:`poll_step_time` + :meth:`observe` once per
    replica per drive round; a non-``None`` return value is the
    quarantine reason. Detection is pure host-side arithmetic — no
    device traffic, no timers of its own.
    """

    def __init__(self, n_replicas: int, cfg: FTConfig, spans=None):
        self.cfg = cfg
        self.spans = spans if spans is not None else obs_spans.NOOP
        self.ema: List[Optional[float]] = [None] * n_replicas
        self.flags: List[int] = [0] * n_replicas
        self.stuck: List[int] = [0] * n_replicas
        self.dead: Set[int] = set()
        # (count, sum) watermark per replica into engine_step_seconds
        self._seen: List[Tuple[int, float]] = [(0, 0.0)] * n_replicas

    def poll_step_time(self, idx: int, engine) -> Optional[float]:
        """Mean of the step-time observations the engine recorded since
        the last poll, read from ITS registry (replicas may share one —
        the ``engine`` label keeps the series apart). ``None`` when the
        registry is disabled or nothing new landed."""
        h = engine.metrics.histogram(
            "engine_step_seconds", "wall time of one engine step",
            ("engine",)).labels(engine=engine.engine_id)
        c, s = h.count(), h.sum()
        c0, s0 = self._seen[idx]
        self._seen[idx] = (c, s)
        if c <= c0:
            return None
        return (s - s0) / (c - c0)

    def _peer_median(self, idx: int) -> Optional[float]:
        """Median EMA over the OTHER live replicas."""
        ts = sorted(e for i, e in enumerate(self.ema)
                    if i != idx and i not in self.dead and e is not None)
        if not ts:
            return None
        return ts[len(ts) // 2]

    def observe(self, idx: int, dt: Optional[float], progressed: bool,
                has_work: bool) -> Optional[str]:
        """Feed one drive round's outcome for replica ``idx``; returns a
        quarantine reason or ``None``."""
        if idx in self.dead:
            return None
        cfg = self.cfg
        # stuck: the replica holds work it cannot advance (corrupt
        # admission, exhausted pool) — step-time EMA never sees these
        # because the no-op steps are FAST
        if has_work and not progressed:
            self.stuck[idx] += 1
            self.spans.instant("watchdog_flag", replica_idx=idx,
                               flag="stuck", rounds=self.stuck[idx])
            if self.stuck[idx] >= cfg.stuck_rounds:
                return (f"stuck: no progress for {self.stuck[idx]} "
                        "consecutive rounds with work queued")
        else:
            self.stuck[idx] = 0
        if dt is not None:
            prev = self.ema[idx]
            self.ema[idx] = dt if prev is None \
                else cfg.ema * prev + (1 - cfg.ema) * dt
            med = self._peer_median(idx)
            if med is not None and self.ema[idx] > cfg.threshold * med:
                self.flags[idx] += 1
                self.spans.instant("watchdog_flag", replica_idx=idx,
                                   flag="slow", rounds=self.flags[idx])
                if self.flags[idx] >= cfg.grace_steps:
                    return (f"slow: step-time ema {self.ema[idx]:.4g}s > "
                            f"{cfg.threshold}x peer median {med:.4g}s for "
                            f"{self.flags[idx]} consecutive polls")
            else:
                self.flags[idx] = 0
        return None

    def mark_dead(self, idx: int) -> None:
        self.dead.add(idx)

    def revive(self, idx: int) -> None:
        """Clear the replica's health history so a revived replica is not
        instantly re-flagged by its pre-death EMA."""
        self.dead.discard(idx)
        self.ema[idx] = None
        self.flags[idx] = 0
        self.stuck[idx] = 0


# ---------------------------------------------------------------------------
# rescue primitives
# ---------------------------------------------------------------------------

def snapshot_is_current(seq) -> bool:
    """Whether a sequence's copy-on-preempt snapshot still reflects its
    full progress. True exactly for evicted-and-still-waiting sequences
    (nothing decodes while waiting); a RUNNING sequence's device state is
    ahead of any old snapshot, so it must be replayed instead."""
    return seq.snapshot is not None


def fold_emitted_prefix(req: Request) -> int:
    """Fold the already-emitted tokens into the prompt as a forced
    prefix, so a rescued request re-prefills deterministically on a
    survivor and greedy decode continues bit-identically from where the
    dead replica stopped. Returns the emitted-token high-water mark.

    ``out_tokens`` is deliberately NOT cleared: the engine appends new
    tokens after the high-water mark (``len(out_tokens) >= max_new``
    terminates on the same total), so every token is emitted exactly
    once — replay never re-emits the prefix, it only re-computes its
    cache state."""
    hwm = len(req.out_tokens)
    if hwm:
        prompt = np.asarray(req.prompt)
        req.prompt = np.concatenate(
            [prompt, np.asarray(req.out_tokens, dtype=prompt.dtype)])
    return hwm


def make_probe(cfg, uid: int = -1, max_new: int = 2) -> Request:
    """A tiny greedy request used by ``Router.revive`` to prove a
    quarantined replica is healthy again before it rejoins placement."""
    prompt = (np.arange(1, 4, dtype=np.int32) % cfg.vocab).astype(np.int32)
    enc = None
    if cfg.is_encdec:
        from repro.models import frontends
        enc = frontends.synthetic_audio_features(
            np.random.default_rng(0), cfg)
    return Request(uid=uid, prompt=prompt, max_new=max_new, enc_emb=enc)
