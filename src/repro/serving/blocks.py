"""Page/block bookkeeping for the paged serving cache (host-side control
plane; no jax here).

The pools are fixed device allocations (see ``paged_cache``); this
module hands out ids into them and tracks which request owns what. The
scheduler runs one :class:`BlockAllocator` per index domain: the *paged*
domain, where full-KV and MLA-latent requests take
``ceil(len / page_size)`` growable pages tracked in a per-request
:class:`BlockTable`, and the *slot* domain (page_size 1), where
constant-size states — the paper's O(m d) SRF state, the SSD state, the
enc-dec encoder memory — take exactly one slot for the request's whole
lifetime. A mixed-geometry request (hybrid, enc-dec) owns both.

Id 0 is reserved in both domains as the *null page/slot*: padded batch
rows point their block tables (and slot vector) at it, so scatters from
inactive rows land in scratch memory instead of corrupting live
requests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

NULL_PAGE = 0


class BlockAllocator:
    """Free-list page allocator over a fixed pool of ``num_pages`` pages.

    Invariants (tested):
      * a page is never handed out twice while allocated
      * ``free`` returns pages to the pool exactly once
      * page ``NULL_PAGE`` is never allocated
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop -> 1,2,..
        self._allocated: set = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._allocated)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages, or None if the pool cannot satisfy the request."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: List[int]) -> None:
        for pg in pages:
            if pg not in self._allocated:
                raise ValueError(f"double free / foreign page {pg}")
            self._allocated.remove(pg)
            self._free.append(pg)

    def defrag_plan(self) -> Dict[int, int]:
        """Compaction map {old_page: new_page} packing live pages into the
        lowest indices. The caller must apply the map to its block tables
        AND copy the pool rows (``paged_cache.apply_moves``) before using
        the allocator again; this method re-labels internal state only."""
        live = sorted(self._allocated)
        targets = range(1, len(live) + 1)
        moves = {old: new for old, new in zip(live, targets) if old != new}
        if moves:
            self._allocated = set(targets)
            self._free = [p for p in range(self.num_pages - 1, 0, -1)
                          if p not in self._allocated]
        return moves


@dataclass
class BlockTable:
    """Per-request page list + logical length (tokens written)."""
    pages: List[int] = field(default_factory=list)
    length: int = 0

    def padded(self, width: int) -> List[int]:
        """Fixed-width view for the device block-table tensor."""
        if len(self.pages) > width:
            raise ValueError(f"{len(self.pages)} pages > table width {width}")
        return self.pages + [NULL_PAGE] * (width - len(self.pages))

    def pages_needed(self, new_length: int, page_size: int) -> int:
        """How many NEW pages must be allocated to grow to ``new_length``.
        (Paged-domain only: constant-size states live in the slot domain
        and never grow — see the scheduler's plan handling.)"""
        want = -(-new_length // page_size)        # ceil
        return max(0, want - len(self.pages))
