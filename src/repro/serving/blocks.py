"""Page/block bookkeeping for the paged serving cache (host-side control
plane; no jax here).

The pools are fixed device allocations (see ``paged_cache``); this
module hands out ids into them and tracks which request owns what. The
scheduler runs one :class:`BlockAllocator` per index domain: the *paged*
domain, where full-KV and MLA-latent requests take
``ceil(len / page_size)`` growable pages tracked in a per-request
:class:`BlockTable`, and the *slot* domain (page_size 1), where
constant-size states — the paper's O(m d) SRF state, the SSD state, the
enc-dec encoder memory — take exactly one slot for the request's whole
lifetime. A mixed-geometry request (hybrid, enc-dec) owns both.

Pages are REFCOUNTED: ``alloc`` hands a page out at refcount 1,
``share`` adds owners (the prefix cache and every request reusing a
cached prefix hold one reference each), and ``free`` only returns a
page to the free list when its last reference drops. A shared page is
read-only by contract — a writer must COW-fork it first (see
``serving/prefix/cow.py``); the allocator itself only counts.

Id 0 is reserved in both domains as the *null page/slot*: padded batch
rows point their block tables (and slot vector) at it, so scatters from
inactive rows land in scratch memory instead of corrupting live
requests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

NULL_PAGE = 0


class BlockAllocator:
    """Free-list page allocator over a fixed pool of ``num_pages`` pages,
    with per-page reference counts for prefix sharing.

    Invariants (tested):
      * a page is never handed out twice while allocated
      * ``free`` decrements exactly one reference; the page returns to
        the pool only at refcount 0, and freeing a page with no live
        reference RAISES (double free / foreign page) instead of
        silently re-listing it — re-listing would let the same page be
        handed to two requests, which is silent cache corruption
      * page ``NULL_PAGE`` is never allocated and never refcounted
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop -> 1,2,..
        self._ref: Dict[int, int] = {}

    @property
    def _allocated(self) -> set:
        """Set of pages with at least one live reference (compat view —
        pre-refcount callers and tests read this)."""
        return set(self._ref)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._ref)

    @property
    def total_refs(self) -> int:
        """Sum of refcounts over all allocated pages — the conservation
        quantity for shared pages: equals the number of (owner, page)
        edges across request tables, the prefix cache, and transient
        pins."""
        return sum(self._ref.values())

    def refcount(self, pg: int) -> int:
        return self._ref.get(pg, 0)

    def is_shared(self, pg: int) -> bool:
        """More than one live owner: writing requires a COW fork."""
        return self._ref.get(pg, 0) > 1

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages at refcount 1, or None if the pool cannot satisfy."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._ref[pg] = 1
        return pages

    def share(self, pages: List[int]) -> None:
        """Add one reference per page (a new owner of already-allocated
        pages: a prefix-cache entry, or a request attaching to one)."""
        for pg in pages:
            if pg not in self._ref:
                raise ValueError(f"share of unallocated page {pg}")
            self._ref[pg] += 1

    def free(self, pages: List[int]) -> List[int]:
        """Drop one reference per page; pages whose LAST reference drops
        return to the free list (returned for the caller's bookkeeping).
        Raises on a page with no live reference — a double free must
        never silently re-list a page another owner still reads."""
        released: List[int] = []
        for pg in pages:
            n = self._ref.get(pg)
            if n is None:
                raise ValueError(f"double free / foreign page {pg}")
            if n == 1:
                del self._ref[pg]
                self._free.append(pg)
                released.append(pg)
            else:
                self._ref[pg] = n - 1
        return released

    def defrag_plan(self) -> Dict[int, int]:
        """Compaction map {old_page: new_page} packing live pages into the
        lowest indices. The caller must apply the map to its block tables
        AND the prefix cache AND copy the pool rows
        (``paged_cache.apply_moves``) before using the allocator again;
        this method re-labels internal state (refcounts travel with the
        page) only."""
        live = sorted(self._ref)
        targets = range(1, len(live) + 1)
        moves = {old: new for old, new in zip(live, targets) if old != new}
        if moves:
            self._ref = {moves.get(pg, pg): n for pg, n in self._ref.items()}
            self._free = [p for p in range(self.num_pages - 1, 0, -1)
                          if p not in self._ref]
        return moves


@dataclass
class BlockTable:
    """Per-request page list + logical length (tokens written)."""
    pages: List[int] = field(default_factory=list)
    length: int = 0

    def padded(self, width: int) -> List[int]:
        """Fixed-width view for the device block-table tensor."""
        if len(self.pages) > width:
            raise ValueError(f"{len(self.pages)} pages > table width {width}")
        return self.pages + [NULL_PAGE] * (width - len(self.pages))

    def pages_needed(self, new_length: int, page_size: int) -> int:
        """How many NEW pages must be allocated to grow to ``new_length``.
        (Paged-domain only: constant-size states live in the slot domain
        and never grow — see the scheduler's plan handling.)"""
        want = -(-new_length // page_size)        # ceil
        return max(0, want - len(self.pages))
