"""Continuous-batching scheduler: admission, chunked prefill, FCFS or
priority ordering, and preemption-by-eviction.

Replaces the old lock-step slot loop. Requests wait in an admission
queue until the shared :class:`~repro.serving.blocks.BlockAllocator` can
hold their prompt; admitted sequences prefill in fixed-size chunks
(bounding any single step's cost, so a long prompt cannot stall decode
for everyone), then join the batched decode set. When decode needs a
page the pool cannot supply, the lowest-ranked running sequence is
evicted: its pages are snapshotted to host memory (copy-on-preempt),
freed, and the sequence re-enters the admission queue to be swapped back
in later — no work is lost.

Page accounting is MIXED-GEOMETRY per request, driven by the config's
:class:`~repro.serving.paged_cache.PoolPlan`: the *paged* domain holds
``ceil(len / page_size)`` growable pages (kv / mla attention state), the
*slot* domain holds exactly one constant-size slot (srf / ssd states and
the enc-dec encoder memory). A dense model uses pages only, a pure
SSM/SRF model slots only, and a hybrid or enc-dec request owns both — a
request is admitted only when BOTH domains can supply it, and eviction /
completion returns both.

The scheduler is pure host-side bookkeeping; the engine owns device
state and tells the scheduler what happened.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

from .blocks import BlockAllocator, BlockTable
from .prefix import cow


@dataclass(frozen=True)
class SchedConfig:
    max_batch: int = 8          # decode rows per step (jit shape)
    prefill_batch: int = 4      # prefill rows per step
    prefill_chunk: int = 16     # tokens per prefill chunk
    page_size: int = 16
    num_pages: int = 64         # paged-domain pages incl. reserved null page
    table_width: int = 8        # M: max pages per request
    num_slots: int = 0          # slot-domain slots incl. reserved null slot
                                # (0: derive max_batch + 1; unused when the
                                # plan has no constant-state component)
    policy: str = "fcfs"        # fcfs | priority


def tenant_of(req) -> str:
    """Metric label value for a request's tenant namespace. The default
    (unset) namespace is ``"-"`` so the label is never empty."""
    return getattr(req, "namespace", "") or "-"


@dataclass
class Sequence:
    """Scheduler-side state of one request."""
    req: object                       # serving.engine.Request
    arrival: int
    table: BlockTable = field(default_factory=BlockTable)
    slot: Optional[int] = None        # constant-state slot id (plan.needs_slot)
    prefill_pos: int = 0              # prompt tokens already cached
    snapshot: Optional[object] = None  # host pages while preempted
    snapshot_pages: List[int] = field(default_factory=list)
    # -- prefix sharing (serving/prefix) --
    ns: int = 0                       # cache namespace (enc-dec: enc hash)
    hit_tokens: int = 0               # prompt tokens served from the cache
    shared_pages: List[int] = field(default_factory=list)
    fork: Optional[cow.Fork] = None   # pending COW copy (engine applies)
    state_payload: Optional[object] = None  # donor slot-state to restore

    @property
    def prompt_len(self) -> int:
        return len(self.req.prompt)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= self.prompt_len


class Scheduler:
    """``plan`` is the config's :class:`~repro.serving.paged_cache.PoolPlan`
    (anything exposing ``has_paged`` / ``needs_slot`` works)."""

    def __init__(self, cfg: SchedConfig, plan, metrics=None,
                 labels: Optional[Dict[str, str]] = None, spans=None):
        self.cfg = cfg
        self.plan = plan
        self.spans = spans if spans is not None else obs_spans.NOOP
        self.alloc = BlockAllocator(cfg.num_pages, cfg.page_size)
        self.num_slots = 0
        self.slot_alloc: Optional[BlockAllocator] = None
        if plan.needs_slot:
            self.num_slots = max(cfg.num_slots or (cfg.max_batch + 1), 2)
            self.slot_alloc = BlockAllocator(self.num_slots, 1)
        self.waiting: List[Sequence] = []
        self.running: List[Sequence] = []
        self._arrivals = 0
        self.prefix = None                # PrefixCache (engine attaches)
        self._init_metrics(metrics, labels)

    def attach_prefix(self, cache) -> None:
        """Attach the engine's :class:`~repro.serving.prefix.PrefixCache`
        (it shares ``self.alloc``): admission becomes prefix-aware and
        allocator pressure can evict cache entries. The cache reports
        back whenever it changes the pool so the scheduler's page gauges
        stay truthful."""
        self.prefix = cache
        cache.on_pool_change = self._sync_gauges

    # -- metrics -------------------------------------------------------------

    def _init_metrics(self, metrics, labels) -> None:
        """Counters/gauges in the shared registry; ``self.stats`` is a
        read-only compat view over them (PRs 1-5 exposed a plain dict).
        The engine passes its registry and ``{"engine": id}`` label so a
        router deployment reads every replica from ONE registry; a
        scheduler built standalone (tests) gets a private registry."""
        self.metrics = metrics if metrics is not None \
            else obs_metrics.MetricsRegistry()
        labels = dict(labels or {"engine": "-"})
        self._labels = labels
        ln = tuple(labels)
        c = lambda name, help: self.metrics.counter(  # noqa: E731
            name, help, ln).labels(**labels)
        g = lambda name, help: self.metrics.gauge(    # noqa: E731
            name, help, ln).labels(**labels)
        self._c_submitted = c("sched_submitted_total", "requests submitted")
        self._c_admitted = c("sched_admitted_total", "admissions (incl. "
                             "swap-ins of preempted sequences)")
        self._c_finished = c("sched_finished_total", "requests finished")
        self._c_preempted = c("sched_preemptions_total", "evictions")
        self._c_defrags = c("sched_defrags_total", "defrag passes")
        self._c_released = c("sched_released_total",
                             "sequences released for migration")
        self._c_adopted = c("sched_adopted_total",
                            "sequences adopted from another replica")
        self._c_expired = c("sched_expired_total",
                            "waiting sequences expired past deadline")
        self._g_waiting = g("sched_waiting", "sequences in admission queue")
        self._g_running = g("sched_running", "sequences holding capacity")
        self._g_free_pages = g("sched_free_pages", "paged-domain free pages")
        self._g_used_pages = g("sched_used_pages", "paged-domain used pages")
        self._g_free_slots = g("sched_free_slots", "slot-domain free slots")
        self._g_used_slots = g("sched_used_slots", "slot-domain used slots")
        # per-tenant fairness substrate: pages currently held by RUNNING
        # sequences, broken down by the request's namespace (vanished
        # tenants are zeroed, not deleted — scrapes see the drop)
        self._g_tenant_pages = self.metrics.gauge(
            "tenant_pages_held", "paged-domain pages held by running "
            "sequences, by tenant namespace", ln + ("tenant",))
        self._tenant_page_children: Dict[str, object] = {}
        self.stats = obs_metrics.StatsView({
            "admitted": self._c_admitted.value,
            "preemptions": self._c_preempted.value,
            "defrags": self._c_defrags.value,
            "submitted": self._c_submitted.value,
            "finished": self._c_finished.value,
        })
        self._sync_gauges()

    def _sync_gauges(self) -> None:
        self._g_waiting.set(len(self.waiting))
        self._g_running.set(len(self.running))
        self._g_free_pages.set(self.alloc.free_pages)
        self._g_used_pages.set(self.alloc.used_pages)
        if self.slot_alloc is not None:
            self._g_free_slots.set(self.slot_alloc.free_pages)
            self._g_used_slots.set(self.slot_alloc.used_pages)
        held: Dict[str, int] = {}
        for seq in self.running:
            t = tenant_of(seq.req)
            held[t] = held.get(t, 0) + len(seq.table.pages)
        for t, n in held.items():
            ch = self._tenant_page_children.get(t)
            if ch is None:
                ch = self._g_tenant_pages.labels(
                    **dict(self._labels, tenant=t))
                self._tenant_page_children[t] = ch
            ch.set(n)
        for t, ch in self._tenant_page_children.items():
            if t not in held:
                ch.set(0)

    # -- ordering -----------------------------------------------------------

    def _rank(self, seq: Sequence) -> Tuple:
        """Sort key: best-to-schedule first, deadline-aware (EDF): among
        equal priority, deadlined sequences come before deadline-less
        ones, earliest deadline first. The key is also what
        ``_victim_order`` reverses, so deadlined work is evicted LAST.
        Non-deadlined requests keep the pre-deadline ordering exactly
        (their EDF component is the constant ``(1, 0.0)``)."""
        da = getattr(seq.req, "deadline_at", None)
        edf = (0, da) if da is not None else (1, 0.0)
        if self.cfg.policy == "priority":
            return (-getattr(seq.req, "priority", 0), *edf, seq.arrival)
        return (*edf, seq.arrival)

    def _victim_order(self) -> List[Sequence]:
        """Worst-to-keep first (reverse of schedule rank)."""
        return sorted(self.running, key=self._rank, reverse=True)

    # -- submission / admission --------------------------------------------

    def fits(self, req) -> bool:
        """Whether this scheduler's pool geometry can ever hold the
        request (the admission capacity rule; shared with the router so
        the two cannot drift). Slot-domain state is constant-size, so
        only the paged component bounds the token budget."""
        if not self.plan.has_paged:
            return True
        return len(req.prompt) + self._remaining_new(req) <= \
            self.cfg.table_width * self.cfg.page_size

    @staticmethod
    def _remaining_new(req) -> int:
        """Tokens the request can still emit. A replica-failure replay
        folds emitted tokens into the prompt without truncating
        ``out_tokens`` (serving/ft.py), so its total budget at finish is
        unchanged — counting the full ``max_new`` again would double the
        emitted prefix and reject rescues that actually fit."""
        emitted = len(getattr(req, "out_tokens", ()) or ())
        return max(1, req.max_new - emitted)

    def submit(self, req) -> Sequence:
        if len(req.prompt) == 0:
            raise ValueError("empty prompt (need >= 1 token to prefill)")
        if not self.fits(req):
            cap = self.cfg.table_width * self.cfg.page_size
            need = len(req.prompt) + self._remaining_new(req)
            raise ValueError(f"request needs {need} "
                             f"tokens > capacity {cap}")
        seq = Sequence(req=req, arrival=self._arrivals)
        self._arrivals += 1
        self.waiting.append(seq)
        self._c_submitted.inc()
        self._g_waiting.set(len(self.waiting))
        return seq

    def _pages_for(self, n_tokens: int) -> int:
        if not self.plan.has_paged:
            return 0
        return max(1, -(-n_tokens // self.cfg.page_size))

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """Allocate, evicting prefix-cache entries under pressure: the
        cache is elastic capacity — LRU unpinned leaves are dropped until
        the allocation fits or the cache runs dry."""
        pages = self.alloc.alloc(n)
        if pages is None and self.prefix is not None and \
                self.prefix.evict_for(n - self.alloc.free_pages) > 0:
            pages = self.alloc.alloc(n)
        return pages

    def _lookup_prefix(self, seq: Sequence) -> Optional[cow.PrefixMatch]:
        """Prefix-cache lookup for a FRESH admission (a preemption
        snapshot already has its exact pages; restoring shared content
        into them would alias nothing anyway). Slot-bearing plans only
        match at a donor's state point — the cache enforces that."""
        if self.prefix is None or seq.snapshot is not None \
                or not self.plan.has_paged:
            return None
        return self.prefix.lookup(seq.ns, seq.req.prompt,
                                  want_state=bool(self.plan.slot_families),
                                  tenant=tenant_of(seq.req),
                                  uid=seq.req.uid)

    def admit(self) -> List[Sequence]:
        """Move waiting sequences into the running set while BOTH domains
        can supply them (pages for the prompt, one constant-state slot).
        Returns ALL newly admitted sequences; the engine must swap pages
        back in for those carrying a preemption snapshot and zero the
        (possibly previously used) slots of fresh admits — srf/ssd states
        are accumulators, so a stale slot is live garbage, not masked-out
        history like a stale KV row.

        With a prefix cache attached, a fresh admission is charged only
        its UNSHARED pages: the matched prefix's full pages join the
        request's table as read-only shared references, prefill resumes
        at the match boundary, and an unaligned boundary page is
        scheduled for a COW fork into the request's first fresh page
        (the engine applies the device copy; see serving/prefix). A
        failed admission releases the match's pins — next round re-looks
        it up against a possibly changed cache."""
        tok = self.spans.begin("admit")
        admitted = []
        for seq in sorted(self.waiting, key=self._rank):
            if len(self.running) >= self.cfg.max_batch:
                break
            match = None
            if seq.snapshot is not None:
                n = len(seq.snapshot_pages)
            else:
                match = self._lookup_prefix(seq)
                n = self._pages_for(max(seq.prompt_len, 1)) \
                    - (len(match.pages) if match is not None else 0)
            pages = self._alloc_pages(n)
            if pages is None:
                if match is not None:
                    self.prefix.release(match)
                break                    # head-of-line blocks (no starvation)
            if self.slot_alloc is not None:
                slot = self.slot_alloc.alloc(1)
                if slot is None:
                    self.alloc.free(pages)
                    if match is not None:
                        self.prefix.release(match)
                    break                # slot domain exhausted: same rule
                seq.slot = slot[0]
            if match is not None and match.tokens > 0:
                # shared prefix pages lead the table; ownership of the
                # pins transfers to the table (released uniformly later)
                seq.table.pages = list(match.pages) + pages
                seq.shared_pages = list(match.pages)
                seq.prefill_pos = match.tokens
                seq.table.length = match.tokens
                seq.hit_tokens = match.tokens
                seq.state_payload = match.payload
                if match.fork_src is not None:
                    seq.fork = cow.Fork(match.fork_src, pages[0],
                                        pinned_src=True)
            else:
                seq.table.pages = pages
            self.waiting.remove(seq)
            self.running.append(seq)
            self._c_admitted.inc()
            admitted.append(seq)
        if admitted:
            self._sync_gauges()
        tok.args["admitted"] = len(admitted)
        tok.args["waiting"] = len(self.waiting)
        self.spans.end(tok)
        return admitted

    # -- prefill ------------------------------------------------------------

    def prefill_work(self) -> List[Sequence]:
        todo = [s for s in self.running if not s.prefill_done]
        return sorted(todo, key=self._rank)[: self.cfg.prefill_batch]

    # -- decode -------------------------------------------------------------

    def decode_ready(self) -> List[Sequence]:
        rdy = [s for s in self.running if s.prefill_done]
        return sorted(rdy, key=self._rank)[: self.cfg.max_batch]

    def grow_for_decode(self, seq: Sequence) -> Tuple[bool, Optional[Sequence]]:
        """Ensure ``seq`` has a page for its next token. Returns
        (ok, victim): when the pool is exhausted the chosen victim must be
        evicted by the engine (its pages snapshotted + freed) before the
        decode step; ``ok`` is False if seq itself must stall this step.
        Constant-state-only plans never grow (the slot is the state)."""
        if not self.plan.has_paged:
            return True, None
        need = seq.table.pages_needed(seq.table.length + 1,
                                      self.cfg.page_size)
        if need <= 0:
            # the next token lands in an existing page — but if that page
            # is SHARED (prefix-cache / sibling request), writing it would
            # corrupt every other reader: COW-fork it first. The table
            # swaps to the fresh page immediately and this request's
            # reference on the source is dropped — safe because the
            # device copy (engine-applied, batched gather-then-scatter
            # reading pre-copy pools) happens before any write lands.
            pos = seq.table.length
            idx = cow.decode_fork_index(self.alloc, seq.table.pages, pos,
                                        self.cfg.page_size)
            if idx is None:
                return True, None
            pages = self._alloc_pages(1)
            if pages is not None:
                src = seq.table.pages[idx]
                seq.fork = cow.Fork(src, pages[0], pinned_src=False)
                seq.table.pages[idx] = pages[0]
                self.alloc.free([src])
                self._g_free_pages.set(self.alloc.free_pages)
                self._g_used_pages.set(self.alloc.used_pages)
                return True, None
        else:
            if len(seq.table.pages) + need > self.cfg.table_width:
                return False, None       # at capacity: request finishes soon
            pages = self._alloc_pages(need)
            if pages is not None:
                seq.table.pages.extend(pages)
                self._g_free_pages.set(self.alloc.free_pages)
                self._g_used_pages.set(self.alloc.used_pages)
                return True, None
        for victim in self._victim_order():
            if victim is not seq:
                return False, victim
        return False, None

    # -- eviction / completion ---------------------------------------------

    def _release(self, seq: Sequence) -> None:
        self.alloc.free(seq.table.pages)
        seq.table.pages = []
        seq.shared_pages = []
        if seq.slot is not None:
            self.slot_alloc.free([seq.slot])
            seq.slot = None

    def evicted(self, seq: Sequence, snapshot) -> None:
        """Engine snapshotted ``seq``'s pages+slot; return them, requeue."""
        seq.snapshot = snapshot
        seq.snapshot_pages = list(seq.table.pages)
        self._release(seq)
        self.running.remove(seq)
        self.waiting.append(seq)
        self._c_preempted.inc()
        self._sync_gauges()

    def restored(self, seq: Sequence) -> None:
        seq.snapshot = None
        seq.snapshot_pages = []

    def finished(self, seq: Sequence) -> None:
        self._release(seq)
        self.running.remove(seq)
        self._c_finished.inc()
        self._sync_gauges()

    # -- cross-replica migration (serving.mesh.router) ----------------------

    def expire_overdue(self, now: float) -> List[Sequence]:
        """Drop WAITING sequences past their deadline and hand them to
        the engine for terminal ``timeout`` bookkeeping. Waiting
        sequences hold no device capacity, so expiry frees nothing —
        but it does stop a backlogged pool from spending pages on work
        that is already late. Running sequences are never expired (their
        pages are bought; finishing them is strictly cheaper than
        re-serving). Expired counts land in ``finished_total`` too, so
        the conservation identity (submitted + adopted == finished +
        released + running + waiting) is untouched;
        ``sched_expired_total`` tells the timeout story apart."""
        out = [s for s in self.waiting
               if getattr(s.req, "deadline_at", None) is not None
               and now > s.req.deadline_at]
        for seq in out:
            self.waiting.remove(seq)
            seq.snapshot = None
            seq.snapshot_pages = []
            self._c_finished.inc()
            self._c_expired.inc()
            self.spans.instant("expired", uid=seq.req.uid,
                               tenant=tenant_of(seq.req))
        if out:
            self._g_waiting.set(len(self.waiting))
        return out

    def release_running(self, seq: Sequence) -> None:
        """Drop a RUNNING sequence whose device state is gone (its
        replica died): both domains are freed locally and the request is
        handed back to the router for replay elsewhere. Counted as
        released — the conservation identity absorbs the hand-off
        exactly like ``release_waiting``."""
        self._release(seq)
        self.running.remove(seq)
        self._c_released.inc()
        self._sync_gauges()

    def release_waiting(self, seq: Sequence) -> None:
        """Detach a waiting sequence so another replica can adopt it.
        Waiting sequences hold no pages or slots (fresh or evicted-with-
        snapshot), so nothing device-side needs to move with them."""
        self.waiting.remove(seq)
        self._c_released.inc()
        self._g_waiting.set(len(self.waiting))

    def adopt(self, seq: Sequence) -> None:
        """Take over a sequence released by another replica's scheduler.
        Prefill progress and any preemption snapshot travel with it (the
        snapshot is host memory and pool shapes match across replicas);
        arrival is restamped so local FCFS ordering stays coherent."""
        seq.arrival = self._arrivals
        self._arrivals += 1
        self.waiting.append(seq)
        self._c_adopted.inc()
        self._g_waiting.set(len(self.waiting))

    def defrag(self):
        """Compact live pages to the low end of the paged pool. Returns
        the {old: new} move map; the engine must apply it to the device
        pools AND the scheduler rewrites the block tables here. Slots
        never fragment (one per request)."""
        moves = self.alloc.defrag_plan()
        if moves:
            for seq in self.running:
                seq.table.pages = [moves.get(p, p) for p in seq.table.pages]
            if self.prefix is not None:
                self.prefix.remap(moves)
            self._c_defrags.inc()
        return moves

    @property
    def free_slots(self) -> int:
        return self.slot_alloc.free_pages if self.slot_alloc else 0

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
