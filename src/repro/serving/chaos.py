"""Deterministic fault-injection harness for the serving stack.

TEST-ONLY. This module exists so every recovery path in
``serving/ft.py`` + ``serving/mesh/router.py`` is exercisable without
real hardware faults: nothing in the production serving path imports
it, and nothing here must ever run in a deployment. ``ChaosEngine``
wraps a live :class:`~repro.serving.engine.Engine` and injects exactly
one scripted fault at a chosen step:

``raise``
    ``ChaosError`` escapes ``step()`` — the hard-crash path (device
    loss, XLA abort). The router's exception handler quarantines.
``hang``
    the engine's injected step-time clock (``Engine.clock``) starts
    reporting a large stall, so the recorded
    ``engine_step_seconds`` inflate while real steps keep running —
    exercising the watchdog's EMA-vs-peer-median slow detector exactly
    as a real stall would, without actually sleeping in tests.
``reject``
    admission is corrupted (``sched.admit`` returns nothing), so queued
    work can never start — the stuck detector's territory.
``oom``
    the page/slot pools are exhausted by hostage allocations, topped up
    every step so eviction can't win the pages back — sustained
    allocator exhaustion, also caught by the stuck detector.

Faults are deterministic: ``ChaosPlan`` pins the kind and trip step,
and :meth:`ChaosPlan.from_seed` derives both from a seed for fuzzing.
``heal()`` undoes the fault (returns hostage pages, restores admission,
stops the stall) so ``Router.revive`` probes can succeed — the
simulated equivalent of swapping the broken host.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

FAULT_KINDS = ("raise", "hang", "reject", "oom")


class ChaosError(RuntimeError):
    """An injected failure. Never raised by real serving code."""


@dataclass
class ChaosPlan:
    """One scripted fault: ``kind`` trips once ``at_step`` chaos-engine
    steps have been attempted (and stays tripped until ``heal()``)."""
    kind: str
    at_step: int = 5
    stall_s: float = 30.0   # reported per-step stall for kind="hang"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")

    @classmethod
    def from_seed(cls, seed: int, at_step=(3, 9)) -> "ChaosPlan":
        rng = random.Random(seed)
        return cls(kind=FAULT_KINDS[rng.randrange(len(FAULT_KINDS))],
                   at_step=rng.randrange(at_step[0], at_step[1]))


class _StallClock:
    """Drop-in for ``time.perf_counter`` that adds ``stall`` seconds per
    engine step. The engine reads its clock exactly twice per step
    (start/stop), so advancing the offset on every second call inflates
    each recorded ``engine_step_seconds`` observation by ``stall``
    without blocking the test process."""

    def __init__(self, base):
        self._base = base
        self._offset = 0.0
        self._calls = 0
        self.stall = 0.0

    def __call__(self) -> float:
        self._calls += 1
        if self._calls % 2 == 0:
            self._offset += self.stall
        return self._base() + self._offset


class ChaosEngine:
    """Engine wrapper that injects the fault described by ``fault``.

    Everything except ``step``/``run``/``heal`` delegates to the wrapped
    engine, so the router drives a ``ChaosEngine`` exactly like a real
    replica. The attribute is named ``fault`` (not ``plan``) so it never
    shadows ``Engine.plan`` — the PoolPlan the router's placement logic
    reads through delegation.
    """

    def __init__(self, engine, fault: ChaosPlan):
        self._eng = engine
        self.fault = fault
        self.steps_seen = 0
        self.tripped = False
        self.healed = False
        self._hostage_pages: list = []
        self._hostage_slots: list = []
        self._orig_admit = engine.sched.admit
        self._stall_clock = None
        if fault.kind == "hang":
            self._stall_clock = _StallClock(engine.clock)
            engine.clock = self._stall_clock

    def __getattr__(self, name):
        return getattr(self._eng, name)

    # -- fault machinery -------------------------------------------------

    def _trip(self) -> None:
        k = self.fault.kind
        self.tripped = True
        if k == "raise":
            raise ChaosError(
                f"injected engine failure at chaos step {self.steps_seen}")
        if k == "hang":
            self._stall_clock.stall = self.fault.stall_s
        elif k == "reject":
            self._eng.sched.admit = lambda: []
        elif k == "oom":
            # topped up on every step: eviction frees pages, so a single
            # grab would let the replica limp along and never look stuck
            self._grab_pool()

    def _grab_pool(self) -> None:
        sched = self._eng.sched
        got = sched.alloc.alloc(sched.alloc.free_pages)
        if got:
            self._hostage_pages.extend(got)
        if sched.slot_alloc is not None:
            got = sched.slot_alloc.alloc(sched.slot_alloc.free_pages)
            if got:
                self._hostage_slots.extend(got)
        sched._sync_gauges()

    def heal(self) -> None:
        """Undo the fault (the simulated host swap), so a subsequent
        ``Router.revive`` probe can succeed."""
        self.healed = True
        if self._stall_clock is not None:
            self._stall_clock.stall = 0.0
        self._eng.sched.admit = self._orig_admit
        if self._hostage_pages:
            self._eng.sched.alloc.free(self._hostage_pages)
            self._hostage_pages = []
        if self._hostage_slots:
            self._eng.sched.slot_alloc.free(self._hostage_slots)
            self._hostage_slots = []
        self._eng.sched._sync_gauges()

    # -- engine surface --------------------------------------------------

    def step(self) -> bool:
        self.steps_seen += 1
        if not self.healed and self.steps_seen >= self.fault.at_step:
            self._trip()
        return self._eng.step()

    def run(self, on_step=None):
        """Mirror ``Engine.run`` through the injecting ``step`` (the real
        ``run`` calls the wrapped engine's own step, bypassing us)."""
        tracked = [s.req for s in self._eng.sched.waiting
                   + self._eng.sched.running]
        stall = 0
        while self._eng.sched.has_work:
            progressed = self.step()
            if on_step is not None:
                on_step(self)
            stall = 0 if progressed else stall + 1
            if stall > 2:
                raise RuntimeError(
                    "scheduler stalled: pool too small for the remaining "
                    "requests (or a chaos fault is active)")
        return [r for r in tracked if r.done]
