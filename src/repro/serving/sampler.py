"""Token sampling for the serving engine: temperature / top-k / top-p.

One jit'd, fully batched sampler: every request carries its own
(temperature, top_k, top_p) vector entry, so mixed sampling configs run
in a single call with no per-request branching. ``temperature <= 0``
selects greedy argmax for that row (the engine's default, which keeps
decoding deterministic for tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def sample(rng: jax.Array, logits: jax.Array, temperature: jax.Array,
           top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """logits: (B, V); temperature/top_p: (B,) f32; top_k: (B,) int32
    (0 = disabled) -> (B,) int32 sampled token ids.

    Implementation: sort once descending, build the combined top-k
    (rank < k) and top-p (cumulative prob below p, first always kept)
    masks in sorted order, then Gumbel-max over the surviving logits —
    equivalent to renormalized categorical sampling, no second pass.
    """
    b, v = logits.shape
    lf = logits.astype(jnp.float32)
    greedy = temperature <= 0.0
    temp = jnp.where(greedy, 1.0, jnp.maximum(temperature, 1e-6))
    scaled = lf / temp[:, None]

    order = jnp.argsort(-scaled, axis=-1)                  # (B, V) desc
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)
    ranks = jnp.arange(v)[None, :]
    k_eff = jnp.where(top_k <= 0, v, top_k)[:, None]
    keep = ranks < k_eff                                   # top-k in sorted order

    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens whose cumulative mass BEFORE them is < top_p; the
    # argmax token (rank 0) always survives
    keep &= (cum - probs) < top_p[:, None]
    keep |= ranks == 0

    masked = jnp.where(keep, sorted_logits, -jnp.inf)
    g = jax.random.gumbel(rng, (b, v), jnp.float32)
    pick_sorted = jnp.argmax(masked + g, axis=-1)          # (B,)
    sampled = jnp.take_along_axis(order, pick_sorted[:, None], axis=-1)[:, 0]
    argmax = jnp.argmax(lf, axis=-1)
    return jnp.where(greedy, argmax, sampled).astype(jnp.int32)
