"""Token sampling for the serving engine: temperature / top-k / top-p.

One jit'd, fully batched sampler: every request carries its own
(temperature, top_k, top_p) vector entry, so mixed sampling configs run
in a single call with no per-request branching. ``temperature <= 0``
selects greedy argmax for that row (the engine's default, which keeps
decoding deterministic for tests).

Engines draw through :func:`sample_stateless`: the noise for row ``i``
is a pure function of ``(base_key, uid[i], position[i])`` — NOT of any
engine-side RNG state, batch composition, admission order, or replica.
That is the sampling-key contract fault-tolerant replay relies on: a
rescued request replays the exact keys its killed replica would have
used, so temperature-sampled streams are bit-identical across rescue
(``serving/README.md`` §sampling determinism).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def sample(rng: jax.Array, logits: jax.Array, temperature: jax.Array,
           top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """logits: (B, V); temperature/top_p: (B,) f32; top_k: (B,) int32
    (0 = disabled) -> (B,) int32 sampled token ids.

    Implementation: sort once descending, build the combined top-k
    (rank < k) and top-p (cumulative prob below p, first always kept)
    masks in sorted order, then Gumbel-max over the surviving logits —
    equivalent to renormalized categorical sampling, no second pass.
    """
    b, v = logits.shape
    lf = logits.astype(jnp.float32)
    greedy = temperature <= 0.0
    temp = jnp.where(greedy, 1.0, jnp.maximum(temperature, 1e-6))
    scaled = lf / temp[:, None]

    order = jnp.argsort(-scaled, axis=-1)                  # (B, V) desc
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)
    ranks = jnp.arange(v)[None, :]
    k_eff = jnp.where(top_k <= 0, v, top_k)[:, None]
    keep = ranks < k_eff                                   # top-k in sorted order

    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens whose cumulative mass BEFORE them is < top_p; the
    # argmax token (rank 0) always survives
    keep &= (cum - probs) < top_p[:, None]
    keep |= ranks == 0

    masked = jnp.where(keep, sorted_logits, -jnp.inf)
    g = jax.random.gumbel(rng, (b, v), jnp.float32)
    pick_sorted = jnp.argmax(masked + g, axis=-1)          # (B,)
    sampled = jnp.take_along_axis(order, pick_sorted[:, None], axis=-1)[:, 0]
    argmax = jnp.argmax(lf, axis=-1)
    return jnp.where(greedy, argmax, sampled).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def sample_stateless(base_key: jax.Array, uids: jax.Array,
                     positions: jax.Array, logits: jax.Array,
                     temperature: jax.Array, top_k: jax.Array,
                     top_p: jax.Array) -> jax.Array:
    """Per-request stateless sampling: same masking math as
    :func:`sample`, but row ``i``'s Gumbel noise comes from the derived
    key ``fold_in(fold_in(base_key, uids[i]), positions[i])`` instead of
    one batch-wide key. uids/positions: (B,) int32 (padded rows may carry
    anything — their key is drawn but their token is discarded).

    Because each row's draw depends only on its own (uid, position), the
    sampled stream of a request is invariant to batch composition and
    batch slot — a batch-1 replay (e.g. the legacy engine, or a rescue
    replica re-running a lone request) reproduces it bit for bit.
    """
    b, v = logits.shape
    lf = logits.astype(jnp.float32)
    greedy = temperature <= 0.0
    temp = jnp.where(greedy, 1.0, jnp.maximum(temperature, 1e-6))
    scaled = lf / temp[:, None]

    order = jnp.argsort(-scaled, axis=-1)                  # (B, V) desc
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)
    ranks = jnp.arange(v)[None, :]
    k_eff = jnp.where(top_k <= 0, v, top_k)[:, None]
    keep = ranks < k_eff

    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep &= (cum - probs) < top_p[:, None]
    keep |= ranks == 0

    masked = jnp.where(keep, sorted_logits, -jnp.inf)

    def row_gumbel(uid, position):
        k = jax.random.fold_in(jax.random.fold_in(base_key, uid), position)
        return jax.random.gumbel(k, (v,), jnp.float32)

    g = jax.vmap(row_gumbel)(uids, positions)              # (B, V)
    pick_sorted = jnp.argmax(masked + g, axis=-1)          # (B,)
    sampled = jnp.take_along_axis(order, pick_sorted[:, None], axis=-1)[:, 0]
    argmax = jnp.argmax(lf, axis=-1)
    return jnp.where(greedy, argmax, sampled).astype(jnp.int32)
