"""Pooled, pre-allocated decode caches behind one ``CacheFamily`` protocol.

Four per-layer families share the allocator and the batched decode step:

=========  ==============================================  ===============
family     page contents (per layer)                       state growth
=========  ==============================================  ===============
``kv``     k/v pages   (num_pages, P, Hkv, hd) x2          O(L) paged
``mla``    latent c    (num_pages, P, kv_lora)
           + rope kpe  (num_pages, P, qk_rope)             O(L) paged
``srf``    feature S   (num_slots, Hq, m, dv)
           + norm z    (num_slots, Hq, m)                  O(m d) constant
``ssd``    conv tail   (num_slots, conv-1, conv_dim)
           + SSM state (num_slots, nh, ns, hd)             O(1) constant
=========  ==============================================  ===============

``kv``/``mla`` grow one page per ``page_size`` tokens and live in the
*paged* index domain (page ids from the scheduler's main allocator);
``srf``/``ssd`` are the paper's constant-size decode states, one fixed
"slot" per request in the *slot* index domain (slot ids from a separate,
much smaller allocator). A model mixes domains freely: a hybrid layer
owns a kv sub-pool AND an ssd sub-pool (``transformer._layer_plan``
names the components per layer kind), and an enc-dec model adds a
model-level read-only *encoder-memory* pool — one slot per request,
written once at admission (the encoder runs exactly once per request)
and cross-attended by every decoder layer via the paged-gather kernel.

The full pool container is one pytree::

    {"paged": [per-segment {component: {leaf: (L, num_pages, ...)}} | None],
     "slot":  [per-segment {component: {leaf: (L, num_slots, ...)}} | None],
     "memory": (num_slots, enc_len, d_model)}      # enc-dec only

Segments mirror ``transformer.segments``; all layers of a segment share
shapes, so per-layer pools are stacked on a leading layer axis and
scanned together with the stacked layer params (``transformer.paged_step``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as model_lib


@dataclass(frozen=True)
class PagedConfig:
    """Pool-layout knobs orthogonal to the scheduler's SchedConfig.

    ``quantize_kv``: store KV pages as int8 with f32 per-page-row (one per
    cached token) scales — halves (bf16) or quarters (f32) the dominant
    pool bytes; dequant is fused into the paged-gather kernel. Only the
    ``kv`` family quantizes (MLA latents are already compressed, srf/ssd
    states are constant-size).
    """
    quantize_kv: bool = False


# ---------------------------------------------------------------------------
# family protocol
# ---------------------------------------------------------------------------

class CacheFamily(Protocol):
    """A cache family owns the pool layout for one serving state kind."""
    name: str
    constant_state: bool     # True: one fixed-size page per request

    def layer_pool(self, cfg, num_pages: int, page_size: int,
                   paged: Optional[PagedConfig] = None) -> Dict:
        """Single-layer pool pytree (leading axis = num_pages/slots)."""

    def bytes_per_token(self, cfg, max_len: int,
                        paged: Optional[PagedConfig] = None) -> float:
        """Decode-state bytes per cached token per layer (docs/stats)."""


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


class KVFamily:
    name = "kv"
    constant_state = False

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        shp = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        if paged is not None and paged.quantize_kv:
            sshp = (num_pages, page_size, 1)
            return {"k": jnp.zeros(shp, jnp.int8),
                    "v": jnp.zeros(shp, jnp.int8),
                    "k_scale": jnp.zeros(sshp, jnp.float32),
                    "v_scale": jnp.zeros(sshp, jnp.float32)}
        return {"k": jnp.zeros(shp, _dt(cfg)), "v": jnp.zeros(shp, _dt(cfg))}

    def bytes_per_token(self, cfg, max_len, paged=None):
        if paged is not None and paged.quantize_kv:
            return 2 * (cfg.n_kv_heads * cfg.head_dim + 4)   # int8 + f32 scale
        return 2 * cfg.n_kv_heads * cfg.head_dim * _dt(cfg).itemsize


class MLAFamily:
    name = "mla"
    constant_state = False

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        return {"c": jnp.zeros((num_pages, page_size, cfg.mla_kv_lora), _dt(cfg)),
                "kpe": jnp.zeros((num_pages, page_size, cfg.mla_qk_rope), _dt(cfg))}

    def bytes_per_token(self, cfg, max_len, paged=None):
        return (cfg.mla_kv_lora + cfg.mla_qk_rope) * _dt(cfg).itemsize


class SRFFamily:
    name = "srf"
    constant_state = True

    def _feat_dim(self, cfg):
        from repro.models.attention import srf_cfg
        return srf_cfg(cfg).feat_dim

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        m = self._feat_dim(cfg)
        dv = cfg.mla_v_dim if cfg.is_mla else cfg.head_dim
        return {"s": jnp.zeros((num_pages, cfg.n_heads, m, dv), _dt(cfg)),
                "z": jnp.zeros((num_pages, cfg.n_heads, m), _dt(cfg))}

    def bytes_per_token(self, cfg, max_len, paged=None):
        m = self._feat_dim(cfg)
        dv = cfg.mla_v_dim if cfg.is_mla else cfg.head_dim
        total = cfg.n_heads * m * (dv + 1) * _dt(cfg).itemsize
        return total / max_len      # amortized: the state never grows


class SSDFamily:
    name = "ssd"
    constant_state = True

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        cd = cfg.d_inner + 2 * cfg.ssm_state
        return {"conv": jnp.zeros((num_pages, cfg.ssm_conv - 1, cd), _dt(cfg)),
                "ssm": jnp.zeros((num_pages, cfg.ssm_heads, cfg.ssm_state,
                                  cfg.ssm_head_dim), jnp.float32)}

    def bytes_per_token(self, cfg, max_len, paged=None):
        cd = cfg.d_inner + 2 * cfg.ssm_state
        total = ((cfg.ssm_conv - 1) * cd * _dt(cfg).itemsize
                 + cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4)
        return total / max_len


FAMILIES = {f.name: f for f in (KVFamily(), MLAFamily(), SRFFamily(),
                                SSDFamily())}


def attn_family_for(cfg) -> CacheFamily:
    """The cache family of the (self-)attention component."""
    if cfg.attn_impl == "srf":
        return FAMILIES["srf"]
    if cfg.is_mla:
        return FAMILIES["mla"]
    return FAMILIES["kv"]


# ---------------------------------------------------------------------------
# pool plan: which families a config's layers need, per index domain
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PoolPlan:
    """Resolved pool geometry of one config.

    ``segments`` mirrors ``transformer._layer_plan``: per decoder segment
    ``(layer_kind, layer_count, ((component, family_name), ...))``.
    ``paged_family`` is the O(L) component ("kv"/"mla") if any layer has
    one; ``slot_families`` are the constant-state components ("srf"/"ssd");
    ``has_memory`` marks the enc-dec encoder-memory pool. Every request
    holds ``ceil(len/page_size)`` pages in the paged domain (when
    ``has_paged``) plus exactly one slot in the slot domain (when
    ``needs_slot``).
    """
    name: str
    segments: Tuple[Tuple[str, int, Tuple[Tuple[str, str], ...]], ...]
    paged_family: Optional[str]
    attn_family: Optional[str]
    slot_families: Tuple[str, ...]
    has_memory: bool

    @property
    def has_paged(self) -> bool:
        return self.paged_family is not None

    @property
    def needs_slot(self) -> bool:
        return bool(self.slot_families) or self.has_memory

    @property
    def constant_state(self) -> bool:
        """Per-request state does not grow with generated length."""
        return not self.has_paged

    def bytes_per_token(self, cfg, max_len: int,
                        paged: Optional[PagedConfig] = None) -> float:
        """Per-layer decode-state bytes per token, summed over the state
        components one (deepest) layer owns; the enc-dec memory slot is
        amortized over ``max_len`` like the other constant states."""
        fams = set()
        for _, _, comps in self.segments:
            fams |= {f for _, f in comps}
        total = sum(FAMILIES[f].bytes_per_token(cfg, max_len, paged)
                    for f in sorted(fams))
        if self.has_memory:
            total += cfg.enc_len * cfg.d_model * _dt(cfg).itemsize / max_len
        return total


def plan_for(cfg) -> PoolPlan:
    """Resolve the pool plan for a config — every registry family serves."""
    segs = []
    paged_fam = None
    attn_fam = None
    slot_fams: List[str] = []
    for kind, count, comps in model_lib._layer_plan(cfg):
        resolved = []
        for comp in comps:
            fam = attn_family_for(cfg) if comp == "attn" else FAMILIES["ssd"]
            resolved.append((comp, fam.name))
            if comp == "attn":
                attn_fam = fam.name
            if fam.constant_state:
                if fam.name not in slot_fams:
                    slot_fams.append(fam.name)
            else:
                paged_fam = fam.name
        segs.append((kind, count, tuple(resolved)))
    parts = []
    if paged_fam:
        parts.append(paged_fam)
    parts += [f for f in slot_fams if f not in parts]
    if cfg.is_encdec:
        parts.append("mem")
    return PoolPlan(name="+".join(parts), segments=tuple(segs),
                    paged_family=paged_fam, attn_family=attn_fam,
                    slot_families=tuple(slot_fams),
                    has_memory=cfg.is_encdec)


def family_for(cfg) -> CacheFamily:
    """The config's PRIMARY cache family (compat shim over ``plan_for``):
    the attention component's family for attention-bearing stacks, ssd
    for pure SSM. No config is rejected — hybrid / enc-dec / frontend
    families all serve through the paged engine (their full geometry is
    the :class:`PoolPlan`, which mixed-domain callers should use)."""
    plan = plan_for(cfg)
    if plan.attn_family is not None:
        return FAMILIES[plan.attn_family]
    return FAMILIES[plan.slot_families[0]]


# ---------------------------------------------------------------------------
# pool container
# ---------------------------------------------------------------------------

def init_pools(cfg, num_pages: int, page_size: int, num_slots: int = 0,
               mesh=None, paged: Optional[PagedConfig] = None) -> Dict:
    """Build the full pool pytree (see module docstring for the layout).

    ``num_pages`` sizes the paged domain, ``num_slots`` the slot domain
    (constant states + enc-dec memory; slot 0 is the null slot padded
    batch rows write into). All layers of a segment share shapes, so the
    per-layer pools are stacked and scanned with the stacked layer params.

    ``mesh``: lay the pools out with model-axis ``NamedSharding`` on the
    head/feature dim (``serving.mesh.shard.pool_specs``), degrading to
    replication whenever the dim does not divide — the same contract as
    ``distributed/sharding.py``. The page *tables* stay host-local either
    way (they are scheduler bookkeeping, not device state)."""
    plan = plan_for(cfg)
    if plan.needs_slot:
        num_slots = max(num_slots, 2)
    pools: Dict = {"paged": [], "slot": []}
    for kind, count, comps in plan.segments:
        pseg: Dict = {}
        sseg: Dict = {}
        for comp, fam_name in comps:
            fam = FAMILIES[fam_name]
            n = num_slots if fam.constant_state else num_pages
            one = fam.layer_pool(cfg, n, page_size, paged)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one)
            (sseg if fam.constant_state else pseg)[comp] = stacked
        pools["paged"].append(pseg or None)
        pools["slot"].append(sseg or None)
    if plan.has_memory:
        pools["memory"] = jnp.zeros((num_slots, cfg.enc_len, cfg.d_model),
                                    _dt(cfg))
    if mesh is not None:
        from .mesh import shard as mesh_shard
        pools = mesh_shard.place_pools(pools, cfg, mesh, paged)
    return pools


def _map_segs(segs, fn):
    return [None if s is None else jax.tree.map(fn, s) for s in segs]


def _slice_pools(pools: Dict, page_idx, slot_idx) -> Dict:
    out = {"paged": _map_segs(pools["paged"], lambda a: a[:, page_idx]),
           "slot": _map_segs(pools["slot"], lambda a: a[:, slot_idx])}
    if "memory" in pools:
        out["memory"] = pools["memory"][slot_idx]
    return out


class PendingSnapshot:
    """Copy-on-preempt snapshot whose device->host transfer overlaps the
    next decode step.

    Eviction enqueues the page-row slice (a device computation producing
    fresh buffers, so later in-place pool updates and donation cannot
    clobber it) and immediately kicks off the non-blocking host transfer
    (``copy_to_host_async``). The decode loop continues; ``to_host``
    fences with ``jax.block_until_ready`` only when the snapshot is
    actually needed (swap-in), by which time the bytes have usually
    already streamed over."""

    def __init__(self, slices):
        self._dev = slices
        self._host = None
        for leaf in jax.tree.leaves(slices):
            try:
                leaf.copy_to_host_async()
            except AttributeError:      # non-jax leaf (already host)
                pass

    def fence(self) -> None:
        """Block until the device-side slice has executed (the source pool
        buffers are then dead to this snapshot — safe to donate)."""
        if self._dev is not None:
            jax.block_until_ready(self._dev)

    def to_host(self):
        if self._host is None:
            self._host = jax.tree.map(np.asarray, self._dev)
            self._dev = None
        return self._host


def snapshot_page_rows_async(pools: Dict, page_ids: List[int],
                             slot_ids: List[int]) -> PendingSnapshot:
    """Async copy-on-preempt over BOTH index domains (and the memory row
    for enc-dec): returns a :class:`PendingSnapshot` whose host transfer
    overlaps subsequent decode steps."""
    return PendingSnapshot(_slice_pools(pools,
                                        jnp.asarray(page_ids, jnp.int32),
                                        jnp.asarray(slot_ids, jnp.int32)))


def pool_page_rows(pools: Dict, page_ids: List[int],
                   slot_ids: List[int]) -> Dict:
    """Synchronous snapshot (numpy); the engine's hot path uses
    :func:`snapshot_page_rows_async` instead."""
    snap = _slice_pools(pools, np.asarray(page_ids, np.int32),
                        np.asarray(slot_ids, np.int32))
    return jax.tree.map(np.asarray, snap)


def zero_slot_rows(pools: Dict, slot_ids: List[int],
                   zero_memory: bool = True) -> Dict:
    """Reset the given slots of every constant-state pool (and the memory
    pool) to zero. Needed when a freed slot is re-issued to a fresh
    request: srf/ssd states are running accumulators, so stale content is
    live garbage, not masked-out history like an unwritten KV row.
    ``zero_memory=False`` skips the enc-dec memory pool — the engine
    passes it when the encoder is about to overwrite those rows anyway."""
    idx = jnp.asarray(slot_ids, jnp.int32)
    out = {"paged": pools["paged"],
           "slot": _map_segs(pools["slot"],
                             lambda a: a.at[:, idx].set(
                                 jnp.zeros((), a.dtype)))}
    if "memory" in pools:
        out["memory"] = (pools["memory"].at[idx].set(
            jnp.zeros((), pools["memory"].dtype)) if zero_memory
            else pools["memory"])
    return out


def restore_page_rows(pools: Dict, page_ids: List[int], slot_ids: List[int],
                      snap) -> Dict:
    """Inverse of the snapshot: scatter saved rows back into (freshly
    allocated) pages/slots. Accepts either the synchronous host form or a
    :class:`PendingSnapshot`. Returns the updated pools."""
    if isinstance(snap, PendingSnapshot):
        snap = snap.to_host()
    pidx = jnp.asarray(page_ids, jnp.int32)
    sidx = jnp.asarray(slot_ids, jnp.int32)

    def scat(idx):
        return lambda a, s: a.at[:, idx].set(jnp.asarray(s, dtype=a.dtype))

    out = {"paged": [None if p is None else jax.tree.map(scat(pidx), p, sn)
                     for p, sn in zip(pools["paged"], snap["paged"])],
           "slot": [None if p is None else jax.tree.map(scat(sidx), p, sn)
                    for p, sn in zip(pools["slot"], snap["slot"])]}
    if "memory" in pools:
        out["memory"] = pools["memory"].at[sidx].set(
            jnp.asarray(snap["memory"], dtype=pools["memory"].dtype))
    return out


def copy_page_rows(pools: Dict, src_ids: List[int],
                   dst_ids: List[int]) -> Dict:
    """COW fork: copy page rows ``src -> dst`` across every paged-domain
    pool in ONE batched gather-then-scatter (``a.at[:, dst].set(a[:,
    src])`` reads all sources from the pre-copy pools before any write
    lands), so a fork destination that recycles a page freed in the same
    scheduler round can never be read after being clobbered. Slot pools
    and the enc-dec memory never fork (one constant-size slot per
    request, never shared).

    The id vectors are padded to power-of-two buckets (floor 16) with
    null-page self-copies (page 0 -> page 0, reserved scratch): eager
    jax compiles one kernel per SHAPE, so unpadded variable-length fork
    batches would trigger a fresh whole-pool scatter compile for every
    distinct batch size — mid-serve, landing in decode token gaps."""
    if not src_ids:
        return pools
    cap = 16
    while cap < len(src_ids):
        cap *= 2
    pad = [0] * (cap - len(src_ids))          # 0 = reserved null page
    src = jnp.asarray(list(src_ids) + pad, jnp.int32)
    dst = jnp.asarray(list(dst_ids) + pad, jnp.int32)
    out = dict(pools)
    out["paged"] = _map_segs(pools["paged"],
                             lambda a: a.at[:, dst].set(a[:, src]))
    return out


def page_bytes(pools: Dict) -> int:
    """Device bytes ONE paged-domain page occupies across all layers and
    segments — the unit of the prefix cache's byte budget. Leaves are
    shaped (L, num_pages, ...), so per-page bytes is nbytes / num_pages."""
    total = 0
    for seg in pools["paged"]:
        if seg is None:
            continue
        for leaf in jax.tree.leaves(seg):
            total += (int(np.prod(leaf.shape)) // leaf.shape[1]
                      * leaf.dtype.itemsize)
    return total


def apply_moves(pools: Dict, moves: Dict[int, int]) -> Dict:
    """Apply a defrag plan {old: new} to every paged-domain pool (slots
    never fragment: one per request)."""
    if not moves:
        return pools
    src = jnp.asarray(list(moves.keys()), jnp.int32)
    dst = jnp.asarray(list(moves.values()), jnp.int32)
    out = dict(pools)
    out["paged"] = _map_segs(pools["paged"],
                             lambda a: a.at[:, dst].set(a[:, src]))
    return out


def pool_bytes(pools) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(pools))


def pool_bytes_per_device(pools) -> int:
    """Bytes one device holds: the per-shard slice for sharded leaves,
    the full leaf for replicated ones (GLOBAL shape / axis product only
    shrinks dims the NamedSharding actually splits)."""
    total = 0
    for x in jax.tree.leaves(pools):
        shard_shape = x.shape
        sharding = getattr(x, "sharding", None)
        if sharding is not None:
            try:
                shard_shape = sharding.shard_shape(x.shape)
            except Exception:
                pass
        total += int(np.prod(shard_shape)) * x.dtype.itemsize
    return total
