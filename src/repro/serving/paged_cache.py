"""Pooled, pre-allocated decode caches behind one ``CacheFamily`` protocol.

Four families share the allocator and the batched decode step:

=========  ==============================================  ===============
family     page contents (per layer)                       state growth
=========  ==============================================  ===============
``kv``     k/v pages   (num_pages, P, Hkv, hd) x2          O(L) paged
``mla``    latent c    (num_pages, P, kv_lora)
           + rope kpe  (num_pages, P, qk_rope)             O(L) paged
``srf``    feature S   (num_slots, Hq, m, dv)
           + norm z    (num_slots, Hq, m)                  O(m d) constant
``ssd``    conv tail   (num_slots, conv-1, conv_dim)
           + SSM state (num_slots, nh, ns, hd)             O(1) constant
=========  ==============================================  ===============

``kv``/``mla`` grow one page per ``page_size`` tokens; ``srf``/``ssd``
are the paper's constant-size decode states stored as a *single* page
("slot") per request — the multi-block structured construction keeps
that layout uniform across head counts, so the same block table indexes
all four. Pools carry a leading layer axis per model segment and are
scanned together with the stacked layer params (see
``transformer.paged_step``).
"""
from __future__ import annotations

from typing import Dict, List, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transforms import is_pow2
from repro.models import transformer as model_lib


# ---------------------------------------------------------------------------
# family protocol
# ---------------------------------------------------------------------------

class CacheFamily(Protocol):
    """A cache family owns the pool layout for one serving state kind."""
    name: str
    constant_state: bool     # True: one fixed-size page per request

    def layer_pool(self, cfg, num_pages: int, page_size: int) -> Dict:
        """Single-layer pool pytree (leading axis = num_pages/slots)."""

    def bytes_per_token(self, cfg, max_len: int) -> float:
        """Decode-state bytes per cached token per layer (docs/stats)."""


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


class KVFamily:
    name = "kv"
    constant_state = False

    def layer_pool(self, cfg, num_pages, page_size):
        shp = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shp, _dt(cfg)), "v": jnp.zeros(shp, _dt(cfg))}

    def bytes_per_token(self, cfg, max_len):
        return 2 * cfg.n_kv_heads * cfg.head_dim * _dt(cfg).itemsize


class MLAFamily:
    name = "mla"
    constant_state = False

    def layer_pool(self, cfg, num_pages, page_size):
        return {"c": jnp.zeros((num_pages, page_size, cfg.mla_kv_lora), _dt(cfg)),
                "kpe": jnp.zeros((num_pages, page_size, cfg.mla_qk_rope), _dt(cfg))}

    def bytes_per_token(self, cfg, max_len):
        return (cfg.mla_kv_lora + cfg.mla_qk_rope) * _dt(cfg).itemsize


class SRFFamily:
    name = "srf"
    constant_state = True

    def _feat_dim(self, cfg):
        from repro.models.attention import srf_cfg
        return srf_cfg(cfg).feat_dim

    def layer_pool(self, cfg, num_pages, page_size):
        m = self._feat_dim(cfg)
        dv = cfg.mla_v_dim if cfg.is_mla else cfg.head_dim
        return {"s": jnp.zeros((num_pages, cfg.n_heads, m, dv), _dt(cfg)),
                "z": jnp.zeros((num_pages, cfg.n_heads, m), _dt(cfg))}

    def bytes_per_token(self, cfg, max_len):
        m = self._feat_dim(cfg)
        dv = cfg.mla_v_dim if cfg.is_mla else cfg.head_dim
        total = cfg.n_heads * m * (dv + 1) * _dt(cfg).itemsize
        return total / max_len      # amortized: the state never grows


class SSDFamily:
    name = "ssd"
    constant_state = True

    def layer_pool(self, cfg, num_pages, page_size):
        cd = cfg.d_inner + 2 * cfg.ssm_state
        return {"conv": jnp.zeros((num_pages, cfg.ssm_conv - 1, cd), _dt(cfg)),
                "ssm": jnp.zeros((num_pages, cfg.ssm_heads, cfg.ssm_state,
                                  cfg.ssm_head_dim), jnp.float32)}

    def bytes_per_token(self, cfg, max_len):
        cd = cfg.d_inner + 2 * cfg.ssm_state
        total = ((cfg.ssm_conv - 1) * cd * _dt(cfg).itemsize
                 + cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4)
        return total / max_len


FAMILIES = {f.name: f for f in (KVFamily(), MLAFamily(), SRFFamily(),
                                SSDFamily())}


def family_for(cfg) -> CacheFamily:
    """Resolve the cache family a config serves with."""
    if cfg.is_encdec or cfg.family == "hybrid" or cfg.frontend != "none":
        raise ValueError(
            f"paged serving does not support family={cfg.family!r} / "
            f"frontend={cfg.frontend!r} yet (use serving.legacy.Engine)")
    if cfg.family == "ssm":
        return FAMILIES["ssd"]
    if cfg.attn_impl == "srf":
        return FAMILIES["srf"]
    if cfg.is_mla:
        return FAMILIES["mla"]
    return FAMILIES["kv"]


# ---------------------------------------------------------------------------
# pool container
# ---------------------------------------------------------------------------

def init_pools(cfg, num_pages: int, page_size: int) -> List[Dict]:
    """One pool pytree per model segment, leading axis = layer count.

    All layers of a segment share shapes, so the per-layer pools are
    stacked and scanned with the stacked layer params."""
    fam = family_for(cfg)
    pools = []
    for kind, count in model_lib.segments(cfg):
        one = fam.layer_pool(cfg, num_pages, page_size)
        pools.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one))
    return pools


def pool_page_rows(pools: List[Dict], page_ids: List[int]) -> List[Dict]:
    """Copy-on-preempt snapshot: pull the given pages of every layer pool
    to host memory (numpy) so they can be restored after eviction."""
    idx = np.asarray(page_ids, np.int32)
    return [jax.tree.map(lambda a: np.asarray(a[:, idx]), p) for p in pools]


def restore_page_rows(pools: List[Dict], page_ids: List[int],
                      snap: List[Dict]) -> List[Dict]:
    """Inverse of :func:`pool_page_rows`: scatter a snapshot back into
    (freshly allocated) pages. Returns the updated pools."""
    idx = jnp.asarray(page_ids, jnp.int32)
    return [jax.tree.map(lambda a, s: a.at[:, idx].set(jnp.asarray(s)), p, sn)
            for p, sn in zip(pools, snap)]


def apply_moves(pools: List[Dict], moves: Dict[int, int]) -> List[Dict]:
    """Apply a defrag plan {old: new} to every layer pool."""
    if not moves:
        return pools
    src = jnp.asarray(list(moves.keys()), jnp.int32)
    dst = jnp.asarray(list(moves.values()), jnp.int32)
    return [jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), p)
            for p in pools]


def pool_bytes(pools: List[Dict]) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(pools))
