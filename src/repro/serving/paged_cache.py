"""Pooled, pre-allocated decode caches behind one ``CacheFamily`` protocol.

Four families share the allocator and the batched decode step:

=========  ==============================================  ===============
family     page contents (per layer)                       state growth
=========  ==============================================  ===============
``kv``     k/v pages   (num_pages, P, Hkv, hd) x2          O(L) paged
``mla``    latent c    (num_pages, P, kv_lora)
           + rope kpe  (num_pages, P, qk_rope)             O(L) paged
``srf``    feature S   (num_slots, Hq, m, dv)
           + norm z    (num_slots, Hq, m)                  O(m d) constant
``ssd``    conv tail   (num_slots, conv-1, conv_dim)
           + SSM state (num_slots, nh, ns, hd)             O(1) constant
=========  ==============================================  ===============

``kv``/``mla`` grow one page per ``page_size`` tokens; ``srf``/``ssd``
are the paper's constant-size decode states stored as a *single* page
("slot") per request — the multi-block structured construction keeps
that layout uniform across head counts, so the same block table indexes
all four. Pools carry a leading layer axis per model segment and are
scanned together with the stacked layer params (see
``transformer.paged_step``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transforms import is_pow2
from repro.models import transformer as model_lib


@dataclass(frozen=True)
class PagedConfig:
    """Pool-layout knobs orthogonal to the scheduler's SchedConfig.

    ``quantize_kv``: store KV pages as int8 with f32 per-page-row (one per
    cached token) scales — halves (bf16) or quarters (f32) the dominant
    pool bytes; dequant is fused into the paged-gather kernel. Only the
    ``kv`` family quantizes (MLA latents are already compressed, srf/ssd
    states are constant-size).
    """
    quantize_kv: bool = False


# ---------------------------------------------------------------------------
# family protocol
# ---------------------------------------------------------------------------

class CacheFamily(Protocol):
    """A cache family owns the pool layout for one serving state kind."""
    name: str
    constant_state: bool     # True: one fixed-size page per request

    def layer_pool(self, cfg, num_pages: int, page_size: int,
                   paged: Optional[PagedConfig] = None) -> Dict:
        """Single-layer pool pytree (leading axis = num_pages/slots)."""

    def bytes_per_token(self, cfg, max_len: int,
                        paged: Optional[PagedConfig] = None) -> float:
        """Decode-state bytes per cached token per layer (docs/stats)."""


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


class KVFamily:
    name = "kv"
    constant_state = False

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        shp = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        if paged is not None and paged.quantize_kv:
            sshp = (num_pages, page_size, 1)
            return {"k": jnp.zeros(shp, jnp.int8),
                    "v": jnp.zeros(shp, jnp.int8),
                    "k_scale": jnp.zeros(sshp, jnp.float32),
                    "v_scale": jnp.zeros(sshp, jnp.float32)}
        return {"k": jnp.zeros(shp, _dt(cfg)), "v": jnp.zeros(shp, _dt(cfg))}

    def bytes_per_token(self, cfg, max_len, paged=None):
        if paged is not None and paged.quantize_kv:
            return 2 * (cfg.n_kv_heads * cfg.head_dim + 4)   # int8 + f32 scale
        return 2 * cfg.n_kv_heads * cfg.head_dim * _dt(cfg).itemsize


class MLAFamily:
    name = "mla"
    constant_state = False

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        return {"c": jnp.zeros((num_pages, page_size, cfg.mla_kv_lora), _dt(cfg)),
                "kpe": jnp.zeros((num_pages, page_size, cfg.mla_qk_rope), _dt(cfg))}

    def bytes_per_token(self, cfg, max_len, paged=None):
        return (cfg.mla_kv_lora + cfg.mla_qk_rope) * _dt(cfg).itemsize


class SRFFamily:
    name = "srf"
    constant_state = True

    def _feat_dim(self, cfg):
        from repro.models.attention import srf_cfg
        return srf_cfg(cfg).feat_dim

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        m = self._feat_dim(cfg)
        dv = cfg.mla_v_dim if cfg.is_mla else cfg.head_dim
        return {"s": jnp.zeros((num_pages, cfg.n_heads, m, dv), _dt(cfg)),
                "z": jnp.zeros((num_pages, cfg.n_heads, m), _dt(cfg))}

    def bytes_per_token(self, cfg, max_len, paged=None):
        m = self._feat_dim(cfg)
        dv = cfg.mla_v_dim if cfg.is_mla else cfg.head_dim
        total = cfg.n_heads * m * (dv + 1) * _dt(cfg).itemsize
        return total / max_len      # amortized: the state never grows


class SSDFamily:
    name = "ssd"
    constant_state = True

    def layer_pool(self, cfg, num_pages, page_size, paged=None):
        cd = cfg.d_inner + 2 * cfg.ssm_state
        return {"conv": jnp.zeros((num_pages, cfg.ssm_conv - 1, cd), _dt(cfg)),
                "ssm": jnp.zeros((num_pages, cfg.ssm_heads, cfg.ssm_state,
                                  cfg.ssm_head_dim), jnp.float32)}

    def bytes_per_token(self, cfg, max_len, paged=None):
        cd = cfg.d_inner + 2 * cfg.ssm_state
        total = ((cfg.ssm_conv - 1) * cd * _dt(cfg).itemsize
                 + cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4)
        return total / max_len


FAMILIES = {f.name: f for f in (KVFamily(), MLAFamily(), SRFFamily(),
                                SSDFamily())}


def family_for(cfg) -> CacheFamily:
    """Resolve the cache family a config serves with."""
    if cfg.is_encdec or cfg.family == "hybrid" or cfg.frontend != "none":
        raise ValueError(
            f"paged serving does not support family={cfg.family!r} / "
            f"frontend={cfg.frontend!r} yet (use serving.legacy.Engine)")
    if cfg.family == "ssm":
        return FAMILIES["ssd"]
    if cfg.attn_impl == "srf":
        return FAMILIES["srf"]
    if cfg.is_mla:
        return FAMILIES["mla"]
    return FAMILIES["kv"]


# ---------------------------------------------------------------------------
# pool container
# ---------------------------------------------------------------------------

def init_pools(cfg, num_pages: int, page_size: int, mesh=None,
               paged: Optional[PagedConfig] = None) -> List[Dict]:
    """One pool pytree per model segment, leading axis = layer count.

    All layers of a segment share shapes, so the per-layer pools are
    stacked and scanned with the stacked layer params.

    ``mesh``: lay the pools out with model-axis ``NamedSharding`` on the
    head/feature dim (``serving.mesh.shard.pool_specs``), degrading to
    replication whenever the dim does not divide — the same contract as
    ``distributed/sharding.py``. The page *tables* stay host-local either
    way (they are scheduler bookkeeping, not device state)."""
    fam = family_for(cfg)
    pools = []
    for kind, count in model_lib.segments(cfg):
        one = fam.layer_pool(cfg, num_pages, page_size, paged)
        pools.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one))
    if mesh is not None:
        from .mesh import shard as mesh_shard
        pools = mesh_shard.place_pools(pools, cfg, mesh, paged)
    return pools


def pool_page_rows(pools: List[Dict], page_ids: List[int]) -> List[Dict]:
    """Copy-on-preempt snapshot: pull the given pages of every layer pool
    to host memory (numpy) so they can be restored after eviction.
    Synchronous (blocks on the transfer); the engine's hot path uses
    :func:`snapshot_page_rows_async` instead."""
    idx = np.asarray(page_ids, np.int32)
    return [jax.tree.map(lambda a: np.asarray(a[:, idx]), p) for p in pools]


class PendingSnapshot:
    """Copy-on-preempt snapshot whose device->host transfer overlaps the
    next decode step.

    Eviction enqueues the page-row slice (a device computation producing
    fresh buffers, so later in-place pool updates and donation cannot
    clobber it) and immediately kicks off the non-blocking host transfer
    (``copy_to_host_async``). The decode loop continues; ``to_host``
    fences with ``jax.block_until_ready`` only when the snapshot is
    actually needed (swap-in), by which time the bytes have usually
    already streamed over."""

    def __init__(self, slices: List[Dict]):
        self._dev: Optional[List[Dict]] = slices
        self._host: Optional[List[Dict]] = None
        for leaf in jax.tree.leaves(slices):
            try:
                leaf.copy_to_host_async()
            except AttributeError:      # non-jax leaf (already host)
                pass

    def fence(self) -> None:
        """Block until the device-side slice has executed (the source pool
        buffers are then dead to this snapshot — safe to donate)."""
        if self._dev is not None:
            jax.block_until_ready(self._dev)

    def to_host(self) -> List[Dict]:
        if self._host is None:
            self._host = [jax.tree.map(np.asarray, p) for p in self._dev]
            self._dev = None
        return self._host


def snapshot_page_rows_async(pools: List[Dict],
                             page_ids: List[int]) -> PendingSnapshot:
    """Async copy-on-preempt: returns a :class:`PendingSnapshot` whose
    host transfer overlaps subsequent decode steps."""
    idx = jnp.asarray(page_ids, jnp.int32)
    return PendingSnapshot([jax.tree.map(lambda a: a[:, idx], p)
                            for p in pools])


def zero_page_rows(pools: List[Dict], page_ids: List[int]) -> List[Dict]:
    """Reset the given pages of every layer pool to zero. Needed when a
    freed page is re-issued to a fresh request of a constant-state family
    (srf/ssd): those pages are running accumulators, so stale content is
    not masked out downstream the way an unwritten KV row is."""
    idx = jnp.asarray(page_ids, jnp.int32)
    return [jax.tree.map(lambda a: a.at[:, idx].set(jnp.zeros((), a.dtype)), p)
            for p in pools]


def restore_page_rows(pools: List[Dict], page_ids: List[int],
                      snap) -> List[Dict]:
    """Inverse of :func:`pool_page_rows`: scatter a snapshot back into
    (freshly allocated) pages. Accepts either the synchronous host-array
    form or a :class:`PendingSnapshot`. Returns the updated pools."""
    if isinstance(snap, PendingSnapshot):
        snap = snap.to_host()
    idx = jnp.asarray(page_ids, jnp.int32)
    return [jax.tree.map(lambda a, s: a.at[:, idx].set(
                jnp.asarray(s, dtype=a.dtype)), p, sn)
            for p, sn in zip(pools, snap)]


def apply_moves(pools: List[Dict], moves: Dict[int, int]) -> List[Dict]:
    """Apply a defrag plan {old: new} to every layer pool."""
    if not moves:
        return pools
    src = jnp.asarray(list(moves.keys()), jnp.int32)
    dst = jnp.asarray(list(moves.values()), jnp.int32)
    return [jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), p)
            for p in pools]


def pool_bytes(pools: List[Dict]) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(pools))


def pool_bytes_per_device(pools: List[Dict]) -> int:
    """Bytes one device holds: the per-shard slice for sharded leaves,
    the full leaf for replicated ones (GLOBAL shape / axis product only
    shrinks dims the NamedSharding actually splits)."""
    total = 0
    for x in jax.tree.leaves(pools):
        shard_shape = x.shape
        sharding = getattr(x, "sharding", None)
        if sharding is not None:
            try:
                shard_shape = sharding.shard_shape(x.shape)
            except Exception:
                pass
        total += int(np.prod(shard_shape)) * x.dtype.itemsize
    return total
