"""Paged serving subsystem: block allocator, pooled caches per family,
continuous-batching scheduler, batched sampler, the Engine on top, and
the mesh layer (``serving/mesh/``) that shards page pools over a device
mesh and routes requests across engine replicas.

See ``serving/README.md`` for the block-table layout, the
bytes-per-token comparison across cache families (full KV vs MLA-latent
vs the paper's SRF state vs SSD), the mesh-mode pool layout /
router policy / snapshot-overlap notes, and the fault-tolerance story
(``serving/ft.py``: watchdog + failover; ``serving/chaos.py`` is the
TEST-ONLY fault injector and is deliberately not exported here), plus
the prefix-sharing subsystem (``serving/prefix/``: radix cache,
copy-on-write paged KV, chunked prefill —
``Engine(..., prefix=PrefixConfig())`` turns it on).
``serving.legacy`` keeps the old per-slot engine as the benchmark
baseline (deprecated; its import warns).
"""
from .blocks import BlockAllocator, BlockTable          # noqa: F401
from .engine import Engine, Request                     # noqa: F401
from .ft import FTConfig, ReplicaWatchdog               # noqa: F401
from .paged_cache import (PagedConfig, PoolPlan, family_for,  # noqa: F401
                          init_pools, plan_for)
from .prefix import ChunkConfig, PrefixCache, PrefixConfig  # noqa: F401
from .scheduler import SchedConfig, Scheduler           # noqa: F401
from .mesh import Router, RouterConfig                  # noqa: F401
