"""Paged serving subsystem: block allocator, pooled caches per family,
continuous-batching scheduler, batched sampler, and the Engine on top.

See ``serving/README.md`` for the block-table layout and the
bytes-per-token comparison across cache families (full KV vs MLA-latent
vs the paper's SRF state vs SSD). ``serving.legacy`` keeps the old
per-slot engine as the benchmark baseline.
"""
from .blocks import BlockAllocator, BlockTable          # noqa: F401
from .engine import Engine, Request                     # noqa: F401
from .paged_cache import family_for, init_pools         # noqa: F401
from .scheduler import SchedConfig, Scheduler           # noqa: F401
