"""repro.serving subsystem."""
