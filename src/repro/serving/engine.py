"""Paged continuous-batching serving engine.

Replaces the per-slot lock-step engine (now ``serving.legacy``, kept as
a test oracle / benchmark baseline): all requests share pooled,
pre-allocated caches (``paged_cache``) indexed through per-request block
tables and constant-state slots (``blocks``), a scheduler handles
admission / chunked prefill / preemption (``scheduler``), prefill and
decode both run as single batched jit steps (``transformer.paged_step``),
and sampling is temperature / top-k / top-p (``sampler``) with greedy as
the deterministic default.

Why paged: full-KV and MLA caches grow O(L) and are pooled in fixed-size
pages; the paper's SRF attention state (and the SSD state) is O(m d) —
one constant-size slot per request. EVERY registry family serves through
this engine: dense/moe (kv or mla pages), ssm (ssd slots), hybrid (kv
pages AND ssd slots per layer), enc-dec (kv pages + a read-only
encoder-memory slot written once at admission), and the vlm/audio
frontend archs (their decode path is plain kv).

Step shapes are fixed (max_batch x 1 decode, prefill_batch x chunk
prefill), so the engine compiles exactly two programs regardless of
traffic; inactive batch rows are masked and their writes land in the
reserved null page / null slot.
"""
from __future__ import annotations

import hashlib
import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as step_lib
from repro.obs import metrics as obs_metrics
from repro.obs import quality as obs_quality
from repro.obs import spans as obs_spans
from repro.obs import trace as obs_trace

from . import paged_cache
from .prefix import ChunkPolicy, PrefixCache, PrefixConfig, cow
from .sampler import sample_stateless as _sample_stateless
from .scheduler import SchedConfig, Scheduler, Sequence, tenant_of


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int = 32
    eos_id: int = -1                 # -1: never
    priority: int = 0                # higher first (policy="priority")
    temperature: float = 0.0         # 0 = greedy (deterministic)
    top_k: int = 0                   # 0 = disabled
    top_p: float = 1.0
    embed_seed: int = 0              # seeded-SRF configs: personalized
    #                                  zero-storage projection seed (0 =
    #                                  the model's base projection); costs
    #                                  no pool pages and no weight bytes
    enc_emb: Optional[np.ndarray] = None  # (enc_len, feat) enc-dec input
    deadline: Optional[float] = None # seconds after submit; overdue WAITING
    #                                  requests finish as 'timeout' instead
    #                                  of serving late (running ones finish)
    max_retries: int = 2             # replica-failure rescue budget
    namespace: str = ""              # tenant id: per-tenant accounting labels
    #                                  + prefix-cache partition ("" = default
    #                                  tenant, labelled "-")
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    finish_reason: str = ""          # eos | length | timeout | shed | failed
    retries: int = 0                 # rescues consumed (ft router)
    deadline_at: Optional[float] = None  # absolute stamp, set at 1st submit
    # monotonic (perf_counter) stamps — wall-clock time.time() steps
    # corrupt TTFT/TPOT; trace carries the full lifecycle
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    trace: Optional[obs_trace.Trace] = None


def _default_sched(cfg, batch_slots: int, max_len: int, plan,
                   policy: str) -> SchedConfig:
    page = 16 if max_len >= 64 else 8
    if not plan.has_paged:
        # constant-state only: the slot domain is the whole geometry
        return SchedConfig(max_batch=batch_slots, prefill_batch=batch_slots,
                           prefill_chunk=min(32, max(8, page)),
                           page_size=page, num_pages=2, table_width=1,
                           num_slots=batch_slots + 1, policy=policy)
    width = max(1, -(-max_len // page))
    return SchedConfig(max_batch=batch_slots, prefill_batch=batch_slots,
                       prefill_chunk=min(32, 2 * page), page_size=page,
                       num_pages=2 * batch_slots * width + 1,
                       table_width=width, num_slots=batch_slots + 1,
                       policy=policy)


def _enc_namespace(enc_emb) -> int:
    """Prefix-cache namespace for an enc-dec request: a content hash of
    the encoder features (identical features -> identical memory rows ->
    identical decoder KV, so sharing is sound; different features must
    partition the trie)."""
    h = hashlib.blake2b(np.ascontiguousarray(enc_emb).tobytes(),
                        digest_size=8)
    return int.from_bytes(h.digest(), "big")


def _cache_namespace(req, seeded_srf: bool = False) -> int:
    """Prefix-cache trie namespace for a request: partitioned by tenant
    (requests from different namespaces must never share cache state —
    isolation beats reuse across trust boundaries) and, for enc-dec, by
    encoder-content hash. A default-tenant text-only request keeps
    ``ns=0``, bit-identical to the pre-tenant trie layout.

    ``seeded_srf`` engines additionally partition by ``embed_seed``:
    personalized projections produce different attention states for the
    same token prefix, so sharing across seeds would be unsound. Non-
    seeded engines ignore the field (no needless sharing reduction)."""
    ns = _enc_namespace(req.enc_emb) if req.enc_emb is not None else 0
    tenant = getattr(req, "namespace", "")
    if tenant:
        h = hashlib.blake2b(tenant.encode("utf-8"), digest_size=8)
        ns ^= int.from_bytes(h.digest(), "big")
    if seeded_srf:
        es = getattr(req, "embed_seed", 0)
        if es:
            h = hashlib.blake2b(int(es).to_bytes(8, "big", signed=False),
                                digest_size=8)
            ns ^= int.from_bytes(h.digest(), "big")
    return ns


# distinct label value per engine instance: replicas sharing one registry
# must not share counter children (``router.describe`` reads per-engine)
_ENGINE_IDS = itertools.count()


class Engine:
    """Continuous batching over paged cache pools.

    ``batch_slots`` and ``max_len`` keep the old engine's constructor
    contract (tests, examples); pass ``sched=SchedConfig(...)`` to size
    the pools explicitly (e.g. tight pools to exercise preemption).

    ``mesh``: mesh-sharded serving — pools laid out with model-axis
    NamedSharding on the head/feature dim, attention params sliced to
    match, and the step shard_map-wrapped (``serving/mesh/shard.py`` owns
    the layout contract; ``launch.steps.make_paged_step`` builds the
    step). ``paged=PagedConfig(quantize_kv=True)`` stores KV pages as
    int8 with per-page-row scales (kv family only).

    Enc-dec: every :class:`Request` must carry ``enc_emb`` (the frontend
    features); the engine runs the encoder exactly once per request at
    admission (batch-1, bit-identical to the legacy per-slot prefill) and
    caches the result in the read-only encoder-memory pool at the
    request's slot — decode steps gather it and cross-attend.

    Copy-on-preempt snapshots are asynchronous: eviction enqueues the
    device-side page+slot slice and the non-blocking host transfer, the
    next decode step overlaps the copy (the step donates its pool
    buffers, so the engine fences pending slices with
    ``block_until_ready`` first), and the transfer is only awaited when
    the victim swaps back in.
    """

    def __init__(self, cfg, params, batch_slots: int = 4,
                 max_len: int = 512, sched: Optional[SchedConfig] = None,
                 policy: str = "fcfs", seed: int = 0, mesh=None,
                 paged: Optional[paged_cache.PagedConfig] = None,
                 metrics: Optional[obs_metrics.MetricsRegistry] = None,
                 quality_every: int = 64,
                 quality_tol: float = obs_quality.DRIFT_TOL,
                 prefix: Optional[PrefixConfig] = None,
                 spans: Optional[obs_spans.SpanRecorder] = None):
        self.cfg = cfg
        self.plan = paged_cache.plan_for(cfg)
        self.mesh = mesh
        self.paged = paged or paged_cache.PagedConfig()
        self.metrics = metrics if metrics is not None \
            else obs_metrics.MetricsRegistry()
        self.spans = spans if spans is not None else obs_spans.NOOP
        self.engine_id = str(next(_ENGINE_IDS))
        if sched is None:
            sched = _default_sched(cfg, batch_slots, max_len, self.plan,
                                   policy)
        self.sched_cfg = sched
        self.sched = Scheduler(sched, self.plan, metrics=self.metrics,
                               labels={"engine": self.engine_id},
                               spans=self.spans)
        self.pools = paged_cache.init_pools(cfg, sched.num_pages,
                                            sched.page_size,
                                            num_slots=self.sched.num_slots,
                                            mesh=mesh, paged=self.paged)
        if mesh is not None:
            from .mesh import shard as mesh_shard
            params = mesh_shard.place_params(params, cfg, mesh)
        self.params = params
        self._step = jax.jit(
            step_lib.make_paged_step(cfg, mesh=mesh, paged=self.paged,
                                     params_sds=params),
            donate_argnums=(1,))
        self._encode = (jax.jit(step_lib.make_encode_step(cfg))
                        if cfg.is_encdec else None)
        # stateless sampling: the base key never advances — per-token
        # noise is derived as fold_in(fold_in(base, uid), position), so a
        # request's sampled stream is independent of batch composition,
        # admission order and replica (FT replay of sampled requests is
        # bit-identical)
        self._base_key = jax.random.PRNGKey(seed)
        self._seeded_srf = (getattr(cfg, "attn_impl", None) == "srf"
                            and getattr(cfg.srf, "seeded", False))
        # injectable step-time clock, read exactly twice per step() — the
        # replica watchdog consumes the recorded engine_step_seconds, and
        # the chaos harness simulates stalls by swapping this clock
        self.clock = time.perf_counter
        self._pending_snaps: List[paged_cache.PendingSnapshot] = []
        # (src, dst) tail-page copies owed to the prefix cache, flushed
        # as one batched device copy at the end of the prefill step so
        # donors keep exclusive tail ownership (no mid-decode forks)
        self._cache_copies: List[Tuple[int, int]] = []
        # prefix sharing (serving/prefix): pure-constant-state plans have
        # no pages to share, so the cache is paged-domain only
        self.prefix: Optional[PrefixCache] = None
        self._chunk: Optional[ChunkPolicy] = None
        if prefix is not None and prefix.enabled and self.plan.has_paged:
            self.prefix = PrefixCache(
                self.sched.alloc, self.sched_cfg.page_size,
                paged_cache.page_bytes(self.pools), prefix,
                metrics=self.metrics, labels={"engine": self.engine_id},
                spans=self.spans)
            self.sched.attach_prefix(self.prefix)
            self._chunk = ChunkPolicy(prefix.chunk, spans=self.spans)
        self._init_metrics()
        self._quality_every = (quality_every
                               if getattr(cfg, "attn_impl", None) == "srf"
                               else 0)
        self._quality_tol = quality_tol
        # primed so the FIRST decode step publishes a sample — short runs
        # (fewer than quality_every steps) still see the live gauge
        self._steps_since_quality = max(0, self._quality_every - 1)

    # -- metrics -------------------------------------------------------------

    def _init_metrics(self) -> None:
        """Bind this engine's children in the (possibly shared) registry;
        ``self.stats`` stays API-compatible with the old ad-hoc dict as
        a read-only view over the registry."""
        lab = {"engine": self.engine_id}
        m = self.metrics
        c = lambda name, help: m.counter(name, help,  # noqa: E731
                                         ("engine",)).labels(**lab)
        h = lambda name, help: m.histogram(           # noqa: E731
            name, help, ("engine",)).labels(**lab)
        self._c_tokens = c("engine_tokens_total", "tokens generated")
        self._c_requests = c("engine_requests_total", "requests finished")
        self._c_prefill_steps = c("engine_prefill_steps_total",
                                  "batched prefill-chunk steps")
        self._c_prefill_tokens = c("engine_prefill_tokens_total",
                                   "prompt tokens actually prefilled "
                                   "(prefix-cache hits skip theirs)")
        self._c_decode_steps = c("engine_decode_steps_total",
                                 "batched decode steps")
        self._c_preemptions = c("engine_preemptions_total",
                                "copy-on-preempt evictions")
        self._c_expired = c("engine_expired_total",
                            "waiting requests expired past deadline")
        self._c_cow_forks = c("prefix_cow_forks_total",
                              "copy-on-write page forks applied (admission "
                              "boundary + decode divergence)")
        self._h_step = h("engine_step_seconds", "wall time of one engine "
                         "step (the replica-health watchdog reads this)")
        self._h_ttft = h("request_ttft_seconds", "time to first token")
        self._h_tpot = h("request_tpot_seconds", "per-output-token time "
                         "after the first")
        self._h_queue = h("request_queue_seconds", "submit -> admission")
        self._h_e2e = h("request_e2e_seconds", "submit -> done")
        # per-tenant accounting (fairness substrate): same registry,
        # {engine, tenant} labels; children bound lazily per namespace
        tl = ("engine", "tenant")
        self._ct_prefill = m.counter(
            "tenant_prefill_tokens_total",
            "prompt tokens prefilled, by tenant namespace", tl)
        self._ct_decode = m.counter(
            "tenant_decode_tokens_total",
            "decode tokens generated, by tenant namespace", tl)
        self._ct_requests = m.counter(
            "tenant_requests_total",
            "requests finished, by tenant namespace", tl)
        self._ct_expired = m.counter(
            "tenant_expired_total",
            "requests expired past deadline, by tenant namespace", tl)
        self._tenant_children: Dict[str, Dict[str, object]] = {}
        self.stats = obs_metrics.StatsView({
            "tokens": self._c_tokens.value,
            "requests": self._c_requests.value,
            "prefill_steps": self._c_prefill_steps.value,
            "decode_steps": self._c_decode_steps.value,
            "preemptions": self._c_preemptions.value,
        })
        self._sample_memory_gauges()

    def _tenant(self, req) -> Dict[str, object]:
        """Bound per-tenant counter children for a request's namespace
        (cached — binding is a dict insert, incrementing is one add)."""
        t = tenant_of(req)
        ch = self._tenant_children.get(t)
        if ch is None:
            lab = {"engine": self.engine_id, "tenant": t}
            ch = {"prefill": self._ct_prefill.labels(**lab),
                  "decode": self._ct_decode.labels(**lab),
                  "requests": self._ct_requests.labels(**lab),
                  "expired": self._ct_expired.labels(**lab)}
            self._tenant_children[t] = ch
        return ch

    def _sample_memory_gauges(self) -> None:
        """Device-memory gauges from the pool container (pools are
        preallocated, so bytes are constant per engine; free/used page
        and slot gauges track live via the scheduler)."""
        lab = {"engine": self.engine_id}
        g = self.metrics.gauge("pool_bytes", "total pool bytes (all "
                               "devices)", ("engine",)).labels(**lab)
        g.set(paged_cache.pool_bytes(self.pools))
        gd = self.metrics.gauge("pool_bytes_per_device",
                                "pool bytes resident per device",
                                ("engine",)).labels(**lab)
        gd.set(paged_cache.pool_bytes_per_device(self.pools))

    def _maybe_sample_quality(self) -> None:
        """Every ``quality_every`` decode steps, publish the paper's row
        statistics (Def. 1 calibration) of the live SRF params as gauges
        — the live counterpart of ``bench_coherence``'s offline report."""
        if not self._quality_every or not self.metrics.enabled:
            return
        self._steps_since_quality += 1
        if self._steps_since_quality < self._quality_every:
            return
        self._steps_since_quality = 0
        stats = obs_quality.srf_quality_probe(self.cfg, self.params)
        if not stats:
            return
        gq = self.metrics.gauge("srf_quality", "live embedding row "
                                "statistics (Def. 1)", ("engine", "stat"))
        for k, v in stats.items():
            gq.labels(engine=self.engine_id, stat=k).set(v)
        if obs_quality.moments_drifted(stats, self._quality_tol):
            self.metrics.event("quality_drift", engine=self.engine_id,
                               tol=self._quality_tol, **stats)

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if self.cfg.is_encdec and req.enc_emb is None:
            raise ValueError(
                "enc-dec serving needs Request.enc_emb (frontend features "
                f"({self.cfg.enc_len}, feat)); request uid={req.uid} has none")
        now = time.perf_counter()
        req.t_submit = now
        if req.deadline is not None and req.deadline_at is None:
            # absolute stamp survives rescue re-submission: the deadline
            # clock keeps running across replica failures
            req.deadline_at = now + req.deadline
        if req.trace is None:
            req.trace = obs_trace.Trace(uid=req.uid)
        req.trace.stamp("queued", now)
        self.metrics.event("queued", uid=req.uid, engine=self.engine_id)
        seq = self.sched.submit(req)
        if self.prefix is not None:
            # decoder KV depends on the encoder memory, and tenants must
            # not share cache state: token-equal prompts under different
            # encoder inputs or namespaces (or, when projections are
            # personalized, embed seeds) never cross-match
            seq.ns = _cache_namespace(req, self._seeded_srf)

    def prefix_peek(self, req: Request) -> int:
        """Tokens of ``req``'s prompt this engine could serve from its
        prefix cache right now — non-pinning, non-LRU-touching (the
        router's affinity probe)."""
        if self.prefix is None:
            return 0
        return self.prefix.peek(_cache_namespace(req, self._seeded_srf),
                                req.prompt,
                                want_state=bool(self.plan.slot_families))

    def run(self, on_step=None) -> List[Request]:
        """Drain all submitted requests; returns the completed ones.
        ``on_step(engine)`` is called after every scheduler iteration
        (the reporter's periodic-metrics hook)."""
        tracked = [s.req for s in self.sched.waiting + self.sched.running]
        stall = 0
        while self.sched.has_work:
            progressed = self.step()
            if on_step is not None:
                on_step(self)
            stall = 0 if progressed else stall + 1
            if stall > 2:
                raise RuntimeError(
                    "scheduler stalled: pool too small for the remaining "
                    f"requests (free={self.sched.alloc.free_pages} pages, "
                    f"{self.sched.free_slots} slots)")
        return [r for r in tracked if r.done]

    def step(self) -> bool:
        """One scheduler iteration: admit, then one prefill-chunk step if
        any sequence is still prefilling, else one batched decode step.
        Returns False when nothing could run (allocator exhausted).

        Timed through ``self.clock`` (exactly two reads per step) into
        ``engine_step_seconds`` — the replica-health signal. Spans use
        ``perf_counter`` directly and never touch ``self.clock`` (the
        chaos harness's stall clock counts its reads)."""
        t0 = self.clock()
        tok = self.spans.begin("engine_step")
        try:
            return self._step_once()
        finally:
            self.spans.end(tok)
            self._h_step.observe(self.clock() - t0)

    def _step_once(self) -> bool:
        # deadline expiry first: an overdue waiting request holds no
        # device capacity, so dropping it is pure bookkeeping — and doing
        # it before admission means a backlogged pool never wastes pages
        # on work that is already late
        expired = self.sched.expire_overdue(time.perf_counter())
        for seq in expired:
            self._expire(seq)
        admitted = self.sched.admit()
        now = time.perf_counter() if admitted else 0.0
        fresh: List[Sequence] = []
        for seq in admitted:
            if seq.req.trace is not None:
                seq.req.trace.stamp("admitted", now)
            if seq.snapshot is not None:
                self.pools = paged_cache.restore_page_rows(
                    self.pools, seq.table.pages, self._slot_ids(seq),
                    seq.snapshot)
                self.sched.restored(seq)
                if seq.req.trace is not None:
                    seq.req.trace.stamp("restored", now)
                self.metrics.event("restored", uid=seq.req.uid,
                                   engine=self.engine_id)
            else:
                if seq.hit_tokens > 0:
                    if seq.req.trace is not None:
                        seq.req.trace.stamp("prefix_hit", now)
                    self.metrics.event("prefix_hit", uid=seq.req.uid,
                                       engine=self.engine_id,
                                       tokens=seq.hit_tokens)
                if seq.slot is not None:
                    # constant-state slots are accumulators: a reused slot
                    # must start from zero, not the previous request's
                    # state
                    fresh.append(seq)
        if fresh:
            # the enc-dec memory rows are fully overwritten by the encoder
            # below, so their zeroing is skipped (one whole-pool write
            # saved per admission burst)
            self.pools = paged_cache.zero_slot_rows(
                self.pools, [s.slot for s in fresh],
                zero_memory=self._encode is None)
            if self._encode is not None:
                self._write_memories(fresh)
        self._apply_forks(admitted)
        for seq in admitted:
            if seq.state_payload is not None:
                # donor's constant-state snapshot at the matched token
                # count: restoring it is what makes the shared KV pages
                # resumable for slot-bearing plans
                self.pools = paged_cache.restore_page_rows(
                    self.pools, [], self._slot_ids(seq), seq.state_payload)
                seq.state_payload = None
        work = self.sched.prefill_work()
        sc = self.sched_cfg
        if work and self._chunk is not None \
                and self.sched.decode_ready() \
                and self._chunk.spans_steps(work, sc.prefill_chunk,
                                            sc.prefill_batch) \
                and self._chunk.decode_turn():
            # chunked-prefill interleave: yield this step to decode so a
            # long cold prompt cannot starve running requests' TPOT
            if self._decode_step(self.sched.decode_ready()):
                return True
            work = self.sched.prefill_work()    # decode may have evicted
        if work:
            self._prefill_step(work)
            return True
        ready = self.sched.decode_ready()
        if ready:
            return self._decode_step(ready) or bool(expired)
        return bool(admitted) or bool(expired)

    def _apply_forks(self, seqs: List[Sequence]) -> None:
        """Apply pending COW forks as ONE batched gather-then-scatter
        copy (``copy_page_rows`` reads every source from the pre-copy
        pools, so a page freed and recycled as another fork's destination
        in the same round can never clobber a source). Admission forks
        pin their source in the cache until the copy is issued — released
        here."""
        forks = [s.fork for s in seqs if s.fork is not None]
        if not forks:
            return
        self.pools = paged_cache.copy_page_rows(
            self.pools, [f.src for f in forks], [f.dst for f in forks])
        self._c_cow_forks.inc(len(forks))
        self.spans.instant("cow_fork", pages=len(forks))
        for s in seqs:
            if s.fork is not None:
                if s.fork.pinned_src:
                    self.prefix.release_fork(s.fork.src)
                s.fork = None

    def _expire(self, seq: Sequence) -> None:
        """Terminal ``timeout``: the request went past its deadline while
        waiting (it holds no pages/slots — the scheduler already dropped
        it from the queue)."""
        req = seq.req
        req.done = True
        req.finish_reason = "timeout"
        now = time.perf_counter()
        req.t_done = now
        if req.trace is not None:
            req.trace.stamp("done", now)
            if req.trace.e2e is not None:
                self._h_e2e.observe(req.trace.e2e)
        self._c_expired.inc()
        self._tenant(req)["expired"].inc()
        self.metrics.event("expired", uid=req.uid, engine=self.engine_id)

    @staticmethod
    def _slot_ids(seq: Sequence) -> List[int]:
        return [seq.slot] if seq.slot is not None else []

    # -- enc-dec memory ------------------------------------------------------

    def _write_memories(self, seqs: List[Sequence]) -> None:
        """Run the encoder once per freshly admitted request and cache the
        results in the read-only memory pool. Encoding stays batch-1 per
        request (bit-identical to the legacy per-slot prefill); the row
        writes are batched into ONE whole-pool update per admission."""
        mems = [self._encode(self.params, jnp.asarray(s.req.enc_emb)[None])[0]
                for s in seqs]
        idx = jnp.asarray([s.slot for s in seqs], jnp.int32)
        new = self.pools["memory"].at[idx].set(
            jnp.stack(mems).astype(self.pools["memory"].dtype))
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            new = jax.device_put(
                new, NamedSharding(self.mesh, PartitionSpec()))
        self.pools["memory"] = new

    # -- snapshot fencing ----------------------------------------------------

    def _fence_snapshots(self) -> None:
        """The jit'd step donates the pool buffers; make sure every pending
        copy-on-preempt slice has executed before they are reused. This
        waits on the *device* compute only — the device->host transfer
        keeps streaming underneath the next step."""
        if self._pending_snaps:
            for snap in self._pending_snaps:
                snap.fence()
            self._pending_snaps.clear()

    def _run_step(self, tokens, pos, qv, tables, slots, embed_seeds=None):
        self._fence_snapshots()
        if self._seeded_srf:
            return self._step(self.params, self.pools, jnp.asarray(tokens),
                              jnp.asarray(pos), jnp.asarray(qv),
                              jnp.asarray(tables), jnp.asarray(slots),
                              jnp.asarray(embed_seeds))
        return self._step(self.params, self.pools, jnp.asarray(tokens),
                          jnp.asarray(pos), jnp.asarray(qv),
                          jnp.asarray(tables), jnp.asarray(slots))

    def _embed_seeds(self, seqs: List[Sequence], n_pad: int) -> np.ndarray:
        """(B,) uint32 per-row projection seeds for seeded-SRF steps
        (0 = base projection; padded rows are base)."""
        es = np.zeros((n_pad,), np.uint32)
        for i, s in enumerate(seqs):
            es[i] = getattr(s.req, "embed_seed", 0) & 0xFFFFFFFF
        return es

    # -- sampling -----------------------------------------------------------

    def _sample_rows(self, rows: jax.Array, seqs: List[Sequence],
                     n_pad: int) -> np.ndarray:
        """Stateless per-request sampling: row i's noise is keyed by
        (base_key, uid, emitted-token index), never by engine RNG state —
        the token a request samples at position p is the same whatever
        batch it lands in (and on whatever replica; FT replay re-derives
        the identical keys from the forced-prefix high-water mark)."""
        temps = np.zeros((n_pad,), np.float32)
        ks = np.zeros((n_pad,), np.int32)
        ps = np.ones((n_pad,), np.float32)
        uids = np.zeros((n_pad,), np.uint32)
        poss = np.zeros((n_pad,), np.int32)
        for i, s in enumerate(seqs):
            temps[i] = s.req.temperature
            ks[i] = s.req.top_k
            ps[i] = s.req.top_p
            uids[i] = s.req.uid & 0xFFFFFFFF    # negative uids (probes) wrap
            poss[i] = len(s.req.out_tokens)     # index of the token drawn
        stok = self.spans.begin("sample")
        toks = _sample_stateless(self._base_key, jnp.asarray(uids),
                                 jnp.asarray(poss), rows,
                                 jnp.asarray(temps), jnp.asarray(ks),
                                 jnp.asarray(ps))
        out = np.asarray(toks)
        self.spans.end(stok)
        return out

    # -- prefill ------------------------------------------------------------

    def _prefill_step(self, work: List[Sequence]) -> None:
        stok = self.spans.begin("prefill_step")
        sc = self.sched_cfg
        b, c, m = sc.prefill_batch, sc.prefill_chunk, sc.table_width
        tokens = np.zeros((b, c), np.int32)
        pos = np.zeros((b, c), np.int32)
        qv = np.zeros((b, c), bool)
        tables = np.zeros((b, m), np.int32)
        slots = np.zeros((b,), np.int32)
        last_row = np.zeros((b,), np.int32)
        finishing: List[Optional[Sequence]] = [None] * b
        if self._chunk is not None:
            planned = self._chunk.plan(work, c, b)
        else:
            planned = [(s, min(s.prompt_len - s.prefill_pos, c))
                       for s in work]
        self._c_prefill_tokens.inc(sum(t for _, t in planned))
        for i, (seq, take) in enumerate(planned):
            self._tenant(seq.req)["prefill"].inc(take)
            self.spans.instant("prefill_chunk", uid=seq.req.uid,
                               tokens=take)
            start = seq.prefill_pos
            tr = seq.req.trace
            if tr is not None:
                # first chunk stamps "prefill" whether it starts at 0 or
                # at a prefix-cache match boundary; continuations under a
                # chunk policy stamp "chunked_prefill"
                if tr.count("prefill") == 0:
                    tr.stamp("prefill")
                elif self._chunk is not None:
                    tr.stamp("chunked_prefill")
            if self.prefix is not None:
                # host invariant: prefill writes only land in pages this
                # request exclusively owns (shared prefixes are read-only)
                cow.assert_writable(self.sched.alloc, seq.table.pages,
                                    start, take, sc.page_size)
            chunk = np.asarray(seq.req.prompt[start:start + take], np.int32)
            n = len(chunk)
            tokens[i, :n] = chunk
            # true absolute positions (rope); the invalid tail rows are
            # masked by q_valid, and page lookups clamp harmlessly
            pos[i] = start + np.arange(c)
            qv[i, :n] = True
            tables[i] = seq.table.padded(m)
            slots[i] = seq.slot or 0
            seq.prefill_pos += n
            seq.table.length = seq.prefill_pos
            if seq.prefill_done:
                finishing[i] = seq
                last_row[i] = n - 1
        es = (self._embed_seeds([s for s, _ in planned], b)
              if self._seeded_srf else None)
        logits, self.pools = self._run_step(tokens, pos, qv, tables, slots,
                                            es)
        rows = jnp.take_along_axis(
            logits[:, :, : self.cfg.vocab],
            jnp.asarray(last_row)[:, None, None], axis=1)[:, 0]
        toks = self._sample_rows(rows, [s or work[0] for s in finishing], b)
        now = time.perf_counter()
        for i, seq in enumerate(finishing):
            if seq is None:
                continue
            if self.prefix is not None:
                # cache the fully prefilled prompt BEFORE any finish path
                # frees its pages — the cache's references keep them alive
                self._prefix_insert(seq)
            tok = int(toks[i])
            seq.req.out_tokens.append(tok)
            seq.req.t_first = now
            if seq.req.trace is not None:
                seq.req.trace.stamp("first_token", now)
            self._c_tokens.inc()
            self._tenant(seq.req)["decode"].inc()
            # the first token can already satisfy eos/max_new — finishing
            # here keeps max_new=1 at exactly one emitted token and frees
            # the pages/slot a step earlier (previously such a request
            # took one extra decode step and emitted max_new+1 tokens)
            if tok == seq.req.eos_id or \
                    len(seq.req.out_tokens) >= seq.req.max_new:
                self._finish(seq, now)
        self._flush_cache_copies()
        self._c_prefill_steps.inc()
        stok.args["rows"] = len(planned)
        self.spans.end(stok)

    def _prefix_insert(self, seq: Sequence) -> None:
        """Donate a fully prefilled prompt to the prefix cache. Slot-
        bearing plans attach the donor's constant-state snapshot (taken
        async NOW, before any decode step mutates the slot) so a later
        hit can resume the SSM exactly at the prompt boundary.

        An unaligned prompt's tail page would become shared the moment
        it is cached — and the donor's very next decode write would have
        to COW-fork it, a whole-pool copy landing in a decode token gap
        (measurably inflating TPOT p95 at high hit rates). So the CACHE
        takes a private copy of the tail page instead: the copy batches
        into this prefill-completion step (which already pauses decode)
        and the donor keeps exclusive ownership of its own tail. Under
        pool exhaustion the copy page may be unavailable; then the tail
        is shared as-is and the scheduler's decode-fork site covers the
        donor's next write."""
        payload, ptoks = None, 0
        if self.plan.slot_families and seq.slot is not None:
            payload = paged_cache.snapshot_page_rows_async(
                self.pools, [], [seq.slot])
            self._pending_snaps.append(payload)
            ptoks = seq.prompt_len
        pages = list(seq.table.pages)
        tail_src, cp = None, None
        if seq.prompt_len % self.sched_cfg.page_size:
            got = self.sched.alloc.alloc(1)
            if got is not None:
                tail_src, cp = pages[-1], got[0]
                pages[-1] = cp
        newly = self.prefix.insert(seq.ns, seq.req.prompt, pages, payload,
                                   payload_tokens=ptoks)
        if cp is not None:
            if cp in newly:
                # our alloc ref on cp is held until the flush so the
                # page cannot be recycled into another copy's dst first
                self._cache_copies.append((tail_src, cp))
            else:                       # tail node existed: copy unused
                self.sched.alloc.free([cp])
                self.sched._sync_gauges()

    def _flush_cache_copies(self) -> None:
        """One batched device copy for every tail page the cache
        adopted this step (see ``_prefix_insert``), then drop the
        engine's transient allocation refs (the cache's remain)."""
        if not self._cache_copies:
            return
        self.pools = paged_cache.copy_page_rows(
            self.pools, [s for s, _ in self._cache_copies],
            [d for _, d in self._cache_copies])
        self._c_cow_forks.inc(len(self._cache_copies))
        self.spans.instant("cache_tail_copy", pages=len(self._cache_copies))
        self.sched.alloc.free([d for _, d in self._cache_copies])
        self._cache_copies.clear()
        self.sched._sync_gauges()

    # -- completion ----------------------------------------------------------

    def _finish(self, seq: Sequence, now: float) -> None:
        """Mark one sequence done (from prefill or decode): latency
        histograms from its trace, pages/slot back to the scheduler."""
        req = seq.req
        req.done = True
        req.finish_reason = ("eos" if req.out_tokens
                             and req.out_tokens[-1] == req.eos_id
                             else "length")
        req.t_done = now
        tr = req.trace
        if tr is not None:
            tr.stamp("done", now)
            q, ttft, e2e = tr.queue_time, tr.ttft, tr.e2e
            tpot = tr.tpot(len(req.out_tokens))
        else:                             # externally built request
            q, ttft = 0.0, req.t_first - req.t_submit
            e2e, tpot = now - req.t_submit, None
        if q is not None:
            self._h_queue.observe(q)
        if ttft is not None:
            self._h_ttft.observe(ttft)
        if e2e is not None:
            self._h_e2e.observe(e2e)
        if tpot is not None:
            self._h_tpot.observe(tpot)
        self._c_requests.inc()
        self._tenant(req)["requests"].inc()
        self.metrics.event("done", uid=req.uid, engine=self.engine_id,
                           tokens=len(req.out_tokens))
        self.sched.finished(seq)

    # -- decode -------------------------------------------------------------

    def _evict(self, victim: Sequence) -> None:
        if victim.fork is not None:
            # a decode fork planned earlier in this same grow loop: its
            # table already points at the (not-yet-copied) destination, so
            # the copy must land before the snapshot reads it
            self._apply_forks([victim])
        snap = paged_cache.snapshot_page_rows_async(
            self.pools, victim.table.pages, self._slot_ids(victim))
        self._pending_snaps.append(snap)
        self.sched.evicted(victim, snap)
        self.spans.instant("preempt", uid=victim.req.uid)
        if victim.req.trace is not None:
            victim.req.trace.stamp("preempted")
        self.metrics.event("preempted", uid=victim.req.uid,
                           engine=self.engine_id)
        self._c_preemptions.inc()

    def _decode_step(self, ready: List[Sequence]) -> bool:
        stok = self.spans.begin("decode_step")
        try:
            return self._decode_once(ready, stok)
        finally:
            self.spans.end(stok)

    def _decode_once(self, ready: List[Sequence], stok) -> bool:
        sc = self.sched_cfg
        batch: List[Sequence] = []
        for seq in ready:
            if seq not in self.sched.running:
                continue                       # evicted below us this step
            ok, victim = self.sched.grow_for_decode(seq)
            while not ok and victim is not None:
                self._evict(victim)
                batch = [s for s in batch if s is not victim]
                ok, victim = self.sched.grow_for_decode(seq)
            if ok:
                batch.append(seq)
        if not batch:
            return False
        self._apply_forks(batch)         # COW: diverging writes into
        #                                  shared pages fork first
        b, m = sc.max_batch, sc.table_width
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b, 1), np.int32)
        qv = np.zeros((b, 1), bool)
        tables = np.zeros((b, m), np.int32)
        slots = np.zeros((b,), np.int32)
        for i, seq in enumerate(batch):
            if self.prefix is not None:
                cow.assert_writable(self.sched.alloc, seq.table.pages,
                                    seq.table.length, 1, sc.page_size)
            tokens[i, 0] = seq.req.out_tokens[-1]
            pos[i, 0] = seq.table.length
            qv[i, 0] = True
            tables[i] = seq.table.padded(m)
            slots[i] = seq.slot or 0
        es = self._embed_seeds(batch, b) if self._seeded_srf else None
        logits, self.pools = self._run_step(tokens, pos, qv, tables, slots,
                                            es)
        toks = self._sample_rows(logits[:, 0, : self.cfg.vocab], batch, b)
        now = time.perf_counter()
        for i, seq in enumerate(batch):
            seq.table.length += 1
            tok = int(toks[i])
            seq.req.out_tokens.append(tok)
            if seq.req.trace is not None and \
                    seq.req.trace.count("decode") == 0:
                seq.req.trace.stamp("decode", now)
            self._c_tokens.inc()
            self._tenant(seq.req)["decode"].inc()
            if tok == seq.req.eos_id or \
                    len(seq.req.out_tokens) >= seq.req.max_new:
                self._finish(seq, now)
        self._c_decode_steps.inc()
        stok.args["rows"] = len(batch)
        self._maybe_sample_quality()
        return True

    def defrag(self) -> None:
        """Compact live pages to the low pool indices. Paging never needs
        this for correctness (any free page serves any request); it is an
        idle-time locality optimization, so it is NOT run on the decode
        hot path."""
        moves = self.sched.defrag()
        self.pools = paged_cache.apply_moves(self.pools, moves)

    # -- introspection ------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return self.sched.alloc.free_pages

    @property
    def free_slots(self) -> int:
        return self.sched.free_slots

    @property
    def usable_pages(self) -> int:
        """Paged-domain pages available to requests (page 0 is null)."""
        return max(self.sched_cfg.num_pages - 1, 1)

    @property
    def usable_slots(self) -> int:
        """Slot-domain slots available to requests (slot 0 is null)."""
        return max(self.sched.num_slots - 1, 1)

    @property
    def free_fraction(self) -> float:
        """Fraction of the BINDING pool currently free (router pressure):
        the minimum over the domains this plan actually allocates from."""
        fr = []
        if self.plan.has_paged:
            fr.append(self.free_pages / self.usable_pages)
        if self.sched.slot_alloc is not None:
            fr.append(self.free_slots / self.usable_slots)
        return min(fr) if fr else 1.0

    def cache_report(self, max_len: Optional[int] = None) -> Dict[str, float]:
        ml = max_len or (self.sched_cfg.table_width * self.sched_cfg.page_size)
        return {"family": self.plan.name,
                "bytes_per_token_per_layer":
                    self.plan.bytes_per_token(self.cfg, ml, self.paged),
                "pool_bytes": paged_cache.pool_bytes(self.pools),
                "pool_bytes_per_device":
                    paged_cache.pool_bytes_per_device(self.pools),
                "free_pages": self.sched.alloc.free_pages,
                "free_slots": self.sched.free_slots}
