"""Post-SPMD HLO text analyzer: trip-count-aware FLOPs / HBM bytes /
collective bytes.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits every
instruction ONCE — a ``lax.scan`` over 40 layers reports one layer of
flops (verified empirically; see EXPERIMENTS.md §Dry-run). This module
re-walks ``compiled.as_text()`` (per-device local shapes after SPMD
partitioning), builds the computation call graph, reads while-loop trip
counts from XLA's ``backend_config known_trip_count`` (fallback: the
lax.scan condition constant), and scales costs by the product of
enclosing trips.

Cost model per instruction:
  dot               2 * prod(output_shape) * prod(lhs contracting dims)
  fusion            flops of dots inside + HBM bytes = sum(operand buffer
                    sizes) + output size (fusion operands ARE its HBM reads)
  dus/copy/...      operands + output bytes
  collectives       per-device bytes = max(operands, output); all-reduce
                    counted x2 (ring reduce-scatter + all-gather)

Approximations (documented in EXPERIMENTS.md): elementwise flops ignored
(dot-dominated), conditional branches all counted, unknown trips -> 1 and
flagged in ``unknown_trips``.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(s: str) -> List[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(s: str) -> int:
    n = 1
    for d in _first_shape_dims(s):
        n *= d
    return max(n, 1) if _SHAPE_RE.search(s) else 0


@dataclass
class Instr:
    name: str
    opcode: str
    out_shape: str
    operands: List[str]
    raw: str
    callees: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))?[\w\[\],\{\}\s]*?)\s*"
    r"([\w\-]+)\((.*)$")
_CALLEE_RE = re.compile(
    r"(?:to_apply|body|condition|branch_computations|calls)="
    r"\{?%?([\w\.\-,\s%]+?)\}?(?:,|$)")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],]+))")


def parse_hlo(text: str):
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, str] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        s = line.strip()
        if not s:
            continue
        hm = _HDR_RE.match(s)
        if hm and "=" not in s.split("(")[0]:
            cur = Computation(hm.group(2))
            comps[cur.name] = cur
            for pm in _PARAM_RE.finditer(hm.group(3)):
                shapes.setdefault(pm.group(1), pm.group(2))
            continue
        im = _INSTR_RE.match(line)
        if im and cur is not None:
            name, oshape, opcode, rest = im.groups()
            args = rest.split(")")[0] if ")" in rest else rest
            operands = _NAME_RE.findall(args)
            callees = []
            for cm in _CALLEE_RE.finditer(line):
                for nm in cm.group(1).split(","):
                    nm = nm.strip().lstrip("%")
                    if nm:
                        callees.append(nm)
            ins = Instr(name, opcode, oshape.strip(), operands, line, callees)
            cur.instrs.append(ins)
            shapes[name] = oshape.strip()
    return comps, shapes


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out = _elems(ins.out_shape)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    if not mc or not ins.operands:
        return 2.0 * out
    lhs_shape = shapes.get(ins.operands[0], "")
    dims = _first_shape_dims(lhs_shape)
    k = 1
    for ci in mc.group(1).split(","):
        if ci and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * out * k


_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SKIP_BYTES = ("parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call", "custom-call", "compare",
               "add", "subtract", "multiply", "select", "broadcast", "iota",
               "reshape", "convert")


def _while_trip(ins: Instr, comps) -> Optional[int]:
    m = _TRIP_RE.search(ins.raw)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
    if mc and mc.group(1) in comps:
        best = None
        for ci in comps[mc.group(1)].instrs:
            mm = re.search(r"constant\((\d+)\)", ci.raw)
            if mm:
                v = int(mm.group(1))
                best = v if best is None else max(best, v)
        return best
    return None


def analyze(text: str) -> Dict[str, float]:
    comps, shapes = parse_hlo(text)
    if not comps:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    called = set()
    for c in comps.values():
        for i in c.instrs:
            called.update(i.callees)
    roots = [n for n in comps if n not in called]
    entry = next((n for n in roots if "main" in n), roots[0] if roots else
                 next(iter(comps)))

    totals = defaultdict(float)
    coll = defaultdict(float)

    def _dus_update_bytes(cname: str, out_bytes: int) -> Optional[float]:
        """If the fused computation is an in-place cache update (contains a
        dynamic-update-slice producing the fusion's full output), the HBM
        cost is ~2x the update slice, not 2x the buffer."""
        if cname not in comps:
            return None
        for fi in comps[cname].instrs:
            if fi.opcode == "dynamic-update-slice" and \
                    _shape_bytes(fi.out_shape) == out_bytes and \
                    len(fi.operands) >= 2:
                upd = _shape_bytes(shapes.get(fi.operands[1], ""))
                if 0 < upd < out_bytes:
                    return 2.0 * upd
        return None

    def _sliced_param_bytes(cname: str) -> Dict[int, float]:
        """Fusion params consumed ONLY via dynamic-slice read just the
        slice, not the whole buffer (e.g. the per-layer weight slice of a
        scan's stacked params — charging the full stack per iteration
        overcounts weight traffic by n_layers). -> {param_index: bytes}."""
        out: Dict[int, float] = {}
        if cname not in comps:
            return out
        pname_to_idx: Dict[str, int] = {}
        uses: Dict[str, List[Instr]] = {}
        for fi in comps[cname].instrs:
            if fi.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", fi.raw)
                if m:
                    pname_to_idx[fi.name] = int(m.group(1))
            for o in fi.operands:
                uses.setdefault(o, []).append(fi)
        for pname, idx in pname_to_idx.items():
            us = uses.get(pname, [])
            if us and all(u.opcode == "dynamic-slice" for u in us):
                out[idx] = sum(_shape_bytes(u.out_shape) for u in us)
        return out

    def op_bytes(ins: Instr) -> float:
        # In-place slice updates touch only the slice, not the buffer.
        if ins.opcode == "dynamic-update-slice" and len(ins.operands) >= 2:
            return 2.0 * _shape_bytes(shapes.get(ins.operands[1], ""))
        if ins.opcode == "dynamic-slice":
            return 2.0 * _shape_bytes(ins.out_shape)
        if ins.opcode == "fusion":
            ob_out = _shape_bytes(ins.out_shape)
            adj = None
            sliced: Dict[int, float] = {}
            for c in ins.callees:
                a = _dus_update_bytes(c, ob_out)
                adj = a if a is not None else adj
                sliced.update(_sliced_param_bytes(c))
            ob = 0.0
            for i, o in enumerate(ins.operands):
                ob += sliced.get(i, _shape_bytes(shapes.get(o, "")))
            if adj is not None:
                big = max((_shape_bytes(shapes.get(o, ""))
                           for o in ins.operands), default=0)
                return adj + (ob - big if ob > big else 0.0)
            return ob + ob_out
        ob = sum(_shape_bytes(shapes.get(o, "")) for o in ins.operands)
        return ob + _shape_bytes(ins.out_shape)

    def fusion_flops(cname: str) -> float:
        f = 0.0
        if cname in comps:
            for fi in comps[cname].instrs:
                if fi.opcode == "dot":
                    f += _dot_flops(fi, shapes)
                elif fi.opcode == "convolution":
                    f += 2.0 * _elems(fi.out_shape)
        return f

    stack = set()

    def walk(name: str, mult: float):
        if name not in comps or name in stack:
            return
        stack.add(name)
        for ins in comps[name].instrs:
            op = ins.opcode
            if op == "while":
                trip = _while_trip(ins, comps)
                if trip is None:
                    trip = 1
                    totals["unknown_trips"] += 1
                mb = re.search(r"body=%?([\w\.\-]+)", ins.raw)
                if mb:
                    walk(mb.group(1), mult * trip)
                continue
            if op in ("conditional", "call"):
                for c in ins.callees:
                    walk(c, mult)
                continue
            if op == "dot":
                totals["flops"] += mult * _dot_flops(ins, shapes)
                totals["bytes"] += mult * op_bytes(ins)
                continue
            if op == "convolution":
                totals["flops"] += mult * 2.0 * _elems(ins.out_shape)
                totals["bytes"] += mult * op_bytes(ins)
                continue
            if op == "fusion":
                for c in ins.callees:
                    totals["flops"] += mult * fusion_flops(c)
                totals["bytes"] += mult * op_bytes(ins)
                continue
            if op in _COLL_OPS:
                b = max(sum(_shape_bytes(shapes.get(o, ""))
                            for o in ins.operands),
                        _shape_bytes(ins.out_shape))
                factor = 2.0 if op == "all-reduce" else 1.0
                coll[op] += mult * b * factor
                totals["collective_bytes"] += mult * b * factor
                totals["collective_count"] += mult
                continue
            if op not in _SKIP_BYTES:
                totals["bytes"] += mult * op_bytes(ins)
        stack.discard(name)

    walk(entry, 1.0)
    out = dict(totals)
    for k, v in coll.items():
        out[f"coll/{k}"] = v
    out.setdefault("flops", 0.0)
    out.setdefault("bytes", 0.0)
    out.setdefault("collective_bytes", 0.0)
    return out


# --- roofline ----------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 / chip (v5e)
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link


def roofline_terms(per_device: Dict[str, float]) -> Dict[str, float]:
    """Inputs are PER-DEVICE (post-SPMD HLO) — terms are wall-seconds."""
    t_comp = per_device["flops"] / PEAK_FLOPS
    t_mem = per_device["bytes"] / HBM_BW
    t_coll = per_device["collective_bytes"] / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    return {"t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "t_roofline": dom[1], "bottleneck": dom[0]}
