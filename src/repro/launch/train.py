"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ck [--compress-dp]

Full-size configs target the production mesh (launch/mesh.py) on real
fleets; on this CPU container use --reduced for runnable examples/tests.
Resumes automatically from the latest committed checkpoint in --ckpt-dir.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs import registry
from repro.launch.steps import TrainHyper
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--attn", default=None, choices=[None, "full", "srf"])
    ap.add_argument("--compress-dp", action="store_true",
                    help="structured-JL compressed cross-pod gradients")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    overrides = {}
    if args.attn:
        overrides["attn_impl"] = args.attn
    cfg = (registry.reduced if args.reduced else registry.get)(
        args.arch, **overrides)
    tcfg = TrainerConfig(
        num_steps=args.steps, batch=args.batch, seq=args.seq, seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        hyper=TrainHyper(lr=args.lr, warmup=min(50, args.steps // 5 + 1),
                         total_steps=args.steps),
        compress_dp=args.compress_dp)
    trainer = Trainer(cfg, tcfg)
    resumed = trainer.try_resume()
    print(f"arch={args.arch} params={cfg.param_count():,} resumed={resumed} "
          f"start_step={trainer.step}")
    out = trainer.train()
    for rec in out["log"]:
        print(json.dumps(rec))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(out, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
