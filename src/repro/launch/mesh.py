"""Production meshes. Importing this module never touches jax device state;
``make_production_mesh`` is a function (per spec)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data', 'model') = 256 chips (v5e pod).
    Multi-pod:  (2, 16, 16) ('pod', 'data', 'model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic reshapes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def describe(mesh) -> str:
    return " x ".join(f"{n}={s}" for n, s in
                      zip(mesh.axis_names, mesh.devices.shape))
