"""Production meshes. Importing this module never touches jax device state;
``make_production_mesh`` is a function (per spec)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data', 'model') = 256 chips (v5e pod).
    Multi-pod:  (2, 16, 16) ('pod', 'data', 'model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic reshapes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_serving_meshes(replicas: int, model_parallel: int = 1,
                        devices=None):
    """Partition the device set into per-replica ('data', 'model') meshes
    for the mesh-serving router: ``replicas`` engine replicas, each a
    ``model_parallel``-wide tensor-parallel slice (data axis is 1 — the
    router, not a batch axis, spreads requests over replicas).

    On a real deployment each slice is one host's chips; in tests the
    forced host platform supplies the devices. Raises when the device
    set cannot cover ``replicas * model_parallel``.
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = list(devices if devices is not None else jax.devices())
    need = replicas * model_parallel
    if len(devs) < need:
        raise ValueError(f"need {need} devices for {replicas} replicas x "
                         f"model={model_parallel}, have {len(devs)}")
    return [Mesh(np.array(devs[i * model_parallel:(i + 1) * model_parallel]
                          ).reshape(1, model_parallel), ("data", "model"))
            for i in range(replicas)]


def describe(mesh) -> str:
    return " x ".join(f"{n}={s}" for n, s in
                      zip(mesh.axis_names, mesh.devices.shape))
