"""Launch layer: meshes, step factories, dry-run, train/serve CLIs."""
