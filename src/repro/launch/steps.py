"""Step-function factories shared by the trainer, the server and the
multi-pod dry-run. Pure functions of (params, state, batch) — jit/sharding
is applied by the caller.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as model
from repro.optim import adamw, schedule


def _prewarm_srf_spinner(cfg) -> None:
    """Populate the fused-spinner block-size plan cache for every block of
    the SRF feature pipeline this config will serve. The sweep itself is
    cheap (a pure-Python candidate scan); the point is to pin the plan at
    factory time so every step dispatch sees a warm, deterministic cache
    and the chosen blocks are inspectable before the first request."""
    if getattr(cfg, "attn_impl", None) != "srf":
        return
    import jax.numpy as _jnp
    from repro.core import spinner
    from repro.kernels import ops as kops
    from repro.models.attention import srf_cfg
    sc = srf_cfg(cfg)
    pipe = sc.pipeline
    dtype = _jnp.dtype(getattr(cfg, "dtype", "float32"))
    # softmax_pos: keys use the fused 'exp' epilogue; the stabilized query
    # path projects with 'identity' (overflow-safe shift applied outside).
    # Nonlinearities with needs_input (exp's subtrahend is the pipeline
    # input norm) fuse in-kernel only at depth 1 — same rule as
    # SpinnerPipeline.apply — so deeper pipelines warm 'identity' instead.
    last = {"softmax_pos": ("exp", "identity"), "trig": ("cos_sin",),
            "relu": ("relu",)}[sc.feature]
    if pipe.depth > 1:
        last = tuple(dict.fromkeys(
            "identity" if spinner.nonlinearity(e).needs_input else e
            for e in last))
    for i, blk in enumerate(pipe.blocks):
        epis = last if i == pipe.depth - 1 else ("identity",)
        for epi in epis:
            kops.spinner_plan(blk.kind, blk.n, blk.m, use_hd=blk.use_hd,
                              epilogue=epi, dtype=dtype, seeded=blk.seeded)


@dataclass(frozen=True)
class TrainHyper:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    adam: adamw.AdamWConfig = adamw.AdamWConfig()
    aux_weight: float = 0.01


def make_train_step(cfg, hyper: TrainHyper = TrainHyper(),
                    grad_shardings=None):
    """``grad_shardings``: optional NamedSharding tree = the ZeRO-1 moment
    shardings. Constraining the bf16 grads to it BEFORE the optimizer's
    f32 upcast makes XLA reduce-scatter bf16 gradients to the moment
    shards instead of all-gathering f32 ones (2x collective bytes on the
    MoE cells, measured — EXPERIMENTS.md §Perf-hillclimb A4)."""
    def train_step(params, opt_state, step_idx, batch):
        def loss(p):
            return model.loss_fn(p, cfg, batch, hyper.aux_weight)
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        lr = schedule.warmup_cosine(step_idx, hyper.lr, hyper.warmup,
                                    hyper.total_steps)
        params, opt_state, stats = adamw.update(grads, opt_state, params,
                                                lr, hyper.adam)
        out = {"loss": l, "lr": lr, **metrics, **stats}
        return params, opt_state, out
    return train_step


def make_grad_step(cfg, aux_weight: float = 0.01):
    """Gradients only (used by the compressed-DP trainer, which applies the
    optimizer after the explicit cross-pod reduction)."""
    def grad_step(params, batch):
        def loss(p):
            return model.loss_fn(p, cfg, batch, aux_weight)
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        return grads, {"loss": l, **metrics}
    return grad_step


def make_prefill_step(cfg):
    _prewarm_srf_spinner(cfg)
    def prefill_step(params, batch, cache):
        return model.prefill(params, cfg, batch, cache)
    return prefill_step


def make_encode_step(cfg):
    """Enc-dec encoder pass: (params, enc_emb (B, E, feat)) -> memory
    (B, E, d_model). The paged engine runs this once per request at
    admission (batch 1 — bit-identical to the legacy per-slot prefill)
    and caches the result in the read-only encoder-memory pool."""
    def encode_step(params, enc_emb):
        return model.encode_memory(params, cfg, enc_emb)
    return encode_step


def make_paged_step(cfg, mesh=None, paged=None, params_sds=None):
    """Batched paged serving step (decode: C = 1; chunked prefill: C = chunk).

    (params, pools, tokens (B, C), positions (B, C), q_valid (B, C),
    tables (B, M), slots (B,)) -> (logits (B, C, V_padded), pools').
    ``pools`` is the full container from ``serving.paged_cache``
    (paged-domain pages + constant-state slots + optional enc-dec
    memory); ``slots`` indexes the slot-domain pools and the memory pool
    (0 = null slot for padded rows) and threads the per-request encoder
    memory through to the cross-attending decoder layers. One jit cache
    entry per (B, C) shape — the engine keeps those fixed. With SRF
    attention the phi(q)/phi(k) feature maps inside run as single fused
    spinner passes; the factory pre-warms their block-size plan.

    ``mesh``: mesh-sharded serving. When the family's head dims divide
    the mesh's model axis (``serving.mesh.shard.paged_tp``), the step is
    wrapped in a manual shard_map: q/k/v projections arrive column-
    parallel sliced, pools arrive as the local head block, the body runs
    ``model.paged_step`` under the shard-local config, and attention
    stitches the per-shard head outputs with a model-axis all-gather
    (``distributed.collectives.stitch_heads``) before contracting the
    deliberately REPLICATED wo — that keeps the d_model reduction in
    single-host order, so greedy tokens are bit-identical to the
    unsharded engine (a row-parallel wo + psum re-associates the sum).
    The paged-gather kernel then runs per-shard on the local pool slice.
    Families that degrade to replication (mla / ssd / indivisible heads)
    fall back to the plain body — identical work on every device, pools
    replicated.

    ``paged`` (``serving.paged_cache.PagedConfig``) only changes the
    pool *structure* the specs are derived from (int8 scale leaves);
    ``params_sds`` (any tree of arrays or ShapeDtypeStructs, e.g. the
    engine's real params) supplies the parameter shapes the in_specs are
    derived from, avoiding an abstract re-trace of ``model.init``.

    Seeded-SRF configs (``cfg.srf.seeded``) get an EIGHTH positional
    argument ``embed_seeds (B,) uint32`` — per-request projection seeds
    (0 = base projection); non-seeded configs keep the 7-arg signature
    so existing call sites and jit caches are untouched.
    """
    _prewarm_srf_spinner(cfg)
    seeded_srf = (getattr(cfg, "attn_impl", None) == "srf"
                  and getattr(cfg.srf, "seeded", False))

    if seeded_srf:
        def paged_step(params, pools, tokens, positions, q_valid, tables,
                       slots, embed_seeds):
            return model.paged_step(params, cfg, pools, tokens, positions,
                                    q_valid, tables, slots,
                                    embed_seeds=embed_seeds)
    else:
        def paged_step(params, pools, tokens, positions, q_valid, tables,
                       slots):
            return model.paged_step(params, cfg, pools, tokens, positions,
                                    q_valid, tables, slots)

    if mesh is None:
        return paged_step
    from jax.sharding import PartitionSpec as P
    from repro.distributed import collectives
    from repro.serving import paged_cache
    from repro.serving.mesh import shard as mesh_shard

    tp = mesh_shard.paged_tp(cfg, mesh)
    if tp <= 1:
        return paged_step               # replication degradation: plain body

    cfg_local = mesh_shard.local_cfg(cfg, tp)
    if params_sds is None:
        params_sds = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
    pspecs = mesh_shard.serving_param_specs(params_sds, cfg, mesh)
    poolspecs = mesh_shard.pool_specs(cfg, mesh, paged)
    rep = P()

    if seeded_srf:
        def body(params, pools, tokens, positions, q_valid, tables, slots,
                 embed_seeds):
            return model.paged_step(params, cfg_local, pools, tokens,
                                    positions, q_valid, tables, slots,
                                    tp_axis="model",
                                    embed_seeds=embed_seeds)
        in_specs = (pspecs, poolspecs, rep, rep, rep, rep, rep, rep)
    else:
        def body(params, pools, tokens, positions, q_valid, tables, slots):
            return model.paged_step(params, cfg_local, pools, tokens,
                                    positions, q_valid, tables, slots,
                                    tp_axis="model")
        in_specs = (pspecs, poolspecs, rep, rep, rep, rep, rep)

    return collectives.axis_shard_map(
        body, mesh,
        in_specs=in_specs,
        out_specs=(rep, poolspecs),
        axes=set(mesh.axis_names))


def make_serve_step(cfg, greedy: bool = True, temperature: float = 1.0):
    """One decode step: (params, cache, tokens(B,1)) -> (next(B,1), cache)."""
    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cfg, cache, tokens)
        logits = logits[:, -1, : cfg.vocab]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step
