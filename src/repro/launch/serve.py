"""Serving launcher: paged continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 16 --prompt-len 16 --max-new 24 [--attn srf] \
        [--policy priority] [--temperature 0.8 --top-k 40] [--legacy]

``--attn srf`` serves with the paper's SRF attention: the per-request
cache is one constant-size O(m d) state page instead of O(L) KV pages.
``--legacy`` runs the old per-slot lock-step engine for comparison.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer as model_lib
from repro.serving import Engine, Request
from repro.serving import legacy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--attn", default=None, choices=[None, "full", "srf"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--policy", default="fcfs", choices=["fcfs", "priority"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--legacy", action="store_true",
                    help="old per-slot engine (baseline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    overrides = {"attn_impl": args.attn} if args.attn else {}
    cfg = registry.reduced(args.arch, **overrides)
    params = model_lib.init(jax.random.PRNGKey(args.seed), cfg)
    if args.legacy:
        eng = legacy.Engine(cfg, params, batch_slots=args.slots,
                            max_len=args.max_len)
    else:
        eng = Engine(cfg, params, batch_slots=args.slots,
                     max_len=args.max_len, policy=args.policy,
                     seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              args.prompt_len).astype(np.int32)
        eng.submit(Request(uid=i, prompt=prompt, max_new=args.max_new,
                           priority=int(rng.integers(0, 3)),
                           temperature=args.temperature,
                           top_k=args.top_k, top_p=args.top_p))
    done = eng.run()
    dt = time.time() - t0
    tok = sum(len(r.out_tokens) for r in done)
    engine = "legacy" if args.legacy else "paged"
    print(f"arch={args.arch} attn={cfg.attn_impl} engine={engine} "
          f"requests={len(done)} tokens={tok} wall={dt:.2f}s "
          f"tok/s={tok/dt:.1f}")
    if not args.legacy:
        print(f"  sched: {eng.sched.stats}  report: {eng.cache_report()}")
    for r in done[:3]:
        print(f"  req{r.uid}: ttft={r.t_first - r.t_submit:.3f}s "
              f"out={r.out_tokens[:8]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
