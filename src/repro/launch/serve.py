"""Serving launcher: paged continuous-batching engine, optionally
mesh-sharded and router-replicated.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 16 --prompt-len 16 --max-new 24 [--attn srf] \
        [--policy priority] [--temperature 0.8 --top-k 40] [--legacy] \
        [--replicas 2] [--model-parallel 2] [--quantize-kv]

Every registry family serves through the paged engine — dense/moe/mla,
ssm (constant-state slots), hybrid (kv pages + ssd slots), enc-dec
(synthetic frontend features are generated per request and encoded once
at admission) and the vlm/audio frontend archs.

``--attn srf`` serves with the paper's SRF attention: the per-request
cache is one constant-size O(m d) state page instead of O(L) KV pages.
``--legacy`` runs the old per-slot lock-step engine (the test oracle)
for comparison.
``--replicas``/``--model-parallel`` route requests across engine
replicas whose page pools are model-axis sharded (``serving/mesh``);
``--quantize-kv`` stores KV pages as int8 with per-page-row scales.
``--prefix-cache`` arms the prefix-sharing subsystem (radix cache +
copy-on-write paged KV, ``serving/prefix``); ``--cache-bytes`` bounds
its footprint and ``--chunk-tokens`` budgets chunked prefill so long
cold prompts interleave with decode. ``--shared-prefix N`` makes the
synthetic prompts share their first N tokens, so hit rates are visible.
``--ft`` arms the fault-tolerant router (replica watchdog + failover
with request rescue, ``serving/ft.py``), ``--deadline S`` gives every
request an S-second deadline (overdue waiting requests finish as
``timeout``), and ``--chaos KIND@STEP[:REPLICA]`` injects a scripted
fault through the TEST-ONLY harness (``serving/chaos.py``) to
demonstrate the recovery path end to end.

Telemetry: every engine replica and the router share ONE
``obs.MetricsRegistry``; ``--metrics`` prints a live one-line report
every ``--metrics-every`` seconds plus a final latency-percentile dump,
``--metrics-out FILE`` additionally writes the Prometheus text
exposition (+ ``FILE.events.jsonl``), and ``--kernel-timing`` records
per-dispatch kernel wall times (eager dispatches only; serializing, so
off by default). All output routes through ``obs.report.Reporter`` —
this module is lint-pinned print-free (``tests/test_obs.py``).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import obs
from repro.configs import registry
from repro.launch import mesh as mesh_lib
from repro.models import transformer as model_lib
from repro.obs import export as trace_export
from repro.obs import quality as quality_lib
from repro.obs import spans as spans_lib
from repro.obs.report import Reporter
from repro.serving import Engine, PagedConfig, Request, Router


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--attn", default=None, choices=[None, "full", "srf"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--policy", default="fcfs", choices=["fcfs", "priority"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--legacy", action="store_true",
                    help="old per-slot engine (baseline)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="router-managed engine replicas")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model-axis TP width per replica (shards pools)")
    ap.add_argument("--quantize-kv", action="store_true",
                    help="int8 KV pages + per-page-row scales (kv family)")
    ap.add_argument("--ft", action="store_true",
                    help="fault-tolerant router: replica health watchdog "
                         "+ failover with request rescue (multi-replica)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds; overdue waiting "
                         "requests finish with reason 'timeout'")
    ap.add_argument("--chaos", default=None, metavar="KIND@STEP[:REPLICA]",
                    help="TEST-ONLY fault injection (kinds: raise|hang|"
                         "reject|oom), e.g. raise@6:1; needs --ft and "
                         "--replicas >= 2 to demonstrate recovery")
    ap.add_argument("--metrics", action="store_true",
                    help="periodic one-line metrics report + final "
                         "latency-percentile dump from the shared registry")
    ap.add_argument("--metrics-every", type=float, default=2.0,
                    help="seconds between periodic metrics lines")
    ap.add_argument("--metrics-out", default=None,
                    help="write Prometheus text exposition here "
                         "(+ .events.jsonl) at exit")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache: requests sharing a cached "
                         "prompt prefix reuse its KV pages (COW) instead "
                         "of re-prefilling (serving/prefix)")
    ap.add_argument("--cache-bytes", type=int, default=0,
                    help="prefix-cache byte budget (0 = unbounded; LRU "
                         "eviction above the budget)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="chunked-prefill token budget per step (0 = full "
                         "jit budget); long cold prompts admit in chunks "
                         "interleaved with decode")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="synthetic prompts share their first N tokens "
                         "(workload shaping for --prefix-cache demos)")
    ap.add_argument("--kernel-timing", action="store_true",
                    help="record per-dispatch kernel wall times (eager "
                         "dispatches only; serializes the device pipeline)")
    ap.add_argument("--quality-every", type=int, default=64,
                    help="decode steps between SRF row-gaussianity quality "
                         "probes (srf_row_* gauges; 0 disables)")
    ap.add_argument("--quality-tol", type=float,
                    default=quality_lib.DRIFT_TOL,
                    help="row-moment drift tolerance; past it the engine "
                         "emits a quality_drift registry event")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record span timelines on every replica and the "
                         "router, write a merged Chrome-trace JSON here "
                         "at exit (load in Perfetto / chrome://tracing)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rep = Reporter()
    metrics = obs.MetricsRegistry()
    if args.kernel_timing:
        obs.enable_kernel_timing(metrics)
    tracing = args.trace_out is not None and not args.legacy
    recorders = [spans_lib.SpanRecorder(replica=i)
                 for i in range(max(args.replicas, 1))] if tracing else []

    def _spans(i):
        return recorders[i] if tracing else None
    overrides = {"attn_impl": args.attn} if args.attn else {}
    cfg = registry.reduced(args.arch, **overrides)
    params = model_lib.init(jax.random.PRNGKey(args.seed), cfg)
    paged = PagedConfig(quantize_kv=args.quantize_kv)
    prefix = None
    if args.prefix_cache or args.cache_bytes or args.chunk_tokens:
        from repro.serving import ChunkConfig, PrefixConfig
        prefix = PrefixConfig(
            cache_bytes=args.cache_bytes,
            chunk=ChunkConfig(chunk_tokens=args.chunk_tokens))
    if args.legacy:
        from repro.serving import legacy
        eng = legacy.Engine(cfg, params, batch_slots=args.slots,
                            max_len=args.max_len)
    elif args.replicas > 1 or args.model_parallel > 1:
        from repro.serving import FTConfig
        meshes = mesh_lib.make_serving_meshes(args.replicas,
                                              args.model_parallel)
        engines = [Engine(cfg, params, batch_slots=args.slots,
                          max_len=args.max_len, policy=args.policy,
                          seed=args.seed + i, mesh=m, paged=paged,
                          metrics=metrics, prefix=prefix,
                          quality_every=args.quality_every,
                          quality_tol=args.quality_tol, spans=_spans(i))
                   for i, m in enumerate(meshes)]
        if args.chaos:
            from repro.serving.chaos import ChaosEngine, ChaosPlan
            spec, _, rep_s = args.chaos.partition(":")
            kind, _, step_s = spec.partition("@")
            rep_i = int(rep_s or (len(engines) - 1))
            engines[rep_i] = ChaosEngine(
                engines[rep_i], ChaosPlan(kind, at_step=int(step_s or 5)))
            rep.line(f"[chaos] replica {rep_i}: {kind}@{step_s or 5} "
                     "(test-only fault injection)")
        if tracing:
            # the router's own spans (scoring, quarantine/rescue/replay)
            # merge as one extra timeline row past the replica rows
            recorders.append(spans_lib.SpanRecorder(replica=len(engines)))
        eng = Router(engines, metrics=metrics,
                     ft=FTConfig() if args.ft else None,
                     spans=recorders[-1] if tracing else None)
    else:
        eng = Engine(cfg, params, batch_slots=args.slots,
                     max_len=args.max_len, policy=args.policy,
                     seed=args.seed, paged=paged, metrics=metrics,
                     prefix=prefix, quality_every=args.quality_every,
                     quality_tol=args.quality_tol, spans=_spans(0))
    rng = np.random.default_rng(args.seed)
    common = rng.integers(0, cfg.vocab, max(args.shared_prefix, 0)
                          ).astype(np.int32)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              args.prompt_len).astype(np.int32)
        if len(common):
            prompt = np.concatenate([common, prompt[len(common):]]) \
                if args.prompt_len > len(common) else common.copy()
        enc = None
        if cfg.is_encdec:
            from repro.models import frontends
            enc = frontends.synthetic_audio_features(rng, cfg)
        eng.submit(Request(uid=i, prompt=prompt, max_new=args.max_new,
                           priority=int(rng.integers(0, 3)),
                           temperature=args.temperature,
                           top_k=args.top_k, top_p=args.top_p,
                           enc_emb=enc, deadline=args.deadline))
    on_step = (rep.periodic(metrics, every_s=args.metrics_every)
               if args.metrics and not args.legacy else None)
    done = (eng.run() if args.legacy else eng.run(on_step=on_step))
    dt = time.perf_counter() - t0
    tok = sum(len(r.out_tokens) for r in done)
    engine = ("legacy" if args.legacy else
              "router" if isinstance(eng, Router) else "paged")
    rep.line(f"arch={args.arch} attn={cfg.attn_impl} engine={engine} "
             f"requests={len(done)} tokens={tok} wall={dt:.2f}s "
             f"tok/s={tok/dt:.1f}")
    if isinstance(eng, Router):
        rep.line(f"  router: {eng.describe()}")
        rep.line(f"  replica0 report: {eng.engines[0].cache_report()}")
    elif not args.legacy:
        rep.line(f"  sched: {dict(eng.sched.stats)}  "
                 f"report: {eng.cache_report()}")
    if prefix is not None and not args.legacy:
        v = metrics.value_sum
        rep.line(f"  prefix: hits={int(v('prefix_hits_total'))} "
                 f"hit_tokens={int(v('prefix_hit_tokens_total'))} "
                 f"cow_forks={int(v('prefix_cow_forks_total'))} "
                 f"evictions={int(v('prefix_evictions_total'))} "
                 f"cache_bytes={int(v('prefix_cache_bytes'))}")
    for r in done[:3]:
        ttft = (f"{r.t_first - r.t_submit:.3f}s" if r.t_first
                else f"n/a ({r.finish_reason})")   # expired/shed: no token
        rep.line(f"  req{r.uid}: ttft={ttft} out={r.out_tokens[:8]}...")
    if args.metrics or args.metrics_out:
        rep.final(metrics, done, dump_path=args.metrics_out)
    if tracing:
        n = trace_export.dump_chrome_trace(args.trace_out, recorders)
        spans = sum(len(r) for r in recorders)
        dropped = sum(r.dropped for r in recorders)
        rep.line(f"[trace] {args.trace_out}: {n} events from {spans} "
                 f"spans across {len(recorders)} timelines"
                 + (f" ({dropped} dropped)" if dropped else ""))
    if args.kernel_timing and not metrics.snapshot()["histograms"].get(
            "kernel_dispatch_seconds"):
        rep.line("[metrics] kernel-timing: no eager dispatches recorded — "
                 "the serving loop runs under jit, where timed dispatches "
                 "are skipped by design; named_scope annotations still "
                 "land in profiler timelines. Sample "
                 "kernel_dispatch_seconds via direct ops calls or "
                 "benchmarks instead.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
