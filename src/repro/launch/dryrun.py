import os
import sys as _sys
# MUST precede any jax import/init: jax locks the device count on first use.
# Set here (and only here) so tests/benches still see 1 real device.
# REPRO_DRYRUN_DEVICES is the single programmatic override (set it before
# importing this module); without it, the CLI serve-mesh/serve-chaos paths
# force a realistic 8-device host instead of 512 to keep startup down. The
# smokes themselves only need 4 devices and are correct (just slower) under
# 512, and the grid cells are lower/compile-only, so a mesh wider than the
# forced count still partitions — the argv sniff is a speed knob, not
# semantics.
_FORCED = os.environ.get("REPRO_DRYRUN_DEVICES") or \
    ("8" if ("--serve-mesh" in _sys.argv or "--serve-chaos" in _sys.argv
             or "--serve-prefix" in _sys.argv or "--serve-seeded" in _sys.argv)
     else "512")
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_FORCED}"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract memory/cost/collective evidence for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
        --shape train_4k [--multi-pod] [--attn srf] [--remat dots]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl

Per cell this proves: the sharding config is coherent (SPMD partitioning
succeeds), the per-device footprint fits HBM (memory_analysis), and yields
the roofline terms (trip-count-aware HLO walk; see hlo_analysis.py).
"""
import argparse
import dataclasses
import importlib.util
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import registry, shapes as shp
from repro.obs.report import Reporter
from repro.distributed import sharding as S
from repro.launch import hlo_analysis as H
from repro.launch import mesh as M
from repro.launch import steps
from repro.models import hooks
from repro.models import transformer as T
from repro.optim import adamw

HBM_PER_CHIP = 16 * 1024 ** 3   # v5e


def check_bench(bench_dir: Optional[str] = None, reporter=None) -> int:
    """``--check-bench``: run the perf-regression gate
    (``benchmarks/regress.py``) over the committed ``BENCH_*.json``
    payloads vs ``BENCH_history.jsonl``. The benchmarks tree is not a
    package on ``PYTHONPATH=src``, so the module is loaded by file path;
    ``REPRO_BENCH_DIR`` overrides the default (cwd = repo root)."""
    rep = reporter or Reporter()
    bench_dir = bench_dir or os.environ.get("REPRO_BENCH_DIR", ".")
    mod_path = os.path.join(bench_dir, "benchmarks", "regress.py")
    if not os.path.exists(mod_path):
        mod_path = os.path.join(bench_dir, "regress.py")
    if not os.path.exists(mod_path):
        rep.line(f"[regress] no regress.py under {bench_dir}")
        return 1
    spec = importlib.util.spec_from_file_location("_bench_regress", mod_path)
    regress = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(regress)
    paths = regress.discover(bench_dir)
    history = os.path.join(bench_dir, regress.HISTORY)
    bad = regress.check_files(paths, history, reporter=rep)
    for msg in bad:
        rep.line(f"[regress] REGRESSION {msg}")
    rep.line(f"[regress] {'FAIL' if bad else 'PASS'}: {len(bad)} "
             f"violation(s) across {len(paths)} payload(s)")
    return 1 if bad else 0


def _mem_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    return {
        "arg_bytes": float(ma.argument_size_in_bytes),
        "out_bytes": float(ma.output_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "peak_bytes": float(ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            - ma.alias_size_in_bytes
                            + ma.temp_size_in_bytes),
    }


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             use_reduced: bool = False, overrides: Optional[Dict] = None,
             hlo_dir: Optional[str] = None) -> Dict:
    t0 = time.time()
    cfg, note = shp.cell_config(arch, shape, use_reduced, **(overrides or {}))
    ss = shp.SHAPES[shape]
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    hooks.set_constrainer(S.make_constrainer(mesh, cfg))
    rec: Dict = {
        "arch": arch, "shape": shape, "mesh": M.describe(mesh),
        "chips": chips, "step": ss.step, "attn_impl": cfg.attn_impl,
        "note": note, "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    try:
        params_sds = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
        pspecs = S.param_specs(params_sds, mesh)
        ins = shp.input_specs(cfg, shape)
        with mesh:
            if ss.step == "train":
                opt_sds = jax.eval_shape(lambda: adamw.init(params_sds))
                ospecs = S.opt_state_specs(opt_sds, params_sds, pspecs, mesh)
                bspecs = S.batch_specs_tree(ins["batch"], mesh)
                gshard = S.named(mesh, S.zero1_specs(params_sds, pspecs,
                                                     mesh))
                fn = steps.make_train_step(cfg, grad_shardings=gshard)
                jitted = jax.jit(
                    fn,
                    in_shardings=(S.named(mesh, pspecs), S.named(mesh, ospecs),
                                  None, S.named(mesh, bspecs)),
                    out_shardings=(S.named(mesh, pspecs),
                                   S.named(mesh, ospecs), None),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(params_sds, opt_sds,
                                       jax.ShapeDtypeStruct((), jnp.int32),
                                       ins["batch"])
            elif ss.step == "prefill":
                cspecs = S.cache_specs_tree(ins["cache"], cfg, mesh)
                bspecs = S.batch_specs_tree(ins["batch"], mesh)
                fn = steps.make_prefill_step(cfg)
                jitted = jax.jit(
                    fn,
                    in_shardings=(S.named(mesh, pspecs),
                                  S.named(mesh, bspecs),
                                  S.named(mesh, cspecs)),
                    out_shardings=(None, S.named(mesh, cspecs)),
                    donate_argnums=(2,))
                lowered = jitted.lower(params_sds, ins["batch"], ins["cache"])
            else:  # decode
                cspecs = S.cache_specs_tree(ins["cache"], cfg, mesh)
                tspec = S.batch_specs_tree({"t": ins["tokens"]}, mesh)["t"]
                fn = steps.make_serve_step(cfg)
                jitted = jax.jit(
                    fn,
                    in_shardings=(S.named(mesh, pspecs),
                                  S.named(mesh, cspecs),
                                  S.named(mesh, {"t": tspec})["t"]),
                    out_shardings=(None, None, S.named(mesh, cspecs)),
                    donate_argnums=(1,))
                lowered = jitted.lower(params_sds, ins["cache"],
                                       ins["tokens"])
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            rec.update(_mem_summary(compiled))
            ca = compiled.cost_analysis() or {}
            rec["xla_cost_flops_once"] = float(ca.get("flops", 0.0))
            hlo = compiled.as_text()
            if hlo_dir:
                os.makedirs(hlo_dir, exist_ok=True)
                tag = f"{arch}_{shape}_{'mp' if multi_pod else 'sp'}"
                with open(os.path.join(hlo_dir, tag + ".hlo"), "w") as f:
                    f.write(hlo)
            an = H.analyze(hlo)
            rec.update({f"hlo_{k.replace('/', '_')}": v for k, v in an.items()})
            rec.update(H.roofline_terms(an))
            rec["fits_hbm"] = bool(rec.get("peak_bytes", 0) < HBM_PER_CHIP)
            rec["lower_s"] = round(t1 - t0, 2)
            rec["compile_s"] = round(t2 - t1, 2)
            rec["ok"] = True
    except Exception as e:  # failures here are bugs in the system
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        hooks.reset()
    return rec


def pipeline_smoke() -> Dict:
    """``--pipeline``: spinner-pipeline serialization round-trip smoke.

    Builds a mixed-kind 3-block SpinnerPipeline, round-trips it through
    ``spinner.dumps``/``loads`` (the checkpointable config form), and
    proves the reloaded pipeline is spec-equal AND bit-identical under
    ``apply`` with the same params — the invariant checkpoint restore
    relies on.
    """
    from repro.core import spinner
    t0 = time.time()
    pipe = spinner.chain(
        [spinner.SpinnerBlock("circulant", 128, 128),
         spinner.SpinnerBlock("toeplitz", 128, 128),
         spinner.SpinnerBlock("skew_circulant", 256, 128)], f="relu")
    rec: Dict = {"cell": "pipeline_smoke", "depth": pipe.depth,
                 "n_in": pipe.n_in, "out_dim": pipe.out_dim,
                 "budget_t": pipe.budget, "storage_floats": pipe.storage}
    try:
        blob = spinner.dumps(pipe)
        pipe2 = spinner.loads(blob)
        params = pipe.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, pipe.n_in)) * 0.3
        y1 = pipe.apply(params, x)
        y2 = pipe2.apply(params, x)
        rec["config_bytes"] = len(blob)
        rec["roundtrip_spec_equal"] = bool(pipe2 == pipe)
        rec["roundtrip_apply_identical"] = bool(jnp.all(y1 == y2))
        rec["apply_finite"] = bool(jnp.all(jnp.isfinite(y1)))
        rec["ok"] = (rec["roundtrip_spec_equal"]
                     and rec["roundtrip_apply_identical"]
                     and rec["apply_finite"])
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def serve_mesh_smoke(arch: str = "qwen3-4b") -> Dict:
    """``--serve-mesh``: mesh-serving end-to-end smoke on the fake
    8-device host platform.

    Builds 2 router-managed engine replicas with model-axis-sharded page
    pools (TP=2 each), serves 4 mixed-length requests end to end, and
    checks (a) every request completes with greedy tokens identical to
    the single-host paged engine, (b) per-device pool bytes are
    1/model_axis of the single-host layout.
    """
    import numpy as np
    from repro.launch import mesh as mesh_lib
    from repro.serving import Engine, Request, Router
    from repro.serving.mesh import shard as mesh_shard

    t0 = time.time()
    cfg = registry.reduced(arch, n_layers=2)
    rec: Dict = {"cell": "serve_mesh_smoke", "arch": arch,
                 "devices": len(jax.devices())}
    try:
        params = T.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        lens = [3, 9, 17, 6]
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in lens]

        single = Engine(cfg, params, batch_slots=4, max_len=64)
        for i, p in enumerate(prompts):
            single.submit(Request(uid=i, prompt=p, max_new=6))
        want = {r.uid: r.out_tokens for r in single.run()}

        meshes = mesh_lib.make_serving_meshes(replicas=2, model_parallel=2)
        router = Router([Engine(cfg, params, batch_slots=4, max_len=64,
                                mesh=m) for m in meshes])
        for i, p in enumerate(prompts):
            router.submit(Request(uid=i, prompt=p.copy(), max_new=6))
        got = {r.uid: r.out_tokens for r in router.run()}

        rep = router.engines[0].cache_report()
        tp = mesh_shard.paged_tp(cfg, meshes[0])
        rec.update({
            "replicas": 2, "model_parallel": 2, "paged_tp": tp,
            "requests_done": len(got),
            "tokens_match_single_host": bool(got == want),
            "pool_bytes_single": single.cache_report()["pool_bytes"],
            "pool_bytes_per_device": rep["pool_bytes_per_device"],
            "router": router.describe(),
        })
        rec["ok"] = (got == want and len(got) == len(prompts)
                     and tp == 2
                     and rep["pool_bytes_per_device"] * tp
                     == single.cache_report()["pool_bytes"])
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def serve_chaos_smoke(arch: str = "qwen3-4b") -> Dict:
    """``--serve-chaos``: fault-tolerant mesh-serving smoke on the fake
    8-device host platform.

    Builds 2 router-managed TP=2 replicas sharing one metrics registry,
    arms the FT watchdog, and kills replica 1 mid-decode with the
    TEST-ONLY chaos harness (``raise`` at its 4th step). Checks (a) every
    request still completes with greedy tokens bit-identical to an
    undisturbed single-host run (exactly-once rescue), (b) exactly one
    quarantine and zero rescue failures, (c) after ``heal`` + ``revive``
    the pool leaks no pages/slots and fresh requests bit-match too.
    """
    import numpy as np
    from repro.launch import mesh as mesh_lib
    from repro.obs.metrics import MetricsRegistry
    from repro.serving import Engine, FTConfig, Request, Router
    from repro.serving.chaos import ChaosEngine, ChaosPlan

    t0 = time.time()
    cfg = registry.reduced(arch, n_layers=2)
    rec: Dict = {"cell": "serve_chaos_smoke", "arch": arch,
                 "devices": len(jax.devices())}
    try:
        params = T.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        lens = [3, 9, 17, 6, 11, 5]
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in lens]

        single = Engine(cfg, params, batch_slots=4, max_len=64)
        for i, p in enumerate(prompts):
            single.submit(Request(uid=i, prompt=p.copy(), max_new=6))
        want = {r.uid: r.out_tokens for r in single.run()}

        reg = MetricsRegistry()
        meshes = mesh_lib.make_serving_meshes(replicas=2, model_parallel=2)
        engines = [Engine(cfg, params, batch_slots=2, max_len=64, seed=i,
                          mesh=m, metrics=reg)
                   for i, m in enumerate(meshes)]
        chaos = ChaosEngine(engines[1], ChaosPlan("raise", at_step=4))
        engines[1] = chaos
        router = Router(engines, metrics=reg, ft=FTConfig())
        for i, p in enumerate(prompts):
            router.submit(Request(uid=i, prompt=p.copy(), max_new=6))
        got = {r.uid: r.out_tokens for r in router.run()}

        v = reg.value_sum
        quarantined = int(router.metrics.value_sum(
            "router_quarantined_total"))
        rec.update({
            "replicas": 2, "model_parallel": 2,
            "requests_done": len(got),
            "tokens_match_undisturbed": bool(got == want),
            "quarantined": quarantined,
            "dead_after_fault": sorted(router.dead),
            "rescued": int(router.metrics.value_sum("router_rescued_total")),
            "replayed": int(router.metrics.value_sum(
                "router_replayed_total")),
            "failed": int(router.metrics.value_sum("router_failed_total")),
        })

        chaos.heal()
        revived = router.revive(1)
        extra = [Request(uid=100 + i, prompt=p.copy(), max_new=6)
                 for i, p in enumerate(prompts[:2])]
        for r in extra:
            router.submit(r)
        router.run()
        used = sum(e.sched.alloc.used_pages for e in router.engines)
        slots = sum(e.sched.slot_alloc.used_pages for e in router.engines
                    if e.sched.slot_alloc is not None)
        conserved = (v("sched_submitted_total") + v("sched_adopted_total")
                     == v("sched_finished_total")
                     + v("sched_released_total"))
        rec.update({
            "revived": bool(revived),
            "extra_after_revive_match": bool(
                all(np.array_equal(r.out_tokens, want[r.uid - 100])
                    for r in extra)),
            "used_pages_after": used, "used_slots_after": slots,
            "conservation_holds": bool(conserved),
            "router": router.describe(),
        })
        rec["ok"] = (got == want and len(got) == len(prompts)
                     and quarantined == 1 and rec["failed"] == 0
                     and rec["dead_after_fault"] == [1]
                     and revived and rec["extra_after_revive_match"]
                     and used == 0 and slots == 0 and conserved)
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def serve_prefix_smoke(arch: str = "qwen3-4b") -> Dict:
    """``--serve-prefix``: prefix-sharing serving smoke.

    Serves 8 requests sharing a 32-token prompt prefix through one
    paged engine with the radix prefix cache + chunked prefill armed
    (small slot count so admission staggers into waves and later waves
    can hit the donor wave's cached pages). Checks (a) the cache
    actually hit (hit-rate > 0 and strictly fewer tokens prefilled than
    the cold engine), (b) greedy tokens are bit-identical to a cold-cache
    run, (c) after the drain + ``drop_all`` not a single page or slot is
    leaked.
    """
    import numpy as np
    from repro.obs.metrics import MetricsRegistry
    from repro.serving import (ChunkConfig, Engine, PrefixConfig, Request)

    t0 = time.time()
    cfg = registry.reduced(arch, n_layers=2)
    rec: Dict = {"cell": "serve_prefix_smoke", "arch": arch}
    try:
        params = T.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        shared = rng.integers(0, cfg.vocab, 32).astype(np.int32)
        prompts = [np.concatenate([shared, rng.integers(
            0, cfg.vocab, 3 + i).astype(np.int32)]) for i in range(8)]

        def serve(prefix, reg):
            eng = Engine(cfg, params, batch_slots=2, max_len=64,
                         metrics=reg, prefix=prefix)
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=p.copy(), max_new=6))
            return eng, {r.uid: r.out_tokens for r in eng.run()}

        cold_reg = MetricsRegistry()
        _, want = serve(None, cold_reg)
        warm_reg = MetricsRegistry()
        eng, got = serve(PrefixConfig(chunk=ChunkConfig(chunk_tokens=16)),
                         warm_reg)

        hits = int(warm_reg.value_sum("prefix_hits_total"))
        rec.update({
            "requests_done": len(got),
            "hit_rate": round(hits / len(prompts), 3),
            "hit_tokens": int(warm_reg.value_sum("prefix_hit_tokens_total")),
            "cow_forks": int(warm_reg.value_sum("prefix_cow_forks_total")),
            "prefill_tokens_cold": int(cold_reg.value_sum(
                "engine_prefill_tokens_total")),
            "prefill_tokens_warm": int(warm_reg.value_sum(
                "engine_prefill_tokens_total")),
            "tokens_match_cold": bool(got == want),
        })
        cache_pages = eng.prefix.pages
        eng.prefix.drop_all()
        rec.update({
            "cache_pages_at_drain": cache_pages,
            "used_pages_after_drop": eng.sched.alloc.used_pages,
        })
        rec["ok"] = (got == want and len(got) == len(prompts)
                     and hits > 0
                     and rec["prefill_tokens_warm"]
                     < rec["prefill_tokens_cold"]
                     and eng.sched.alloc.used_pages == 0
                     and eng.sched.alloc.total_refs == 0)
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def serve_seeded_smoke(arch: str = "qwen3-4b") -> Dict:
    """``--serve-seeded``: zero-storage seeded-projection serving smoke.

    Builds the SRF variant of ``arch`` with ``srf.seeded=True`` (every
    projection regenerated in-kernel from one uint32 seed per head) and
    serves one base request plus two requests with DISTINCT per-request
    ``embed_seed``s through one paged engine. Checks (a) zero
    materialized projection bytes — the params hold one uint32 per
    (layer, head, block), orders of magnitude under the materialized
    twin's float storage, (b) personalization — the seeded requests
    decode different streams than the base one from the SAME prompt, and
    differ from each other, (c) determinism — a rerun is bit-identical.
    """
    import numpy as np
    from repro.models.attention import srf_cfg
    from repro.serving import Engine, Request

    t0 = time.time()
    cfg = registry.reduced(arch, n_layers=2, attn_impl="srf")
    cfg = dataclasses.replace(
        cfg, srf=dataclasses.replace(cfg.srf, seeded=True))
    rec: Dict = {"cell": "serve_seeded_smoke", "arch": arch}
    try:
        params = T.init(jax.random.PRNGKey(0), cfg)
        seed_leaves = [l for l in jax.tree_util.tree_leaves(params)
                       if l.dtype == jnp.uint32]
        seed_bytes = sum(int(l.size) * 4 for l in seed_leaves)
        pipe = srf_cfg(cfg).pipeline
        twin = dataclasses.replace(pipe, blocks=tuple(
            dataclasses.replace(b, seeded=False) for b in pipe.blocks))
        head_pipes = sum(int(l.size) for l in seed_leaves) // len(pipe.blocks)
        mat_bytes = int(twin.storage) * 4 * head_pipes

        prompt = np.arange(9, dtype=np.int32)

        def serve():
            eng = Engine(cfg, params, batch_slots=4, max_len=64)
            for uid, es in ((0, 0), (1, 1234), (2, 98765)):
                eng.submit(Request(uid=uid, prompt=prompt.copy(), max_new=6,
                                   embed_seed=es))
            return {r.uid: list(r.out_tokens) for r in eng.run()}

        got, again = serve(), serve()
        rec.update({
            "requests_done": len(got),
            "projection_seed_bytes": seed_bytes,
            "materialized_equiv_bytes": mat_bytes,
            "projection_bytes_reduction_x":
                round(mat_bytes / max(seed_bytes, 1), 1),
            "personalized": bool(got[1] != got[0] and got[2] != got[0]
                                 and got[2] != got[1]),
            "deterministic": bool(got == again),
        })
        rec["ok"] = (len(got) == 3
                     and rec["personalized"] and rec["deterministic"]
                     and seed_bytes == 4 * sum(int(l.size)
                                               for l in seed_leaves)
                     and mat_bytes > 10 * seed_bytes)
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=registry.ARCHS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(shp.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all 40 cells")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--attn", default=None, choices=[None, "full", "srf"])
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "dots", "full"])
    ap.add_argument("--srf-kind", default=None)
    ap.add_argument("--srf-features", type=int, default=None)
    ap.add_argument("--out", default=None, help="append-jsonl results path")
    ap.add_argument("--hlo-dir", default=None, help="dump compiled HLO here")
    ap.add_argument("--pipeline", action="store_true",
                    help="spinner-pipeline serialization round-trip smoke "
                         "(no mesh/arch needed)")
    ap.add_argument("--serve-mesh", action="store_true",
                    help="mesh-serving smoke: router + sharded pools on a "
                         "fake 8-device mesh, 4 mixed-length requests e2e")
    ap.add_argument("--serve-chaos", action="store_true",
                    help="fault-tolerance smoke: FT router + chaos-killed "
                         "replica mid-decode, rescue must be bit-identical")
    ap.add_argument("--serve-prefix", action="store_true",
                    help="prefix-sharing smoke: 8 shared-prefix requests, "
                         "hit-rate > 0, bit-match vs cold cache, zero "
                         "leaked pages")
    ap.add_argument("--serve-seeded", action="store_true",
                    help="seeded-projection smoke: requests with distinct "
                         "embed_seeds personalize deterministically with "
                         "zero materialized projection bytes")
    ap.add_argument("--check-bench", action="store_true",
                    help="perf-regression gate: check the committed "
                         "BENCH_*.json payloads against "
                         "BENCH_history.jsonl (benchmarks/regress.py); "
                         "REPRO_BENCH_DIR overrides the repo-root default")
    ap.add_argument("--bench-dir", default=None,
                    help="bench payload/history dir for --check-bench")
    args = ap.parse_args(argv)

    rep = Reporter()
    if args.check_bench:
        return check_bench(args.bench_dir, reporter=rep)

    if (args.pipeline or args.serve_mesh or args.serve_chaos
            or args.serve_prefix or args.serve_seeded):
        rec = (pipeline_smoke() if args.pipeline
               else serve_mesh_smoke(args.arch or "qwen3-4b")
               if args.serve_mesh
               else serve_chaos_smoke(args.arch or "qwen3-4b")
               if args.serve_chaos
               else serve_prefix_smoke(args.arch or "qwen3-4b")
               if args.serve_prefix
               else serve_seeded_smoke(args.arch or "qwen3-4b"))
        line = json.dumps(rec, default=float)
        rep.line(line)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
        return 0 if rec["ok"] else 1

    overrides = {}
    if args.attn:
        overrides["attn_impl"] = args.attn
    if args.remat:
        overrides["remat"] = args.remat
    if args.srf_kind or args.srf_features:
        base = registry.get(args.arch or registry.ARCHS[0]).srf
        overrides["srf"] = dataclasses.replace(
            base, **({"kind": args.srf_kind} if args.srf_kind else {}),
            **({"n_features": args.srf_features} if args.srf_features else {}))

    cells = []
    archs = [args.arch] if args.arch else registry.ARCHS
    shapes_ = [args.shape] if args.shape else list(shp.SHAPES)
    if not (args.all or args.arch or args.shape):
        ap.error("pass --arch/--shape or --all")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes_:
            for mp in meshes:
                cells.append((a, s, mp))

    ok = True
    for a, s, mp in cells:
        rec = run_cell(a, s, multi_pod=mp, use_reduced=args.reduced,
                       overrides=overrides, hlo_dir=args.hlo_dir)
        ok = ok and rec["ok"]
        line = json.dumps(rec, default=float)
        rep.line(line)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
