"""qwen2-vl-2b [vlm] — arXiv:2409.12191.
28L d_model=1536 12H (GQA kv=2, head_dim=128) d_ff=8960 vocab=151936,
M-RoPE (sections 16/24/24), dynamic-resolution vision frontend stubbed
with precomputed patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, m_rope=True, m_rope_sections=(16, 24, 24),
    frontend="vision_stub", n_vision_tokens=1024, rope_theta=1_000_000.0,
    max_seq=32768, dtype="bfloat16",
)
