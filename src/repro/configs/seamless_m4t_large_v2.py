"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596.
24L enc + 24L dec, d_model=1024 16H (kv=16, head_dim=64) d_ff=8192
vocab=256206. Audio frontend is a stub (precomputed frame embeddings) per
spec; positions use RoPE in place of the original learned/sinusoidal
(noted in DESIGN.md)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, enc_layers=24, enc_len=1024,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, frontend="audio_stub", max_seq=8192,
    dtype="bfloat16",
)
