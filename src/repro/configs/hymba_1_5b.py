"""hymba-1.5b [hybrid] — arXiv:2411.13676.
32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001,
ssm_state=16; parallel attention + mamba heads fused per layer.

Adaptation notes (DESIGN.md): Hymba's meta-tokens and sliding-window mix
are not modeled; the parallel attn||SSM heads with per-branch output norm
and mean fusion are."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, ssm_state=16, ssm_expand=2, ssm_head_dim=64,
    max_seq=8192, dtype="bfloat16",
)
