"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD / state-space duality).
64L d_model=2560 attention-free, d_ff=0, vocab=50280, ssm_state=128,
expand=2 (d_inner=5120), head_dim=64 -> 80 SSD heads."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    max_seq=1048576, dtype="bfloat16",
)
