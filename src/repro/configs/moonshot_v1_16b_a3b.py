"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B (kimi).
48L d_model=2048 16H (GQA kv=16, head_dim=128) per-expert d_ff=1408,
MoE 64e top-6 + 2 shared, vocab=163840. Assigned-spec numbers used
verbatim (layer count per the assignment sheet)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=11264, vocab=163840,
    moe_experts=64, moe_top_k=6, moe_shared=2, moe_d_ff=1408,
    moe_first_dense=1,
    max_seq=131072, dtype="bfloat16",
)
