"""Model/config schema shared by all architectures.

Every assigned architecture gets one file in this package exporting
``CONFIG`` (full-size, exact public numbers) and ``reduced()`` (same
family, tiny dims, for CPU smoke tests). ``registry.py`` maps ids to both.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class SRFAttnConfig:
    """Paper technique knobs for SRF (structured random-feature) attention."""
    kind: str = "circulant"         # structured class (budget-of-randomness knob)
    n_features: int = 256           # m
    feature: str = "softmax_pos"
    r: int = 1                      # displacement rank (ldr)
    chunk: int = 128                # causal chunk
    seeded: bool = False            # zero-storage projections regenerated
                                    # from one uint32 seed per head; unlocks
                                    # per-request embed_seed personalization


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    max_seq: int = 131072

    # attention
    attn_impl: str = "full"         # full | srf   (srf = the paper's mechanism)
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    m_rope: bool = False            # qwen2-vl M-RoPE
    m_rope_sections: Tuple[int, ...] = (16, 24, 24)
    srf: SRFAttnConfig = field(default_factory=SRFAttnConfig)

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0             # shared experts (deepseek style)
    moe_d_ff: int = 0               # per-expert hidden
    moe_first_dense: int = 0        # leading dense layers
    moe_capacity_factor: float = 1.25

    # MLA (deepseek latent attention)
    mla_kv_lora: int = 0            # 0 = plain GQA
    mla_qk_nope: int = 128
    mla_qk_rope: int = 64
    mla_v_dim: int = 128

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # serving
    kv_cache_dtype: str = "bf16"    # bf16 | int8 (quantized KV cache:
                                    # per-token-per-head scales; halves
                                    # decode cache bytes)

    # enc-dec
    enc_layers: int = 0             # >0 => encoder-decoder
    enc_len: int = 1024             # encoder memory length for shapes

    # frontends ([audio]/[vlm] are stubs per spec)
    frontend: str = "none"          # none | audio_stub | vision_stub
    n_vision_tokens: int = 1024

    # numerics / training
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "float32"          # params+activations; reductions f32
    remat: str = "full"             # none | dots | full
    scan_group: int = 1             # layers per checkpointed scan step:
                                    # residuals saved every k layers (k x
                                    # less saved-stack memory, same FLOPs
                                    # under full remat)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded for even sharding (standard practice; loss masks pad)."""
        return _ceil_to(self.vocab, 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:       # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.mla_kv_lora > 0

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def mla_qk_dim(self) -> int:
        return self.mla_qk_nope + self.mla_qk_rope

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, v = self.d_model, self.padded_vocab
        n = 0
        n += v * d                                  # embed
        if not self.tie_embeddings:
            n += v * d                              # lm head
        def attn_params():
            if self.is_mla:
                a = d * self.mla_kv_lora + d * self.mla_qk_rope
                a += self.mla_kv_lora * self.n_heads * (self.mla_qk_nope + self.mla_v_dim)
                a += d * self.n_heads * self.mla_qk_dim
                a += self.n_heads * self.mla_v_dim * d
                return a
            a = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                a += self.q_dim + 2 * self.kv_dim
            return a
        def mlp_params(ff):
            return 3 * d * ff
        def ssm_params():
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = di + 2 * ns
            a = d * (2 * di + 2 * ns + nh)          # in_proj
            a += conv_dim * self.ssm_conv           # conv
            a += 2 * nh + di                        # A_log, D, norm
            a += di * d                             # out_proj
            return a
        for layer in range(self.n_layers):
            n += 2 * d                              # norms
            if self.family == "ssm":
                n += ssm_params()
                continue
            if self.family == "hybrid":
                n += attn_params() + ssm_params()
            else:
                n += attn_params()
            if self.is_moe and layer >= self.moe_first_dense:
                n += d * self.moe_experts           # router
                n += self.moe_experts * mlp_params(self.moe_d_ff) // 1
                n += mlp_params(self.moe_shared * self.moe_d_ff)
            else:
                n += mlp_params(self.d_ff)
        if self.is_encdec:
            # encoder layers + cross attention in decoder
            for _ in range(self.enc_layers):
                n += 2 * d + attn_params() + mlp_params(self.d_ff)
            n += self.n_layers * (d + attn_params())   # cross attn + norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k+shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        moe_layers = self.n_layers - self.moe_first_dense
        unused = (self.moe_experts - self.moe_top_k) * 3 * self.d_model * self.moe_d_ff
        return full - moe_layers * unused
