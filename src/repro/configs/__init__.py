"""Architecture configs (one file per assigned arch) + registry + shapes."""
from .base import ModelConfig, SRFAttnConfig
from . import registry, shapes
from .registry import ARCHS, get, reduced

__all__ = ["ModelConfig", "SRFAttnConfig", "registry", "shapes", "ARCHS",
           "get", "reduced"]
