"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ModelConfig, SRFAttnConfig
from . import (deepseek_v2_lite_16b, hymba_1_5b, internlm2_20b, mamba2_2_7b,
               mistral_nemo_12b, moonshot_v1_16b_a3b, qwen2_5_14b, qwen2_vl_2b,
               qwen3_4b, seamless_m4t_large_v2)

_MODULES = {
    "mistral-nemo-12b": mistral_nemo_12b,
    "internlm2-20b": internlm2_20b,
    "qwen2.5-14b": qwen2_5_14b,
    "qwen3-4b": qwen3_4b,
    "hymba-1.5b": hymba_1_5b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "mamba2-2.7b": mamba2_2_7b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "qwen2-vl-2b": qwen2_vl_2b,
}

ARCHS: List[str] = list(_MODULES)


def get(name: str, **overrides) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    cfg = _MODULES[name].CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def reduced(name: str, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (spec: small layers/width,
    few experts, tiny embedding tables)."""
    cfg = get(name)
    kw = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, max_seq=256, dtype="float32", remat="none",
        srf=SRFAttnConfig(kind=cfg.srf.kind, n_features=32, chunk=16),
        n_vision_tokens=8, enc_len=16, ssm_chunk=16,
    )
    if cfg.is_moe:
        kw.update(moe_experts=8, moe_top_k=2, moe_shared=1, moe_d_ff=32,
                  moe_first_dense=1, n_layers=3)
    if cfg.is_mla:
        kw.update(mla_kv_lora=32, mla_qk_nope=16, mla_qk_rope=8, mla_v_dim=16)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_expand=2, ssm_head_dim=16)
    if cfg.is_encdec:
        kw.update(enc_layers=2)
    if cfg.m_rope:
        kw.update(m_rope_sections=(2, 3, 3))   # sums to head_dim/2 = 8
    if cfg.d_ff == 0:
        kw.update(d_ff=0)
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
