"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434.
27L d_model=2048 16H MLA (kv_lora=512, qk 128+64 rope, v=128),
per-expert d_ff=1408, 2 shared + 64 routed experts top-6, first layer
dense (d_ff=10944), vocab=102400."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    moe_experts=64, moe_top_k=6, moe_shared=2, moe_d_ff=1408,
    moe_first_dense=1,
    mla_kv_lora=512, mla_qk_nope=128, mla_qk_rope=64, mla_v_dim=128,
    max_seq=163840, dtype="bfloat16",
)
