"""mistral-nemo-12b [dense] — hf:mistralai/Mistral-Nemo-Base-2407.
40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072, 128k ctx."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1_000_000.0, max_seq=131072,
    dtype="bfloat16",
)
