"""Assigned input shapes and ShapeDtypeStruct ``input_specs`` per cell.

Four shapes per arch (40 cells):
    train_4k      seq 4096   batch 256   -> train_step
    prefill_32k   seq 32768  batch 32    -> prefill (inference)
    decode_32k    seq 32768  batch 128   -> serve_step (1 token, 32k cache)
    long_500k     seq 524288 batch 1     -> serve_step (sub-quadratic only)

``long_500k`` policy (DESIGN.md §Arch-applicability): SSM/hybrid archs run
natively (O(1)-in-L state); pure-attention archs run with the paper's SRF
attention enabled (O(m d) state replaces the 2.7TB KV cache). The
exact-attention variant of those cells is marked skipped(quadratic).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import frontends, transformer
from .base import ModelConfig
from . import registry


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_config(arch: str, shape: str, use_reduced: bool = False,
                **overrides) -> Tuple[ModelConfig, str]:
    """Resolve the (possibly technique-adapted) config for one cell.

    Returns (cfg, note); note records when the paper's SRF attention was
    switched on to make the cell feasible."""
    cfg = registry.reduced(arch) if use_reduced else registry.get(arch)
    note = ""
    if shape == "decode_32k" and cfg.attn_impl == "full" and not cfg.is_mla:
        # int8 KV cache for the decode shape: halves cache bytes, greedy
        # tokens identical to bf16 (test_int8_kv_cache_decode_quality);
        # required for the 16-head MHA archs to fit 16 GiB.
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
        note = "int8 KV cache"
    if shape == "long_500k" and cfg.family != "ssm":
        if cfg.family == "hybrid":
            cfg = dataclasses.replace(cfg, attn_impl="srf")
            note = "hybrid: SSM native + attention heads in SRF mode"
        else:
            cfg = dataclasses.replace(cfg, attn_impl="srf")
            note = ("exact attention infeasible at 524k (KV cache O(L)); "
                    "running the paper's SRF attention (O(m d) state)")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg, note


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def batch_specs(cfg: ModelConfig, b: int, l: int, training: bool) -> Dict:
    """ShapeDtypeStructs for the data batch of a forward/train call."""
    specs: Dict = {}
    if cfg.is_encdec:
        specs["enc_emb"] = _f32((b, cfg.enc_len, frontends.AUDIO_FEAT_DIM))
        specs["tokens"] = _i32((b, l))
    elif cfg.frontend == "vision_stub":
        nv = min(cfg.n_vision_tokens, l // 2)
        specs["vision_emb"] = _f32((b, nv, frontends.VISION_FEAT_DIM))
        specs["tokens"] = _i32((b, l - nv))
        specs["pos3"] = _i32((3, b, l))
    else:
        specs["tokens"] = _i32((b, l))
    if training:
        specs["labels"] = _i32(specs["tokens"].shape)
    return specs


def cache_specs(cfg: ModelConfig, b: int, max_len: int) -> Dict:
    return jax.eval_shape(
        lambda: transformer.init_serve_cache(cfg, b, max_len))


def input_specs(cfg: ModelConfig, shape: str,
                batch_override: int = 0, seq_override: int = 0) -> Dict:
    """All model inputs (minus params) for the cell's step function."""
    ss = SHAPES[shape]
    b = batch_override or ss.global_batch
    l = seq_override or ss.seq_len
    if ss.step == "train":
        return {"batch": batch_specs(cfg, b, l, training=True)}
    if ss.step == "prefill":
        return {"batch": batch_specs(cfg, b, l, training=False),
                "cache": cache_specs(cfg, b, l)}
    if ss.step == "decode":
        return {"tokens": _i32((b, 1)), "cache": cache_specs(cfg, b, l)}
    raise ValueError(ss.step)
