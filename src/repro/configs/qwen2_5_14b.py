"""qwen2.5-14b [dense] — hf:Qwen/Qwen2.5 family.
48L d_model=5120 40H (GQA kv=8, head_dim=128) d_ff=13824 vocab=152064, QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
    max_seq=131072, dtype="bfloat16",
)
