"""The P-model: budget of randomness + structured projection + HD preconditioning.

This is the paper's core object (Sec 2.2-2.3). A ``PModel`` bundles:
  * a structured matrix kind and its generator params (``structured.py``)
  * the Step-1 randomized Hadamard preconditioner  D1 H D0
  * the projection  x  ->  A . D1 H D0 . x        (the y_{i,j} of eq. 1)

All state lives in a flat params dict (a pytree), so PModels embed directly
into model parameter trees and shard like any other weight — except they
are O(n) floats instead of O(mn), which is the paper's space claim.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import structured, transforms


@dataclass(frozen=True)
class PModelSpec:
    kind: str = "circulant"       # one of structured.KINDS
    m: int = 128                  # output (embedding) dimension
    n: int = 128                  # input dimension (pow2 if use_hd)
    r: int = 1                    # displacement rank (ldr only)
    use_hd: bool = True           # paper Step 1 preconditioner
    ldr_nnz: int = 4

    def __post_init__(self):
        if self.kind not in structured.KINDS:
            raise ValueError(f"kind must be one of {structured.KINDS}")
        if self.use_hd and not transforms.is_pow2(self.n):
            raise ValueError(f"use_hd requires power-of-two n, got {self.n}")

    @property
    def budget(self) -> int:
        """t — the number of Gaussians recycled into the m x n projection."""
        return structured.budget(self.kind, self.m, self.n, self.r)

    @property
    def storage(self) -> int:
        base = structured.storage_floats(self.kind, self.m, self.n, self.r)
        return base + (2 * self.n if self.use_hd else 0)


def init(rng: jax.Array, spec: PModelSpec, dtype=jnp.float32) -> Dict[str, jax.Array]:
    kg, k0, k1 = jax.random.split(rng, 3)
    params = structured.init(kg, spec.kind, spec.m, spec.n, spec.r,
                             spec.ldr_nnz, dtype)
    if spec.use_hd:
        params["d0"] = transforms.sample_signs(k0, spec.n, dtype)
        params["d1"] = transforms.sample_signs(k1, spec.n, dtype)
    return params


def project(spec: PModelSpec, params: Dict[str, jax.Array], x: jax.Array,
            use_kron: bool = False, use_pallas: Optional[bool] = None
            ) -> jax.Array:
    """(..., n) -> (..., m):  A . D1 H D0 . x.

    Routed through the fused spinner (kernels.ops.spinner_project): one
    Pallas pass on TPU, one fused jnp dispatch elsewhere. ``use_kron`` is
    kept for back-compat; the fused path always uses the Kronecker FWHT.
    """
    return project_fused(spec, params, x, use_pallas=use_pallas)


def project_fused(spec: PModelSpec, params: Dict[str, jax.Array],
                  x: jax.Array, epilogue: str = "identity",
                  y_scale: float = 1.0, out_scale: float = 1.0,
                  grouped: bool = False,
                  use_pallas: Optional[bool] = None) -> jax.Array:
    """One-pass  f(y_scale * A D1 H D0 x) * out_scale  (feature-map hot path).

    ``grouped=True``: x is (G, ..., n) and every param leaf carries a
    leading group axis G (per-head P-models); the whole group runs as a
    single fused dispatch. Output (..., m) — (..., 2m) for cos_sin.
    """
    if x.shape[-1] != spec.n:
        raise ValueError(f"expected last dim {spec.n}, got {x.shape}")
    from repro.kernels import ops as kops   # deferred: kernels import core
    return kops.spinner_project(spec.kind, params, x, spec.m,
                                epilogue=epilogue, y_scale=y_scale,
                                out_scale=out_scale, grouped=grouped,
                                use_pallas=use_pallas)


def materialize(spec: PModelSpec, params: Dict[str, jax.Array]) -> jax.Array:
    """Dense (m, n) matrix of the *whole* pipeline A . D1 H D0 (oracle)."""
    a = structured.materialize(spec.kind, params, spec.m, spec.n)
    if spec.use_hd:
        h = transforms.hadamard(spec.n, a.dtype)
        a = (a * params["d1"][None, :]) @ h * params["d0"][None, :]
    return a


def row_gaussianity_moments(spec: PModelSpec, params: Dict[str, jax.Array]):
    """Diagnostic: per-row mean/var of A (each row must be ~N(0, I) by the
    normalization property, Def. 1)."""
    a = structured.materialize(spec.kind, params, spec.m, spec.n)
    return a.mean(axis=1), a.var(axis=1)
