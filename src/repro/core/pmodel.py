"""DEPRECATED back-compat shim: the single-block P-model API.

The paper's core object lives in ``core/spinner.py`` now: a ``PModel``
is exactly a 1-block ``SpinnerPipeline`` (one structured block
``A . D1 H D0`` + a fused nonlinearity). Everything here is a thin
delegating wrapper kept so pre-pipeline call sites keep working:

    old                                   new
    ------------------------------------  -----------------------------------
    PModelSpec(kind, m, n, ...)           spinner.single(kind, m, n, ...)
    pmodel.init(rng, spec)                pipe.init(rng)      (params tuple)
    pmodel.project(spec, params, x)       pipe.apply(params, x)
    pmodel.project_fused(..., epilogue=f) pipe.with_f(f).apply(params, x, ...)
    pmodel.materialize(spec, params)      pipe.materialize(params)
    pmodel.row_gaussianity_moments(...)   pipe.row_gaussianity_moments(...)

``init/project/project_fused`` emit ``DeprecationWarning``; outputs are
bit-identical to the pipeline API for fixed seeds (pipeline init of a
1-block pipeline consumes the rng exactly as the legacy init did).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import spinner, structured, transforms


def _warn(old: str, new: str) -> None:
    warnings.warn(f"repro.core.pmodel.{old} is deprecated; use {new} "
                  "(see core/README.md migration table)",
                  DeprecationWarning, stacklevel=3)


@dataclass(frozen=True)
class PModelSpec:
    """Legacy 1-block spec. Prefer ``spinner.single`` / ``SpinnerBlock``."""
    kind: str = "circulant"       # one of structured.KINDS
    m: int = 128                  # output (embedding) dimension
    n: int = 128                  # input dimension (pow2 if use_hd)
    r: int = 1                    # displacement rank (ldr only)
    use_hd: bool = True           # paper Step 1 preconditioner
    ldr_nnz: int = 4

    def __post_init__(self):
        if self.kind not in structured.KINDS:
            raise ValueError(f"kind must be one of {structured.KINDS}")
        if self.use_hd and not transforms.is_pow2(self.n):
            raise ValueError(f"use_hd requires power-of-two n, got {self.n}")

    @property
    def block(self) -> spinner.SpinnerBlock:
        return spinner.SpinnerBlock(self.kind, self.m, self.n, self.r,
                                    self.use_hd, self.ldr_nnz)

    @property
    def pipeline(self) -> spinner.SpinnerPipeline:
        """The equivalent 1-block SpinnerPipeline (identity f)."""
        return spinner.SpinnerPipeline((self.block,))

    @property
    def budget(self) -> int:
        """t — the number of Gaussians recycled into the m x n projection."""
        return self.block.budget

    @property
    def storage(self) -> int:
        return self.block.storage


def init(rng: jax.Array, spec: PModelSpec, dtype=jnp.float32
         ) -> Dict[str, jax.Array]:
    _warn("init", "SpinnerPipeline.init")
    return spec.pipeline.init(rng, dtype)[0]


def project(spec: PModelSpec, params: Dict[str, jax.Array], x: jax.Array,
            use_kron: bool = False, use_pallas: Optional[bool] = None
            ) -> jax.Array:
    """(..., n) -> (..., m):  A . D1 H D0 . x  (``use_kron`` is vestigial;
    the fused path always uses the Kronecker FWHT)."""
    _warn("project", "SpinnerPipeline.apply")
    return spec.pipeline.apply((params,), x, use_pallas=use_pallas)


def project_fused(spec: PModelSpec, params: Dict[str, jax.Array],
                  x: jax.Array, epilogue: str = "identity",
                  y_scale: float = 1.0, out_scale: float = 1.0,
                  grouped: bool = False,
                  use_pallas: Optional[bool] = None) -> jax.Array:
    """One-pass  f(y_scale * A D1 H D0 x) * out_scale."""
    _warn("project_fused", "SpinnerPipeline.with_f(f).apply")
    return spec.pipeline.with_f(epilogue).apply(
        (params,), x, y_scale=y_scale, out_scale=out_scale,
        grouped=grouped, use_pallas=use_pallas)


def materialize(spec: PModelSpec, params: Dict[str, jax.Array]) -> jax.Array:
    """Dense (m, n) matrix of the *whole* pipeline A . D1 H D0 (oracle)."""
    return spec.pipeline.materialize((params,))


def row_gaussianity_moments(spec: PModelSpec, params: Dict[str, jax.Array]):
    """Diagnostic: per-row mean/var of A (each row must be ~N(0, I) by the
    normalization property, Def. 1)."""
    return spec.block.row_gaussianity_moments(params)
