"""Composable Spinner embedding API: multi-block pipelines as pytrees.

The paper's P-model ``(A, f)`` is one *structured spinner block*
``A . D1 H D0`` followed by a pointwise nonlinearity ``f``. This module
makes that composition first-class:

* ``SpinnerBlock``    — one structured matrix kind + optional HD
                        preconditioning + fixed output scaling, an
                        (n -> m) linear map generated from O(n) Gaussians.
* ``SpinnerPipeline`` — an ordered chain of blocks plus ONE fused
                        nonlinearity:  f(A_k ... A_2 A_1 x). Expresses the
                        stacked constructions (TripleSpin ``M3 M2 M1``,
                        Gaussian-circulant over HD, LDR chains) the
                        framework generalizes to.

Both are frozen dataclasses registered as zero-leaf pytree nodes: they
pass transparently through ``jax.jit`` / ``vmap`` / tree maps (all fields
are static aux data), are hashable (valid static args), and embed inside
parameter trees. Parameters live in a tuple of per-block dicts — a plain
pytree that checkpoints and shards like any other weight.

Uniform protocol (every block and every pipeline):

    init(rng, dtype) -> params        sample the budget of randomness
    apply(params, x, ...)             the fast (fused) forward map
    materialize(params)               dense oracle of the whole linear map
    budget / storage / flops          the paper's complexity accounting

Registries replace ad-hoc string dispatch:

* ``register_kind`` / ``kind_def``: structured matrix classes. The six
  built-ins delegate to ``structured.py`` and carry ``fused=True`` — their
  blocks lower to the fused Pallas spinner (``kernels.ops.spinner_project``,
  ONE dispatch per block). Custom kinds run on a generic jnp path
  (HD -> registry matvec -> epilogue, one jit-fusable graph).
* ``register_nonlinearity`` / ``nonlinearity``: pointwise f's. Built-ins
  map onto the kernel's fused epilogues; custom ones apply after the last
  block's dispatch.

A 1-block pipeline is byte-identical to the PR-2 hot path: a single
``spinner_project`` call. Multi-block pipelines chain one fused dispatch
per block (intermediates stay activations; nothing is re-materialized).
"""
from __future__ import annotations

import json
import math
import warnings
from dataclasses import asdict, dataclass, replace
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import structured, transforms


# ---------------------------------------------------------------------------
# kind registry — structured matrix classes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KindDef:
    """One structured matrix class: samplers, fast/oracle paths, accounting.

    ``init(rng, m, n, r, ldr_nnz, dtype) -> params dict``
    ``matvec(params, x, m) -> y``            fast path, last-axis (..., n)
    ``materialize(params, m, n) -> (m, n)``  dense oracle
    ``budget/storage/flops (m, n, r) -> number``
    ``fused``: the kind string is understood by kernels.ops.spinner_project
    (implicit-tile Pallas on TPU, fused jnp ref elsewhere). Custom kinds
    leave it False and take the generic registry path.
    """
    name: str
    init: Callable[..., Dict[str, jax.Array]]
    matvec: Callable[..., jax.Array]
    materialize: Callable[..., jax.Array]
    budget: Callable[[int, int, int], int]
    storage: Callable[[int, int, int], int]
    flops: Callable[[int, int, int], float]
    fused: bool = False


_KINDS: Dict[str, KindDef] = {}


def register_kind(kd: KindDef, overwrite: bool = False) -> KindDef:
    if kd.name in _KINDS and not overwrite:
        raise ValueError(f"kind {kd.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _KINDS[kd.name] = kd
    return kd


def kind_def(name: str) -> KindDef:
    try:
        return _KINDS[name]
    except KeyError:
        raise ValueError(f"unknown spinner kind {name!r}; registered: "
                         f"{sorted(_KINDS)}") from None


def registered_kinds() -> Tuple[str, ...]:
    return tuple(_KINDS)


def _register_builtin(kind: str) -> None:
    register_kind(KindDef(
        name=kind,
        init=lambda rng, m, n, r=1, ldr_nnz=4, dtype=jnp.float32, _k=kind:
            structured.init(rng, _k, m, n, r, ldr_nnz, dtype),
        matvec=lambda params, x, m, _k=kind: structured.matvec(_k, params, x, m),
        materialize=lambda params, m, n, _k=kind:
            structured.materialize(_k, params, m, n),
        budget=lambda m, n, r, _k=kind: structured.budget(_k, m, n, r),
        storage=lambda m, n, r, _k=kind: structured.storage_floats(_k, m, n, r),
        flops=lambda m, n, r, _k=kind: structured.flops_fast(_k, m, n, r),
        fused=True))


for _k in structured.KINDS:
    _register_builtin(_k)


# ---------------------------------------------------------------------------
# nonlinearity registry — the pointwise f of the pipeline
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Nonlinearity:
    """Pointwise f applied to the final projection.

    ``fn(y, sq) -> out``: ``sq`` is 0.5||x_in||^2 per row (keepdims) when
    ``needs_input`` else None. ``out_mult``: output dim multiplier (2 for
    cos_sin). ``epilogue``: fused kernel epilogue name, or None — then f
    runs as a separate (XLA-fused) stage after the last block's dispatch.
    ``needs_input=True`` (exp): f consumes the norm of the PIPELINE input;
    it can only fuse in-kernel for 1-block pipelines, where the kernel's
    input tile IS the pipeline input (HD isometry argument).
    """
    name: str
    fn: Callable[[jax.Array, Optional[jax.Array]], jax.Array]
    out_mult: int = 1
    epilogue: Optional[str] = None
    needs_input: bool = False


_NONLINEARITIES: Dict[str, Nonlinearity] = {}


def register_nonlinearity(nl: Nonlinearity, overwrite: bool = False
                          ) -> Nonlinearity:
    if nl.name in _NONLINEARITIES and not overwrite:
        raise ValueError(f"nonlinearity {nl.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _NONLINEARITIES[nl.name] = nl
    return nl


def nonlinearity(name: str) -> Nonlinearity:
    try:
        return _NONLINEARITIES[name]
    except KeyError:
        raise ValueError(f"unknown nonlinearity {name!r}; registered: "
                         f"{sorted(_NONLINEARITIES)}") from None


def registered_nonlinearities() -> Tuple[str, ...]:
    return tuple(_NONLINEARITIES)


def _f_exp(y: jax.Array, sq: jax.Array) -> jax.Array:
    return jnp.exp(y.astype(jnp.float32) - sq).astype(y.dtype)


register_nonlinearity(Nonlinearity(
    "identity", lambda y, sq: y, epilogue="identity"))
register_nonlinearity(Nonlinearity(
    "relu", lambda y, sq: jax.nn.relu(y), epilogue="relu"))
register_nonlinearity(Nonlinearity(
    "heaviside", lambda y, sq: (y >= 0).astype(y.dtype), epilogue="heaviside"))
register_nonlinearity(Nonlinearity(
    "sign", lambda y, sq: jnp.sign(y), epilogue="sign"))
register_nonlinearity(Nonlinearity(
    "exp", _f_exp, epilogue="exp", needs_input=True))
register_nonlinearity(Nonlinearity(
    "cos_sin", lambda y, sq: jnp.concatenate([jnp.cos(y), jnp.sin(y)], -1),
    out_mult=2, epilogue="cos_sin"))


# ---------------------------------------------------------------------------
# SpinnerBlock — one  A . D1 H D0  unit
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SpinnerBlock:
    """One structured spinner unit: (n -> m) via  scale . A . [D1 H D0].

    All fields are static (the block is a spec, not a container of
    arrays); parameters are sampled by ``init`` and passed to ``apply``.
    ``scale`` is a fixed output scaling folded into the block's fused
    dispatch (and into ``materialize``): intermediate blocks of a stack
    use ``scale = 1/sqrt(n)`` to stay variance-preserving — a raw
    row-Gaussian block multiplies input norms by ~sqrt(n), which would
    de-calibrate every kernel estimator downstream of a deep stack.

    ``seeded=True`` is the zero-storage mode: ``init`` samples ONE uint32
    seed instead of arrays, and every matrix entry (generator core AND
    the HD diagonals) is regenerated at its position inside the kernel
    (``kernels.seedgen``). ``materialize`` / diagnostics rebuild the
    oracle params transiently. Builtin kinds only.
    """
    kind: str = "circulant"
    m: int = 128
    n: int = 128
    r: int = 1                    # displacement rank (ldr only)
    use_hd: bool = True           # paper Step-1 preconditioner
    ldr_nnz: int = 4
    scale: float = 1.0            # fixed output scaling (fused)
    seeded: bool = False          # zero-storage: params are one uint32 seed

    def __post_init__(self):
        kind_def(self.kind)       # raises on unknown kinds
        if self.m <= 0 or self.n <= 0:
            raise ValueError(f"block dims must be positive, got "
                             f"m={self.m}, n={self.n}")
        if self.use_hd and not transforms.is_pow2(self.n):
            raise ValueError(f"use_hd requires power-of-two n, got {self.n}")
        if self.seeded and self.kind not in structured.KINDS:
            raise ValueError(
                f"seeded mode regenerates params positionally and only "
                f"supports builtin kinds {structured.KINDS}, got "
                f"{self.kind!r}")

    # --- accounting ---------------------------------------------------------

    @property
    def budget(self) -> int:
        """t — Gaussians recycled into this block's m x n projection."""
        return int(kind_def(self.kind).budget(self.m, self.n, self.r))

    @property
    def storage(self) -> int:
        if self.seeded:           # one uint32 seed regenerates everything
            return 1
        base = int(kind_def(self.kind).storage(self.m, self.n, self.r))
        return base + (2 * self.n if self.use_hd else 0)

    @property
    def flops(self) -> float:
        """~FLOPs of the fast path per input vector (HD is lower-order)."""
        f = float(kind_def(self.kind).flops(self.m, self.n, self.r))
        if self.use_hd:
            f += 2.0 * self.n * math.log2(max(self.n, 2))
        return f

    # --- protocol -----------------------------------------------------------

    def init(self, rng: jax.Array, dtype=jnp.float32) -> Dict[str, jax.Array]:
        if self.seeded:
            # the WHOLE parameterization is one uint32 scalar; dtype only
            # governs activations (seeded generation is always f32)
            seed = jax.random.randint(rng, (), 0, jnp.iinfo(jnp.int32).max,
                                      dtype=jnp.int32)
            return {"seed": seed.astype(jnp.uint32)}
        kg, k0, k1 = jax.random.split(rng, 3)
        params = kind_def(self.kind).init(kg, self.m, self.n, self.r,
                                          self.ldr_nnz, dtype)
        if self.use_hd:
            params["d0"] = transforms.sample_signs(k0, self.n, dtype)
            params["d1"] = transforms.sample_signs(k1, self.n, dtype)
        return params

    def _oracle_params(self, params: Dict[str, jax.Array]
                       ) -> Dict[str, jax.Array]:
        """Seeded blocks: the materialized twin of the seed (transient,
        ``structured.init`` shapes). Materialized blocks: passthrough."""
        if not self.seeded:
            return params
        from repro.kernels import seedgen           # deferred: kernels import core
        return seedgen.seeded_params(self.kind, self.n, self.m,
                                     params["seed"], r=self.r,
                                     ldr_nnz=self.ldr_nnz,
                                     use_hd=self.use_hd)

    def apply(self, params: Dict[str, jax.Array], x: jax.Array, *,
              epilogue: str = "identity", y_scale: float = 1.0,
              out_scale: float = 1.0, grouped: bool = False,
              use_pallas: Optional[bool] = None) -> jax.Array:
        """(..., n) -> (..., m):  epi(y_scale . A D1 H D0 x) . out_scale.

        ``epilogue`` is a KERNEL epilogue name (the pipeline picks it from
        its nonlinearity). Fused kinds run as one spinner_project dispatch;
        custom kinds take the generic registry path below.
        """
        if x.shape[-1] != self.n:
            raise ValueError(f"expected last dim {self.n}, got {x.shape}")
        y_scale = float(self.scale) * y_scale     # block scaling, fused
        if self.seeded:
            from repro.kernels import ops as kops   # deferred: kernels import core
            return kops.spinner_project_seeded(
                self.kind, params["seed"], x, self.m, r=self.r,
                ldr_nnz=self.ldr_nnz, use_hd=self.use_hd, epilogue=epilogue,
                y_scale=y_scale, out_scale=out_scale, grouped=grouped,
                use_pallas=use_pallas)
        if kind_def(self.kind).fused:
            from repro.kernels import ops as kops   # deferred: kernels import core
            return kops.spinner_project(self.kind, params, x, self.m,
                                        epilogue=epilogue, y_scale=y_scale,
                                        out_scale=out_scale, grouped=grouped,
                                        use_pallas=use_pallas)
        return self._apply_generic(params, x, epilogue, y_scale, out_scale,
                                   grouped)

    def _apply_generic(self, params, x, epilogue, y_scale, out_scale,
                       grouped) -> jax.Array:
        """Registry path for custom kinds: HD -> matvec -> epilogue as one
        jnp graph (XLA-fused under the caller's jit)."""
        from repro.kernels import ref as kref       # epilogue semantics
        kd = kind_def(self.kind)

        def one(p, xx):
            v = xx
            if "d0" in p:
                v = transforms.hd_preprocess(xx, p["d0"], p["d1"],
                                             use_kron=True)
            y = kd.matvec(p, v, self.m)
            if y_scale != 1.0:
                y = y * jnp.asarray(y_scale, y.dtype)
            return kref._spinner_epilogue(y, xx, epilogue, out_scale)

        if grouped:
            return jax.vmap(one)(params, x)
        return one(params, x)

    def materialize(self, params: Dict[str, jax.Array]) -> jax.Array:
        """Dense (m, n) matrix of the whole block scale . A . [D1 H D0].
        Seeded blocks regenerate the oracle params on demand."""
        params = self._oracle_params(params)
        a = kind_def(self.kind).materialize(params, self.m, self.n)
        if self.use_hd:
            h = transforms.hadamard(self.n, a.dtype)
            a = (a * params["d1"][None, :]) @ h * params["d0"][None, :]
        if self.scale != 1.0:
            a = a * jnp.asarray(self.scale, a.dtype)
        return a

    def row_gaussianity_moments(self, params) -> Tuple[jax.Array, jax.Array]:
        """Per-row mean/var of A (each row ~ N(0, I) by Def. 1)."""
        params = self._oracle_params(params)
        a = kind_def(self.kind).materialize(params, self.m, self.n)
        return a.mean(axis=1), a.var(axis=1)


# ---------------------------------------------------------------------------
# SpinnerPipeline — ordered blocks + one fused nonlinearity
# ---------------------------------------------------------------------------

Params = Tuple[Dict[str, jax.Array], ...]


@dataclass(frozen=True)
class SpinnerPipeline:
    """f(A_k ... A_2 A_1 x): a chain of spinner blocks + pointwise f.

    ``blocks[i+1].n`` must equal ``blocks[i].m`` (validated). The
    nonlinearity ``f`` applies ONCE, after the last block, fused into
    that block's kernel dispatch whenever the registry maps it onto a
    kernel epilogue.
    """
    blocks: Tuple[SpinnerBlock, ...] = (SpinnerBlock(),)
    f: str = "identity"

    def __post_init__(self):
        if isinstance(self.blocks, list):         # tolerate list literals
            object.__setattr__(self, "blocks", tuple(self.blocks))
        if not self.blocks:
            raise ValueError("pipeline needs at least one block")
        for a, b in zip(self.blocks, self.blocks[1:]):
            if b.n != a.m:
                raise ValueError(
                    f"block chain mismatch: block out dim {a.m} feeds "
                    f"block in dim {b.n}")
        nonlinearity(self.f)                      # raises on unknown f

    # --- shape / accounting -------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self.blocks)

    @property
    def n_in(self) -> int:
        return self.blocks[0].n

    @property
    def m_out(self) -> int:
        return self.blocks[-1].m

    @property
    def out_dim(self) -> int:
        return self.m_out * nonlinearity(self.f).out_mult

    @property
    def budget(self) -> int:
        return sum(b.budget for b in self.blocks)

    @property
    def storage(self) -> int:
        return sum(b.storage for b in self.blocks)

    @property
    def flops(self) -> float:
        return sum(b.flops for b in self.blocks)

    def with_f(self, f: str) -> "SpinnerPipeline":
        """Same blocks, different fused nonlinearity."""
        return self if f == self.f else replace(self, f=f)

    # --- protocol -----------------------------------------------------------

    def init(self, rng: jax.Array, dtype=jnp.float32) -> Params:
        """Tuple of per-block param dicts (a pytree). Single-block
        pipelines consume ``rng`` exactly like the legacy pmodel.init, so
        fixed-seed results are reproducible across the API migration."""
        if len(self.blocks) == 1:
            return (self.blocks[0].init(rng, dtype),)
        keys = jax.random.split(rng, len(self.blocks))
        return tuple(b.init(k, dtype) for b, k in zip(self.blocks, keys))

    def block_params(self, params) -> Params:
        """Validated per-block params tuple (a bare dict is accepted for
        1-block pipelines — the legacy single-P-model layout)."""
        if isinstance(params, dict):              # legacy single-block dict
            if len(self.blocks) != 1:
                raise ValueError(
                    f"{len(self.blocks)}-block pipeline got a single param "
                    "dict; pass the per-block tuple from pipeline.init")
            return (params,)
        params = tuple(params)
        if len(params) != len(self.blocks):
            raise ValueError(f"expected {len(self.blocks)} per-block param "
                             f"dicts, got {len(params)}")
        return params

    def apply(self, params: Sequence[Dict[str, jax.Array]], x: jax.Array, *,
              y_scale: float = 1.0, out_scale: float = 1.0,
              grouped: bool = False,
              use_pallas: Optional[bool] = None) -> jax.Array:
        """(..., n_in) -> (..., out_dim):  f(y_scale . A_k...A_1 x) . out_scale.

        ``grouped=True``: x is (G, ..., n_in) and every param leaf carries
        a leading group axis G (per-head pipelines run as one fused
        dispatch per block). One spinner_project dispatch per block; the
        nonlinearity (and both scales) fuse into the LAST block's kernel
        whenever its registry entry maps onto a kernel epilogue — a
        1-block pipeline is exactly the PR-2 fused hot path.
        """
        params = self.block_params(params)
        nl = nonlinearity(self.f)
        # exp's subtrahend is the PIPELINE input norm; the kernel computes
        # it from its own input tile, valid only when that tile IS x.
        fuse = nl.epilogue is not None and \
            (len(self.blocks) == 1 or not nl.needs_input)
        x0 = x
        for i, (blk, p) in enumerate(zip(self.blocks, params)):
            if i < len(self.blocks) - 1:
                x = blk.apply(p, x, grouped=grouped, use_pallas=use_pallas)
            elif fuse:
                x = blk.apply(p, x, epilogue=nl.epilogue, y_scale=y_scale,
                              out_scale=out_scale, grouped=grouped,
                              use_pallas=use_pallas)
            else:
                y = blk.apply(p, x, y_scale=y_scale, grouped=grouped,
                              use_pallas=use_pallas)
                if nl.epilogue is not None:
                    # builtin pushed out of the kernel (exp at depth > 1):
                    # share the kernel's epilogue semantics exactly, with
                    # the PIPELINE input supplying exp's subtrahend
                    from repro.kernels import ref as kref
                    x = kref._spinner_epilogue(y, x0, nl.epilogue, out_scale)
                else:
                    sq = None
                    if nl.needs_input:
                        xf = x0.astype(jnp.float32)
                        sq = 0.5 * jnp.sum(xf * xf, axis=-1, keepdims=True)
                    y = nl.fn(y, sq)
                    x = y if out_scale == 1.0 \
                        else y * jnp.asarray(out_scale, y.dtype)
        return x

    def materialize(self, params: Sequence[Dict[str, jax.Array]]) -> jax.Array:
        """Dense (m_out, n_in) product  A_k ... A_2 A_1  (oracle; the
        nonlinearity is NOT applied — it is pointwise on the output)."""
        params = self.block_params(params)
        a = self.blocks[0].materialize(params[0])
        for blk, p in zip(self.blocks[1:], params[1:]):
            a = blk.materialize(p) @ a
        return a

    def row_gaussianity_moments(self, params) -> Tuple[
            Tuple[jax.Array, jax.Array], ...]:
        """PER-BLOCK (mean, var) row diagnostics (Def. 1 applies blockwise;
        the product of independent spinners is not row-Gaussian)."""
        params = self.block_params(params)
        return tuple(b.row_gaussianity_moments(p)
                     for b, p in zip(self.blocks, params))



# ---------------------------------------------------------------------------
# zero-leaf pytree registration: specs flow through jit/vmap/tree_map
# ---------------------------------------------------------------------------

def _register_spec_pytree(cls):
    jax.tree_util.register_pytree_node(
        cls, lambda s: ((), s), lambda aux, _: aux)


_register_spec_pytree(SpinnerBlock)
_register_spec_pytree(SpinnerPipeline)


def as_pipeline(obj) -> SpinnerPipeline:
    """SpinnerPipeline passthrough; anything carrying an equivalent
    ``.pipeline`` property (the legacy ``PModelSpec``) converts with a
    ``DeprecationWarning``. The shared entry point of the features /
    estimators migration path."""
    if isinstance(obj, SpinnerPipeline):
        return obj
    pipe = getattr(obj, "pipeline", None)
    if isinstance(pipe, SpinnerPipeline):
        warnings.warn(
            f"passing {type(obj).__name__} here is deprecated; pass a "
            "spinner.SpinnerPipeline (see core/README.md migration table)",
            DeprecationWarning, stacklevel=3)
        return pipe
    raise TypeError(f"expected SpinnerPipeline (or legacy PModelSpec), "
                    f"got {type(obj).__name__}")


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def single(kind: str = "circulant", m: int = 128, n: int = 128, *,
           r: int = 1, use_hd: bool = True, ldr_nnz: int = 4,
           f: str = "identity", seeded: bool = False) -> SpinnerPipeline:
    """The paper's P-model: one structured block + f."""
    return SpinnerPipeline(
        (SpinnerBlock(kind, m, n, r, use_hd, ldr_nnz, seeded=seeded),), f)


def chain(blocks: Sequence[SpinnerBlock], f: str = "identity"
          ) -> SpinnerPipeline:
    return SpinnerPipeline(tuple(blocks), f)


def hd_chain(kind: str = "circulant", n: int = 128, m: int = 128,
             depth: int = 3, *, r: int = 1, ldr_nnz: int = 4,
             use_hd: bool = True, f: str = "identity",
             seeded: bool = False) -> SpinnerPipeline:
    """Stacked construction  HD_k ... HD_2 HD_1  (TripleSpin at depth 3):
    ``depth - 1`` square (n -> n) spinner blocks followed by one
    (n -> m) block, every block carrying its own preconditioner
    (``use_hd=False`` drops the HD step, e.g. non-pow2 dims).

    The square blocks are scaled 1/sqrt(n) (variance-preserving: their
    rows act like ~N(0, I/n) rotations), so only the FINAL block is a
    raw row-Gaussian projection — the whole stack keeps the Def.-1
    calibration every kernel estimator relies on."""
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    inv = 1.0 / math.sqrt(n)
    sq = tuple(SpinnerBlock(kind, n, n, r, use_hd, ldr_nnz, scale=inv,
                            seeded=seeded)
               for _ in range(depth - 1))
    return SpinnerPipeline(
        sq + (SpinnerBlock(kind, m, n, r, use_hd, ldr_nnz, seeded=seeded),), f)


# ---------------------------------------------------------------------------
# (de)serialization — checkpointable pipeline configs
# ---------------------------------------------------------------------------

_CONFIG_VERSION = 1


def to_config(pipe: SpinnerPipeline) -> Dict[str, Any]:
    """JSON-able dict capturing the full pipeline spec (not the params —
    those are a pytree for the checkpoint manager)."""
    return {"version": _CONFIG_VERSION, "f": pipe.f,
            "blocks": [asdict(b) for b in pipe.blocks]}


def from_config(cfg: Dict[str, Any]) -> SpinnerPipeline:
    if cfg.get("version") != _CONFIG_VERSION:
        raise ValueError(f"unsupported pipeline config version: "
                         f"{cfg.get('version')!r}")
    return SpinnerPipeline(tuple(SpinnerBlock(**b) for b in cfg["blocks"]),
                           cfg["f"])


def dumps(pipe: SpinnerPipeline) -> str:
    return json.dumps(to_config(pipe), sort_keys=True)


def loads(s: str) -> SpinnerPipeline:
    return from_config(json.loads(s))
