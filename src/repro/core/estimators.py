"""Closed-form kernels Lambda_f and their structured estimators (paper Sec 2.1).

Closed forms (k=2, beta=product, Psi=mean, r ~ N(0, I_n)):

  identity   E[<r,v1><r,v2>]            = <v1, v2>               (JL / ex. 1)
  heaviside  E[1{y1>=0} 1{y2>=0}]       = (pi - theta) / (2 pi)  (ex. 2*)
  sign       E[sgn(y1) sgn(y2)]         = 1 - 2 theta / pi
  relu       E[relu(y1) relu(y2)]       = |v1||v2| (sin t + (pi-t) cos t)/(2 pi)
                                          (arc-cosine b=1, Cho & Saul)
  trig       E[cos((y1-y2)/s)]          = exp(-||v1-v2||^2/(2 s^2))  (Gaussian)
  softmax    E[phi+(v1) phi+(v2)]       = exp(<v1, v2>)

(*) The paper states theta/(2pi) for the angular example; the product-form
expectation is (pi-theta)/(2pi) — theta/(2pi) is half the Hamming/hashing
distance E[(h1-h2)^2]/2. Both are exposed; tests pin both numerically.
"""
from __future__ import annotations

import math
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from . import features, spinner
from .spinner import SpinnerPipeline


def angle(v1: jax.Array, v2: jax.Array) -> jax.Array:
    c = jnp.sum(v1 * v2, -1) / (
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1))
    return jnp.arccos(jnp.clip(c, -1.0, 1.0))


# --- exact closed forms -------------------------------------------------------

def k_inner(v1, v2):
    return jnp.sum(v1 * v2, -1)


def k_angular_product(v1, v2):
    """E[h(y1) h(y2)], h = heaviside:  (pi - theta)/(2 pi)."""
    return (math.pi - angle(v1, v2)) / (2 * math.pi)


def k_angular_paper(v1, v2):
    """theta/(2 pi) — the quantity the paper's ex. 2 names Lambda_f."""
    return angle(v1, v2) / (2 * math.pi)


def k_sign(v1, v2):
    return 1.0 - 2.0 * angle(v1, v2) / math.pi


def k_arccos1(v1, v2):
    """Arc-cosine kernel b=1 (Cho & Saul '09): |v1||v2| J1(theta)/(2 pi),
    J1(t) = sin t + (pi - t) cos t."""
    t = angle(v1, v2)
    n1 = jnp.linalg.norm(v1, axis=-1)
    n2 = jnp.linalg.norm(v2, axis=-1)
    return n1 * n2 * (jnp.sin(t) + (math.pi - t) * jnp.cos(t)) / (2 * math.pi)


def k_gaussian(v1, v2, sigma: float = 1.0):
    d2 = jnp.sum((v1 - v2) ** 2, -1)
    return jnp.exp(-d2 / (2.0 * sigma ** 2))


def k_softmax(v1, v2):
    return jnp.exp(jnp.sum(v1 * v2, -1))


EXACT: Dict[str, Callable] = {
    "identity": k_inner,
    "heaviside": k_angular_product,
    "sign": k_sign,
    "relu": k_arccos1,
    "trig": k_gaussian,
    "softmax": k_softmax,
}


# --- structured estimators ------------------------------------------------------

def estimate(pipe: SpinnerPipeline, params, fname: str, v1: jax.Array,
             v2: jax.Array, sigma: float = 1.0) -> jax.Array:
    """Lambda_f^struct(v1, v2) = <phi(v1), phi(v2)>  (eq. 13).

    ``pipe``: a SpinnerPipeline of any depth (legacy PModelSpec still
    accepted, deprecated — see spinner.as_pipeline).
    """
    pipe = spinner.as_pipeline(pipe)
    if fname == "trig":
        p1 = features.phi_trig(pipe, params, v1, sigma)
        p2 = features.phi_trig(pipe, params, v2, sigma)
    elif fname == "softmax":
        p1 = features.phi_softmax_pos(pipe, params, v1, stabilize=False)
        p2 = features.phi_softmax_pos(pipe, params, v2, stabilize=False)
    else:
        p1 = features.phi_scalar(pipe, params, v1, fname)
        p2 = features.phi_scalar(pipe, params, v2, fname)
    return jnp.sum(p1 * p2, -1)


def exact(fname: str, v1, v2, sigma: float = 1.0):
    if fname == "trig":
        return k_gaussian(v1, v2, sigma)
    return EXACT[fname](v1, v2)


def mc_error(rng: jax.Array, pipe: SpinnerPipeline, fname: str, v1, v2,
             n_trials: int = 32, sigma: float = 1.0):
    """Mean absolute estimation error over fresh pipeline draws (benchmark)."""
    pipe = spinner.as_pipeline(pipe)

    def one(k):
        params = pipe.init(k)
        return jnp.abs(estimate(pipe, params, fname, v1, v2, sigma)
                       - exact(fname, v1, v2, sigma))
    errs = jax.vmap(one)(jax.random.split(rng, n_trials))
    return errs.mean(), errs.std()
