"""Hadamard transforms and the paper's Step-1 preprocessing  D1 H D0.

``H`` is the L2-normalized Sylvester-Hadamard matrix (n a power of two),
``D0``/``D1`` independent random +/-1 diagonals (paper Sec 2.3 Step 1).

Two FWHT realizations:
* ``fwht``       — log2(n)-stage butterfly (pure jnp; the classic algorithm)
* ``fwht_kron``  — 2-factor Kronecker form  H_n = H_a (x) H_b  computed as
                   two dense matmuls  H_a . mat(x) . H_b. This is the
                   TPU-native form (MXU-friendly); the Pallas kernel
                   (kernels/fwht.py) implements exactly this decomposition.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@lru_cache(maxsize=32)
def _hadamard_np(n: int) -> np.ndarray:
    """Unnormalized Sylvester Hadamard matrix as a cached numpy array."""
    assert is_pow2(n), f"Hadamard order must be a power of two, got {n}"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def hadamard(n: int, dtype=jnp.float32, normalized: bool = True) -> jax.Array:
    h = jnp.asarray(_hadamard_np(n), dtype)
    return h / jnp.asarray(math.sqrt(n), dtype) if normalized else h


def fwht(x: jax.Array, normalized: bool = True) -> jax.Array:
    """Fast Walsh-Hadamard transform along the last axis (n = 2^k).

    Classic in-place butterfly, expressed as log2(n) reshape/stack steps
    (each step is a static jnp op; the python loop unrolls at trace time).
    """
    n = x.shape[-1]
    assert is_pow2(n), f"fwht needs power-of-two length, got {n}"
    lead = x.shape[:-1]
    h = 1
    while h < n:
        x = x.reshape(*lead, n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    x = x.reshape(*lead, n)
    if normalized:
        x = x * jnp.asarray(1.0 / math.sqrt(n), x.dtype)
    return x


def kron_factors(n: int) -> Tuple[int, int]:
    """Balanced split n = a * b with both powers of two (a >= b)."""
    assert is_pow2(n)
    k = n.bit_length() - 1
    ka = (k + 1) // 2
    return 1 << ka, 1 << (k - ka)


def fwht_kron(x: jax.Array, normalized: bool = True) -> jax.Array:
    """MXU-form FWHT:  H_n x = vec( H_a . mat(x) . H_b )  with n = a*b.

    mat(x) is the row-major (a, b) reshape. Matches ``fwht`` exactly
    (same Sylvester ordering) because H_{2^{p+q}} = H_{2^p} (x) H_{2^q}.
    """
    n = x.shape[-1]
    a, b = kron_factors(n)
    lead = x.shape[:-1]
    ha = hadamard(a, x.dtype, normalized=False)
    hb = hadamard(b, x.dtype, normalized=False)
    xm = x.reshape(*lead, a, b)
    y = jnp.einsum("pa,...ab,bq->...pq", ha, xm, hb)
    y = y.reshape(*lead, n)
    if normalized:
        y = y * jnp.asarray(1.0 / math.sqrt(n), x.dtype)
    return y


def sample_signs(rng: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.rademacher(rng, (n,), dtype)


def hd_preprocess(x: jax.Array, d0: jax.Array, d1: jax.Array,
                  use_kron: bool = False) -> jax.Array:
    """Paper Step 1:  x -> D1 . H . D0 . x  (normalized H; isometry)."""
    f = fwht_kron if use_kron else fwht
    return d1 * f(d0 * x)


def pad_pow2(x: jax.Array) -> jax.Array:
    """Zero-pad the last axis to the next power of two (for HD preproc)."""
    n = x.shape[-1]
    p = next_pow2(n)
    if p == n:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p - n)])
