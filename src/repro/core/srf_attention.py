"""Structured random-feature (SRF) attention — the paper's mechanism as a
first-class attention layer.

softmax(q k^T / sqrt(d)) V  is approximated by linear attention over the
paper's nonlinear embedding:   phi(q) [ phi(k)^T V ] / phi(q) [ phi(k)^T 1 ]
with phi(x) = f(A D1 H D0 x)/sqrt(m) and A a structured P-model matrix
(circulant / toeplitz / ldr / unstructured — the budget-of-randomness knob).

Complexities (L = seq, d = head dim, m = features):
  full softmax:  O(L^2 d)  time,  O(L) KV cache per head
  SRF:           O(L m d)  time,  O(m d) STATE per head (no KV cache)

The O(m d) state is the paper's space-complexity story applied to serving,
and is what makes the 524k-token decode cells feasible.

Shapes: q,k: (B, H, L, d)   v: (B, H, L, dv)   phi: (B, H, L, m).
GQA is handled by the caller (q-heads grouped onto kv-heads before entry).
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import features, spinner


@dataclass(frozen=True)
class SRFConfig:
    kind: str = "circulant"     # structured class for the projection
    n_features: int = 256       # m
    head_dim: int = 128         # n (power of two -> HD preconditioner valid)
    feature: str = "softmax_pos"  # softmax_pos | relu | trig
    use_hd: bool = True
    r: int = 1                  # displacement rank for ldr
    chunk: int = 128            # causal chunk length
    depth: int = 1              # spinner blocks (depth > 1: stacked d -> d
                                # blocks before the d -> m projection)
    seeded: bool = False        # zero-storage projections (params are one
                                # uint32 seed per head per block)

    @property
    def pipeline(self) -> spinner.SpinnerPipeline:
        """The per-head embedding as a SpinnerPipeline (depth blocks);
        leading square blocks are 1/sqrt(d)-scaled (variance-preserving,
        see spinner.hd_chain) so softmax features stay calibrated."""
        return spinner.hd_chain(self.kind, n=self.head_dim,
                                m=self.n_features, depth=self.depth,
                                r=self.r, use_hd=self.use_hd,
                                seeded=self.seeded)

    @property
    def spec(self):
        """DEPRECATED legacy 1-block spec; use ``pipeline``."""
        warnings.warn("SRFConfig.spec is deprecated; use SRFConfig.pipeline",
                      DeprecationWarning, stacklevel=2)
        from .pmodel import PModelSpec
        return PModelSpec(kind=self.kind, m=self.n_features, n=self.head_dim,
                          r=self.r, use_hd=self.use_hd)

    @property
    def feat_dim(self) -> int:
        return 2 * self.n_features if self.feature == "trig" else self.n_features


def init(rng: jax.Array, cfg: SRFConfig, n_kv_heads: int,
         dtype=jnp.float32) -> Tuple[Dict[str, jax.Array], ...]:
    """Per-kv-head independent pipelines: a tuple of per-block param dicts,
    every leaf with a leading head axis."""
    keys = jax.random.split(rng, n_kv_heads)
    pipe = cfg.pipeline
    return jax.vmap(lambda k: pipe.init(k, dtype))(keys)


def _fold_embed(params, embed_seeds: jax.Array, h: int):
    """Personalize per-head seed params with per-request embed seeds.

    Each block's ``{"seed": (H,)}`` becomes ``{"seed": (H*B,)}``: seed 0 is
    the sentinel for "base projection" (the head seed passes through
    unfolded), any other value derives an independent per-(head, request)
    sub-stream via ``seedgen.fold_seed``. One ``jnp.where`` keeps mixed
    batches (some personalized, some base) in a single jit program."""
    from repro.kernels import seedgen                    # deferred
    e = jnp.asarray(embed_seeds, jnp.uint32)             # (B,)

    def fold_leaf(hs):                                   # (H,) -> (H*B,)
        folded = seedgen.fold_seed(hs[:, None], e[None, :])
        return jnp.where(e[None, :] == 0, hs[:, None], folded).reshape(-1)

    return tuple({"seed": fold_leaf(p["seed"])} for p in params)


def feature_map(cfg: SRFConfig, params, x: jax.Array, is_query: bool,
                embed_seeds=None) -> jax.Array:
    """(B, H, L, d) -> (B, H, L, feat_dim). Softmax-kernel scaling d^-1/4 is
    folded in so phi(q).phi(k) ~ exp(q.k/sqrt(d)) (up to a global constant
    that cancels in the normalizer).

    All H per-head pipelines run as ONE grouped fused-spinner dispatch per
    block (kernels.ops.spinner_project: HD + implicit-tile projection + f
    in a single pass) instead of a vmap of per-head projection pipelines.

    ``embed_seeds``: optional (B,) uint32 per-request projection seeds
    (seeded mode only; 0 = base projection). When given, groups become
    per-(head, request) so every request runs its own personalized
    zero-storage projection — still one dispatch per block, no
    materialized weights."""
    scale = cfg.head_dim ** -0.25
    b, h, l, d = x.shape
    if embed_seeds is not None:
        if not cfg.seeded:
            raise ValueError("embed_seeds requires SRFConfig.seeded=True")
        # (head, request)-major groups: G = H*B, one seed per group
        xg = x.transpose(1, 0, 2, 3).reshape(h * b, l, d)
        params = _fold_embed(params, embed_seeds, h)
    else:
        xg = x.transpose(1, 0, 2, 3).reshape(h, b * l, d)  # head-major groups
    pipe = cfg.pipeline

    if cfg.feature == "softmax_pos":
        phi = features.phi_softmax_pos(pipe, params, xg, scale=scale,
                                       stabilize=is_query, grouped=True)
    elif cfg.feature == "trig":
        phi = features.phi_trig(pipe, params, xg * scale, grouped=True)
    elif cfg.feature == "relu":
        inv = 1.0 / math.sqrt(cfg.n_features)
        phi = pipe.with_f("relu").apply(params, xg * scale, out_scale=inv,
                                        grouped=True) + 1e-6 * inv
    else:
        raise ValueError(cfg.feature)
    return phi.reshape(h, b, l, -1).transpose(1, 0, 2, 3)


def attention_noncausal(phi_q: jax.Array, phi_k: jax.Array, v: jax.Array,
                        eps: float = 1e-6) -> jax.Array:
    """Encoder (bidirectional) SRF attention."""
    kv = jnp.einsum("bhlm,bhld->bhmd", phi_k, v)
    z = jnp.sum(phi_k, axis=-2)                          # (B,H,m)
    num = jnp.einsum("bhlm,bhmd->bhld", phi_q, kv)
    den = jnp.einsum("bhlm,bhm->bhl", phi_q, z)
    return num / (den[..., None] + eps)


def attention_causal(cfg: SRFConfig, phi_q: jax.Array, phi_k: jax.Array,
                     v: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Causal SRF attention via chunked prefix-state scan.

    O(L m (d + C)) with chunk C; state carried between chunks is the
    paper's O(m d) object.
    """
    b, h, l, m = phi_q.shape
    dv = v.shape[-1]
    c = min(cfg.chunk, l)
    if l % c:                      # zero-pad to a chunk multiple (zero phi_k
        pad = c - l % c            # rows are inert; padded outputs sliced off)
        phi_q, phi_k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
                           for t in (phi_q, phi_k, v))
        return attention_causal(cfg, phi_q, phi_k, v, eps)[..., :l, :]
    nc = l // c

    pq = phi_q.reshape(b, h, nc, c, m).transpose(2, 0, 1, 3, 4)
    pk = phi_k.reshape(b, h, nc, c, m).transpose(2, 0, 1, 3, 4)
    vv = v.reshape(b, h, nc, c, dv).transpose(2, 0, 1, 3, 4)
    tri = jnp.tril(jnp.ones((c, c), phi_q.dtype))

    def step(carry, inp):
        s, z = carry                       # (B,H,m,dv), (B,H,m)
        q_c, k_c, v_c = inp
        attn = jnp.einsum("bhim,bhjm->bhij", q_c, k_c) * tri
        num = jnp.einsum("bhij,bhjd->bhid", attn, v_c) \
            + jnp.einsum("bhim,bhmd->bhid", q_c, s)
        den = jnp.einsum("bhij->bhi", attn) \
            + jnp.einsum("bhim,bhm->bhi", q_c, z)
        out = num / (den[..., None] + eps)
        s = s + jnp.einsum("bhjm,bhjd->bhmd", k_c, v_c)
        z = z + jnp.sum(k_c, axis=-2)
        return (s, z), out

    s0 = jnp.zeros((b, h, m, dv), phi_q.dtype)
    z0 = jnp.zeros((b, h, m), phi_q.dtype)
    (_, _), outs = jax.lax.scan(step, (s0, z0), (pq, pk, vv))
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, h, l, dv)


def prefill_state(phi_k: jax.Array, v: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Build the decode state from a processed prompt: S = phi_k^T v, z."""
    s = jnp.einsum("bhlm,bhld->bhmd", phi_k, v)
    z = jnp.sum(phi_k, axis=-2)
    return s, z


def decode_step(state: Tuple[jax.Array, jax.Array], phi_q: jax.Array,
                phi_k: jax.Array, v_new: jax.Array, eps: float = 1e-6
                ) -> Tuple[Tuple[jax.Array, jax.Array], jax.Array]:
    """One-token decode. phi_q/phi_k: (B,H,1,m), v_new: (B,H,1,dv).

    State update BEFORE readout (the new token attends to itself)."""
    s, z = state
    s = s + jnp.einsum("bhlm,bhld->bhmd", phi_k, v_new)
    z = z + jnp.sum(phi_k, axis=-2)
    num = jnp.einsum("bhlm,bhmd->bhld", phi_q, s)
    den = jnp.einsum("bhlm,bhm->bhl", phi_q, z)
    return (s, z), num / (den[..., None] + eps)


def reference_softmax(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True) -> jax.Array:
    """Exact softmax attention (oracle for SRF quality tests)."""
    d = q.shape[-1]
    logits = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(d)
    if causal:
        l = q.shape[-2]
        mask = jnp.tril(jnp.ones((l, l), bool))
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhij,bhjd->bhid", w, v)
