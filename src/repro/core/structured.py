"""Structured Gaussian matrices of the paper's P-model (Sec 2.2).

Every structured class here is a concrete P-model: a budget of randomness
``g`` (t i.i.d. N(0,1) values, t << m*n) plus an implicit sequence of
matrices P_i with ``a^i = g . P_i`` as the i-th row of the projection.

Supported kinds
---------------
``unstructured``     t = m*n     the fully random baseline (P_i = selector)
``circulant``        t = n       rows are right-shifts of g           (paper eq. 7)
``skew_circulant``   t = n       wrap-around entries negated
``toeplitz``         t = n+m-1   constant diagonals                   (paper eq. 9)
``hankel``           t = n+m-1   constant anti-diagonals
``ldr``              t = r*n     sum_{i<=r} Z_1(g^i) Z_{-1}(h^i)      (paper eq. 11)

Two execution paths are provided and cross-tested:

* ``matvec``       — fast path. O(n log n) via (real) FFT; this is the
                     paper's CPU/GPU algorithm and the jnp reference.
* ``materialize``  — O(mn) dense matrix, used as the oracle in tests and
                     by the Pallas implicit-tile kernels (kernels/circulant.py)
                     which regenerate tiles from g on the fly in VMEM.

All functions operate on the LAST axis of ``x`` and support arbitrary
leading batch axes. For m > n, circulant / skew_circulant / ldr matrices
are BLOCK-STACKED: ceil(m/n) independent structured blocks share one
input dimension (the multi-block construction of the paper's companion
[12], Choromanski & Sindhwani '16); toeplitz/hankel support any m natively
(t = n + m - 1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

KINDS = ("unstructured", "circulant", "skew_circulant", "toeplitz", "hankel", "ldr")


def n_blocks(kind: str, m: int, n: int) -> int:
    """Independent structured blocks stacked to reach m rows."""
    if kind in ("circulant", "skew_circulant", "ldr"):
        return -(-m // n)  # ceil
    return 1


def budget(kind: str, m: int, n: int, r: int = 1) -> int:
    """Number t of i.i.d. Gaussians consumed ('budget of randomness')."""
    b = n_blocks(kind, m, n)
    if kind == "unstructured":
        return m * n
    if kind in ("circulant", "skew_circulant"):
        return b * n
    if kind in ("toeplitz", "hankel"):
        return n + m - 1
    if kind == "ldr":
        return b * r * n
    raise ValueError(f"unknown structured kind: {kind}")


def init(rng: jax.Array, kind: str, m: int, n: int, r: int = 1,
         ldr_nnz: int = 4, dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Sample the generator parameters for one structured matrix.

    For ``ldr`` also samples the paper's sparse +/-1/sqrt(a r) h-vectors
    (a = ldr_nnz nonzeros per column, Sec 2.2 item 4).

    circulant/skew/ldr generators carry a leading block axis (b, ...);
    b = ceil(m/n) (b = 1 when m <= n).
    """
    b = n_blocks(kind, m, n)
    if kind == "unstructured":
        g = jax.random.normal(rng, (m, n), dtype)
        return {"g": g}
    if kind in ("circulant", "skew_circulant"):
        return {"g": jax.random.normal(rng, (b, n), dtype)}
    if kind in ("toeplitz", "hankel"):
        return {"g": jax.random.normal(rng, (n + m - 1,), dtype)}
    if kind == "ldr":
        kg, kh_idx, kh_sign = jax.random.split(rng, 3)
        g = jax.random.normal(kg, (b, r, n), dtype)
        # h^i: ldr_nnz random nonzero dims, values +/- 1/sqrt(ldr_nnz * r)
        idx = jax.random.randint(kh_idx, (b, r, ldr_nnz), 0, n)
        sign = jax.random.rademacher(kh_sign, (b, r, ldr_nnz), dtype)
        h = jnp.zeros((b, r, n), dtype)
        val = sign / jnp.asarray(math.sqrt(ldr_nnz * r), dtype)
        bi = jnp.arange(b)[:, None, None]
        ri = jnp.arange(r)[None, :, None]
        h = h.at[bi, ri, idx].set(val)
        return {"g": g, "h": h}
    raise ValueError(f"unknown structured kind: {kind}")


# ---------------------------------------------------------------------------
# Dense materialization (oracle path)
# ---------------------------------------------------------------------------

def _circulant_dense(g: jax.Array, m: int) -> jax.Array:
    """A[i, j] = g[(j - i) mod n]  (row i is g right-shifted by i; eq. 7)."""
    n = g.shape[-1]
    i = jnp.arange(m)[:, None]
    j = jnp.arange(n)[None, :]
    return g[(j - i) % n]


def _skew_circulant_dense(g: jax.Array, m: int) -> jax.Array:
    """Like circulant but wrapped entries (j < i) are negated."""
    n = g.shape[-1]
    i = jnp.arange(m)[:, None]
    j = jnp.arange(n)[None, :]
    sign = jnp.where(j - i < 0, -1.0, 1.0).astype(g.dtype)
    return sign * g[(j - i) % n]


def _toeplitz_dense(g: jax.Array, m: int, n: int) -> jax.Array:
    """Constant diagonals (eq. 9): A[i, j] = g[j - i]  with
    g indexed as: first row g[0..n-1], first column g[0], g[n], g[n+1], ...
    i.e. diagonal offset d = j - i maps to g[d] for d >= 0 and g[n - 1 - d]
    for d < 0 (so index n-1+|d| = n-1-d)."""
    i = jnp.arange(m)[:, None]
    j = jnp.arange(n)[None, :]
    d = j - i
    idx = jnp.where(d >= 0, d, n - 1 - d)
    return g[idx]


def _hankel_dense(g: jax.Array, m: int, n: int) -> jax.Array:
    """Constant anti-diagonals: A[i, j] = g[i + j]."""
    i = jnp.arange(m)[:, None]
    j = jnp.arange(n)[None, :]
    return g[i + j]


def _ldr_dense(g: jax.Array, h: jax.Array, m: int, n: int) -> jax.Array:
    """sum_i Z_1(g^i) Z_{-1}(h^i)  (eq. 11).

    Z_1(v): circulant with first COLUMN v (shift-down with wrap, f=+1);
    Z_{-1}(v): skew version (wrapped entries negated).
    """
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    # Z_f(v)[i, j] = v[(i - j) mod n] * (f if i - j < 0 else 1)
    z1 = g[..., (i - j) % n]                                   # (r, n, n)
    sgn = jnp.where(i - j < 0, -1.0, 1.0).astype(h.dtype)
    zm1 = sgn * h[..., (i - j) % n]                            # (r, n, n)
    a = jnp.einsum("rik,rkj->ij", z1, zm1)
    return a[:m]


def materialize(kind: str, params: Dict[str, jax.Array], m: int, n: int) -> jax.Array:
    """Dense (m, n) matrix A of the P-model — oracle for all fast paths."""
    g = params["g"]
    if kind == "unstructured":
        return g
    if kind == "circulant":
        blocks = jax.vmap(lambda gb: _circulant_dense(gb, n))(g)
        return blocks.reshape(-1, n)[:m]
    if kind == "skew_circulant":
        blocks = jax.vmap(lambda gb: _skew_circulant_dense(gb, n))(g)
        return blocks.reshape(-1, n)[:m]
    if kind == "toeplitz":
        return _toeplitz_dense(g, m, n)
    if kind == "hankel":
        return _hankel_dense(g, m, n)
    if kind == "ldr":
        blocks = jax.vmap(lambda gb, hb: _ldr_dense(gb, hb, n, n))(g, params["h"])
        return blocks.reshape(-1, n)[:m]
    raise ValueError(f"unknown structured kind: {kind}")


# ---------------------------------------------------------------------------
# Fast FFT path (the paper's O(n log n) algorithm; jnp reference on TPU/CPU)
# ---------------------------------------------------------------------------

def _f32(x: jax.Array) -> jax.Array:
    """FFT kernels need fp32; bf16 inputs are upcast for the transform."""
    return x.astype(jnp.float32) if x.dtype in (jnp.bfloat16, jnp.float16) \
        else x


def _circ_corr(x: jax.Array, g: jax.Array) -> jax.Array:
    """y[i] = sum_j x[..., j] g[(j - i) mod n]  via real FFT."""
    n = x.shape[-1]
    fx = jnp.fft.rfft(_f32(x), n=n)
    fg = jnp.fft.rfft(_f32(g), n=n)
    y = jnp.fft.irfft(fx * jnp.conj(fg), n=n)
    return y.astype(x.dtype)


def _circ_conv(x: jax.Array, v: jax.Array) -> jax.Array:
    """y[i] = sum_j v[(i - j) mod n] x[..., j]  = (v * x) circular convolution."""
    n = x.shape[-1]
    fx = jnp.fft.rfft(_f32(x), n=n)
    fv = jnp.fft.rfft(_f32(v), n=n)
    y = jnp.fft.irfft(fx * fv, n=n)
    return y.astype(x.dtype)


def _skew_modulation(n: int, dtype=jnp.complex64) -> jax.Array:
    """d[j] = exp(i pi j / n): diagonal similarity turning skew-circulant
    into circulant: S(v) = conj(D) C'(...) D."""
    j = jnp.arange(n)
    return jnp.exp(1j * jnp.pi * j / n).astype(dtype)


def _skew_circ_matvec(x: jax.Array, g: jax.Array, m: int) -> jax.Array:
    """Rows of the skew-circulant A[i,j] = sgn(j-i) g[(j-i) mod n], first m.

    Uses the modulation identity: with d_j = e^{i pi j / n},
    A = conj(D) B D where B is the plain circulant of (g_j d_j).
    """
    n = x.shape[-1]
    d = _skew_modulation(n)
    gx = _f32(x).astype(jnp.complex64) * d
    gg = _f32(g).astype(jnp.complex64) * d
    fy = jnp.fft.fft(gx, n=n) * jnp.conj(jnp.fft.fft(gg, n=n))
    y = jnp.fft.ifft(fy, n=n) * jnp.conj(d)
    return y.real[..., :m].astype(x.dtype)


def _toeplitz_matvec(x: jax.Array, g: jax.Array, m: int, n: int) -> jax.Array:
    """Toeplitz matvec by embedding into a circulant of size p = n + m.

    A[i, j] = gen(j - i) with gen(d) = g[d] (d>=0), g[n-1-d] (d<0).
    Build c of length p with c[k] = gen(k) for k in [0, n-1] and
    c[p - k] = gen(-k) for k in [1, m-1]; then y = first m of circ-corr.
    """
    p = n + m
    c = jnp.zeros((p,), g.dtype)
    c = c.at[:n].set(g[:n])                       # diagonals d = 0..n-1
    if m > 1:
        k = jnp.arange(1, m)
        c = c.at[p - k].set(g[n - 1 + k])         # d = -k -> g[n-1+k]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p - n)])
    y = _circ_corr(xp, c)
    return y[..., :m]


def matvec(kind: str, params: Dict[str, jax.Array], x: jax.Array, m: int) -> jax.Array:
    """Fast structured matvec: (..., n) -> (..., m). FFT path (paper's alg)."""
    g = params["g"]
    n = x.shape[-1]
    if kind == "unstructured":
        return jnp.einsum("...n,mn->...m", x, g)
    if kind == "circulant":
        y = jax.vmap(lambda gb: _circ_corr(x, gb), out_axes=-2)(g)
        return y.reshape(*x.shape[:-1], -1)[..., :m]
    if kind == "skew_circulant":
        y = jax.vmap(lambda gb: _skew_circ_matvec(x, gb, n), out_axes=-2)(g)
        return y.reshape(*x.shape[:-1], -1)[..., :m]
    if kind == "toeplitz":
        return _toeplitz_matvec(x, g, m, n)
    if kind == "hankel":
        # A[i, j] = g[i + j] = Toeplitz with reversed input:
        # sum_j g[i + j] x[j] = sum_j' gen_T(j' - i) x[n-1-j'] with g reused:
        # simply correlate reversed x against the same generator laid out as
        # T[i, j] = g[i + (n - 1 - j)]: a Toeplitz in -j. Use flip(x).
        gt = g  # length n + m - 1; T[i,j'] = g[i + n - 1 - j'] -> gen(d)=g[n-1-d]
        # Map to our toeplitz layout: gen_T(d) = g[n - 1 - d], d in [-(m-1), n-1]
        row = gt[n - 1::-1]                # d = 0..n-1  -> g[n-1-d]
        col = gt[n:]                       # d = -1..-(m-1) -> g[n-1+k]
        g2 = jnp.concatenate([row, col])
        return _toeplitz_matvec(jnp.flip(x, -1), g2, m, n)
    if kind == "ldr":
        h = params["h"]
        # y = sum_r Z_1(g^r) (Z_{-1}(h^r) x); Z_f(v)[i,j] = sgn v[(i-j) mod n]
        def one(gr, hr):
            # Z_{-1}(h) x : skew 'convolution' — rows indexed by (i - j)
            d = _skew_modulation(n)
            hx = jnp.fft.fft(_f32(x).astype(jnp.complex64) * d, n=n)
            hh = jnp.fft.fft(_f32(hr).astype(jnp.complex64) * d, n=n)
            u = (jnp.fft.ifft(hx * hh, n=n) * jnp.conj(d)).real.astype(x.dtype)
            return _circ_conv(u, gr)
        def block(gb, hb):
            return jax.vmap(one, in_axes=(0, 0), out_axes=0)(gb, hb).sum(0)
        y = jax.vmap(block, in_axes=(0, 0), out_axes=-2)(g, h)
        return y.reshape(*x.shape[:-1], -1)[..., :m]
    raise ValueError(f"unknown structured kind: {kind}")


def storage_floats(kind: str, m: int, n: int, r: int = 1) -> int:
    """Floats stored for the projection (paper's space-complexity claim)."""
    return budget(kind, m, n, r) + (r * n if kind == "ldr" else 0)


def flops_fast(kind: str, m: int, n: int, r: int = 1) -> float:
    """~FLOPs of the fast matvec path (per input vector)."""
    if kind == "unstructured":
        return 2.0 * m * n
    if kind in ("circulant", "skew_circulant"):
        return 5.0 * n * math.log2(max(n, 2)) * 3  # 3 FFTs
    if kind in ("toeplitz", "hankel"):
        p = n + m
        return 5.0 * p * math.log2(max(p, 2)) * 3
    if kind == "ldr":
        return r * 2 * 5.0 * n * math.log2(max(n, 2)) * 3
    raise ValueError(kind)
