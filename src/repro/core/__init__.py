"""Core P-model library: the paper's contribution as composable JAX modules."""
from . import coherence, estimators, features, pmodel, srf_attention, structured, transforms
from .pmodel import PModelSpec
from .srf_attention import SRFConfig

__all__ = [
    "coherence", "estimators", "features", "pmodel", "srf_attention",
    "structured", "transforms", "PModelSpec", "SRFConfig",
]
