"""Core P-model library: the paper's contribution as composable JAX modules.

The embedding API is ``spinner``: ``SpinnerBlock`` / ``SpinnerPipeline``
(frozen pytree specs with init/apply/materialize/budget protocol) plus the
kind- and nonlinearity registries. ``pmodel`` is the deprecated 1-block
shim. See core/README.md for the protocol and the migration table.
"""
from . import (coherence, estimators, features, pmodel, spinner,
               srf_attention, structured, transforms)
from .pmodel import PModelSpec
from .spinner import SpinnerBlock, SpinnerPipeline
from .srf_attention import SRFConfig

__all__ = [
    "coherence", "estimators", "features", "pmodel", "spinner",
    "srf_attention", "structured", "transforms",
    "PModelSpec", "SpinnerBlock", "SpinnerPipeline", "SRFConfig",
]
