"""Coherence graphs and the three P-model quality parameters (Defs. 2-4).

chi[P]   — max chromatic number over all coherence graphs G_{i1,i2}
mu[P]    — coherence       max_{i,j} sqrt( sum_{n1<n2} sigma_{ij}(n1,n2)^2 / n )
mu~[P]   — unicoherence    max_{i<j}  sum_{n1} |sigma_{ij}(n1,n1)|

The paper's concentration theorem (Thm 10) applies when chi, mu = poly(n)
and mu~ = o(n / log^2 n); Sec 2.2 derives chi <= 3 / mu = O(1) / mu~ = 0 for
circulant and chi = 2 for Toeplitz.

We recover the P_i matrices **generically** for every structured kind by
exploiting linearity: a^i = g . P_i, so P_i = d(row i of A)/dg — one
jacobian of ``materialize`` w.r.t. the budget of randomness. This works
for any current or future P-model with zero per-class code.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import spinner, structured


def p_matrices(kind: str, params: Dict[str, jax.Array], m: int, n: int) -> np.ndarray:
    """(m, t, n) stack of the P_i matrices (rows a^i = g . P_i).

    Resolved through the spinner kind registry, so custom registered
    kinds get coherence diagnostics for free.
    """
    g = params["g"]
    gflat = g.reshape(-1)
    rest = {k: v for k, v in params.items() if k != "g"}
    materialize = spinner.kind_def(kind).materialize

    def mat(gf):
        p = dict(rest, g=gf.reshape(g.shape))
        return materialize(p, m, n)

    jac = jax.jacfwd(mat)(gflat)           # (m, n, t)
    return np.asarray(jnp.transpose(jac, (0, 2, 1)))


def sigma_tensor(pmats: np.ndarray) -> np.ndarray:
    """sigma_{i1,i2}(n1,n2) = <p^{i1}_{n1}, p^{i2}_{n2}>  -> (m, m, n, n)."""
    return np.einsum("ita,jtb->ijab", pmats, pmats)


def is_normalized(pmats: np.ndarray, atol: float = 1e-5) -> bool:
    """Def. 1: every column of every P_i has unit L2 norm."""
    norms = np.linalg.norm(pmats, axis=1)  # (m, n)
    return bool(np.all(np.abs(norms - 1.0) < atol))


def orthogonality_condition(pmats: np.ndarray, atol: float = 1e-5) -> bool:
    """Lemma 5's condition: any two columns of each P_i are orthogonal."""
    gram = np.einsum("ita,itb->iab", pmats, pmats)
    m, n, _ = gram.shape
    off = gram - np.eye(n)[None] * gram[:, np.arange(n), np.arange(n)][:, :, None]
    return bool(np.max(np.abs(off)) < atol)


# --- coherence graph -----------------------------------------------------------

def coherence_graph(sig_ij: np.ndarray, tol: float = 1e-8
                    ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
    """Vertices {n1<n2 : sigma != 0}; edges between intersecting pairs."""
    n = sig_ij.shape[0]
    verts = [(a, b) for a in range(n) for b in range(a + 1, n)
             if abs(sig_ij[a, b]) > tol]
    vset = {v: i for i, v in enumerate(verts)}
    edges = []
    by_elem: Dict[int, List[int]] = {}
    for vi, (a, b) in enumerate(verts):
        by_elem.setdefault(a, []).append(vi)
        by_elem.setdefault(b, []).append(vi)
    for elem, vs in by_elem.items():
        for x in range(len(vs)):
            for y in range(x + 1, len(vs)):
                edges.append((vs[x], vs[y]))
    return verts, sorted(set(edges))


def chromatic_number(n_verts: int, edges: List[Tuple[int, int]]) -> int:
    """Exact for max-degree <= 2 graphs (paths/cycles: 1, 2 or 3 via
    bipartiteness); greedy upper bound otherwise."""
    if n_verts == 0:
        return 0
    adj = [[] for _ in range(n_verts)]
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    if not edges:
        return 1
    maxdeg = max(len(a) for a in adj)
    if maxdeg <= 2:
        # union of paths/cycles: 2 if bipartite else 3
        color = [-1] * n_verts
        bipartite = True
        for s in range(n_verts):
            if color[s] >= 0:
                continue
            color[s] = 0
            stack = [s]
            while stack:
                u = stack.pop()
                for v in adj[u]:
                    if color[v] < 0:
                        color[v] = 1 - color[u]
                        stack.append(v)
                    elif color[v] == color[u]:
                        bipartite = False
        return 2 if bipartite else 3
    # greedy (Welsh-Powell order) upper bound
    order = sorted(range(n_verts), key=lambda v: -len(adj[v]))
    color = [-1] * n_verts
    for u in order:
        used = {color[v] for v in adj[u] if color[v] >= 0}
        c = 0
        while c in used:
            c += 1
        color[u] = c
    return max(color) + 1


def pmodel_stats(kind: str, params: Dict[str, jax.Array], m: int, n: int,
                 tol: float = 1e-6) -> Dict[str, float]:
    """chi[P], mu[P], mu~[P] plus normalization/orthogonality checks."""
    pm = p_matrices(kind, params, m, n)
    sig = sigma_tensor(pm)
    chi = 0
    for i in range(m):
        for j in range(m):
            verts, edges = coherence_graph(sig[i, j], tol)
            chi = max(chi, chromatic_number(len(verts), edges))
    iu = np.triu_indices(n, k=1)
    mu = 0.0
    for i in range(m):
        for j in range(m):
            mu = max(mu, float(np.sqrt(np.sum(sig[i, j][iu] ** 2) / n)))
    mu_t = 0.0
    for i in range(m):
        for j in range(i + 1, m):
            mu_t = max(mu_t, float(np.sum(np.abs(np.diagonal(sig[i, j])))))
    return {
        "chi": float(chi),
        "mu": mu,
        "mu_tilde": mu_t,
        "normalized": float(is_normalized(pm)),
        "orthogonal_cols": float(orthogonality_condition(pm)),
        "budget_t": float(pm.shape[1]),
    }


def block_stats(block: spinner.SpinnerBlock, params: Dict[str, jax.Array],
                tol: float = 1e-6) -> Dict[str, float]:
    """chi/mu/mu~ report for one SpinnerBlock (HD excluded: the quality
    parameters are properties of the structured A alone, Defs. 2-4)."""
    return pmodel_stats(block.kind, params, block.m, block.n, tol)


def pipeline_stats(pipe: spinner.SpinnerPipeline, params,
                   tol: float = 1e-6) -> List[Dict[str, float]]:
    """PER-BLOCK quality reports for a multi-block pipeline.

    The concentration machinery (Thm 10) applies blockwise — each block
    is an independent P-model; the report list is index-aligned with
    ``pipe.blocks``.
    """
    params = pipe.block_params(params)      # validates the per-block count
    return [block_stats(b, p, tol) for b, p in zip(pipe.blocks, params)]


ANALYTIC = {
    # paper Sec 2.2: circulant graphs are disjoint cycles -> chi <= 3, mu=O(1),
    # mu~ = 0; Toeplitz graphs are paths -> chi = 2 (Fig. 2), mu~ = 0.
    "circulant": {"chi_max": 3, "mu_tilde": 0.0},
    "skew_circulant": {"chi_max": 3, "mu_tilde": 0.0},
    "toeplitz": {"chi_max": 2, "mu_tilde": 0.0},
    "hankel": {"chi_max": 2, "mu_tilde": 0.0},
    "unstructured": {"chi_max": 1, "mu_tilde": 0.0},
}
