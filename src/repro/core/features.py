"""Pointwise nonlinearities f and the feature maps phi of the paper.

The estimator (eq. 13, k=2, beta=product, Psi=mean) is
    Lambda_f(v1, v2)  ~=  < phi(v1), phi(v2) >
with  phi(v) = f(A_k ... A_1 v) / sqrt(m)   (f applied pointwise).

Every phi takes a ``spinner.SpinnerPipeline`` (any block depth) plus its
params tuple; the projection chain + f + scaling execute as one fused
dispatch per block (the nonlinearity fuses into the LAST block's kernel,
see core/spinner.py). ``grouped=True`` runs G independent pipelines
(leading axis on x and on every param leaf) — the per-kv-head layout of
SRF attention.

Back-compat: passing a legacy ``PModelSpec`` (+ a bare params dict)
still works and emits a ``DeprecationWarning`` — it is converted to the
equivalent 1-block pipeline, so outputs are identical for fixed seeds.
"""
from __future__ import annotations

from typing import Callable, Dict, Union

import jax
import jax.numpy as jnp

from . import spinner
from .spinner import SpinnerPipeline


# --- pointwise f's of the paper — kept importable for back-compat, but
# --- DERIVED from the registry in core/spinner.py (the single source of
# --- truth and the extension point): identity (JL), heaviside (angular /
# --- arc-cosine b=0), sign (E[s1 s2] = 1 - 2 theta/pi), relu (arc-cos b=1)

def _scalar_f(name: str) -> Callable:
    fn = spinner.nonlinearity(name).fn
    return lambda y: fn(y, None)


f_identity = _scalar_f("identity")
f_heaviside = _scalar_f("heaviside")
f_sign = _scalar_f("sign")
f_relu = _scalar_f("relu")

F_TABLE: Dict[str, Callable] = {
    "identity": f_identity,
    "heaviside": f_heaviside,
    "sign": f_sign,
    "relu": f_relu,
}


_as_pipeline = spinner.as_pipeline     # legacy-spec conversion (deprecated)


def _inv_sqrt_m(pipe: SpinnerPipeline) -> float:
    return float(pipe.m_out) ** -0.5


# --- feature maps phi (projection + f + scaling) -------------------------------

def phi_scalar(pipe, params, x: jax.Array, f: Union[str, Callable],
               grouped: bool = False) -> jax.Array:
    """phi(x) = f(proj(x)) / sqrt(m); scalar f fused as the kernel epilogue
    (callables fall back to a separate pointwise stage)."""
    pipe = _as_pipeline(pipe)
    if isinstance(f, str):
        try:                                  # registry = extension point
            nl = spinner.nonlinearity(f)
        except ValueError as e:               # keep the KeyError contract
            raise KeyError(str(e)) from None
        if nl.out_mult != 1 or nl.needs_input:
            raise KeyError(               # exp/cos_sin: different semantics
                f"phi_scalar needs a scalar pointwise f, got {f!r} "
                "(use phi_softmax_pos / phi_trig for exp / cos_sin)")
        return pipe.with_f(f).apply(params, x, out_scale=_inv_sqrt_m(pipe),
                                    grouped=grouped)
    y = pipe.with_f("identity").apply(params, x, grouped=grouped)
    return f(y) / jnp.sqrt(jnp.asarray(pipe.m_out, y.dtype))


def phi_trig(pipe, params, x: jax.Array, sigma: float = 1.0,
             grouped: bool = False) -> jax.Array:
    """Gaussian-kernel features: phi = [cos(y/s), sin(y/s)] / sqrt(m).

    <phi(v1), phi(v2)> -> E[cos((y1-y2)/s)] = exp(-||v1-v2||^2 / (2 s^2)).
    Output dim = 2m; for concrete (Python-number) sigma the 1/sigma
    projection scale and the trig epilogue are fused into the last
    block's spinner pass. A traced/learnable sigma (a jax value, e.g. a
    bandwidth parameter under grad) keeps the fused projection but
    applies the scale + trig outside — fused epilogue scales are
    trace-time statics.
    """
    pipe = _as_pipeline(pipe)
    if isinstance(sigma, (int, float)):
        return pipe.with_f("cos_sin").apply(params, x,
                                            y_scale=1.0 / float(sigma),
                                            out_scale=_inv_sqrt_m(pipe),
                                            grouped=grouped)
    y = pipe.with_f("identity").apply(params, x, grouped=grouped) / sigma
    s = jnp.sqrt(jnp.asarray(pipe.m_out, y.dtype))
    return jnp.concatenate([jnp.cos(y), jnp.sin(y)], axis=-1) / s


def phi_softmax_pos(pipe, params, x: jax.Array,
                    scale: float = 1.0, stabilize: bool = True,
                    grouped: bool = False) -> jax.Array:
    """Positive softmax-kernel features (FAVOR+ form; f = exp).

    phi(x) = exp(y - ||x||^2/2 - c) / sqrt(m),  y = proj(x * scale).
    Precisely: with q' = x * scale,  <phi(q'),phi(k')> ~ exp(<q',k'>) up to
    the global constant e^{-2c} which cancels in attention normalization.

    With ``stabilize=False`` (keys) the whole exp(y - ||x||^2/2) runs
    fused (for 1-block pipelines the kernel computes the subtrahend from
    its input tile via the HD isometry; deeper pipelines apply it after
    the last dispatch) — the same over/underflow exposure as the
    unshifted closed form. With ``stabilize=True`` (queries) the
    projection is still fused but the epilogue stays outside in the
    overflow-safe exp(z - sg(max z)) form: a post-hoc divide by the row
    max would turn an under/overflowed kernel exp into NaN/inf for
    large-norm inputs — exactly what the shift exists to prevent.
    """
    pipe = _as_pipeline(pipe)
    x = x * scale
    if not stabilize:
        return pipe.with_f("exp").apply(params, x,
                                        out_scale=_inv_sqrt_m(pipe),
                                        grouped=grouped)
    y = pipe.with_f("identity").apply(params, x, grouped=grouped)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    z = y - sq
    z = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    return jnp.exp(z) / jnp.sqrt(jnp.asarray(pipe.m_out, y.dtype))


def phi_softmax_trig(pipe, params, x: jax.Array,
                     scale: float = 1.0, grouped: bool = False) -> jax.Array:
    """Trigonometric softmax features (paper's sin/cos comment, Sec 2.1 ex.3):
    exp(<q,k>) = e^{(|q|^2+|k|^2)/2} E[cos(y_q - y_k)]. Unbiased but signed."""
    pipe = _as_pipeline(pipe)
    x = x * scale
    z = pipe.with_f("cos_sin").apply(params, x, out_scale=_inv_sqrt_m(pipe),
                                     grouped=grouped)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    return z * jnp.exp(sq)
