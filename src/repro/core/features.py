"""Pointwise nonlinearities f and the feature maps phi of the paper.

The estimator (eq. 13, k=2, beta=product, Psi=mean) is
    Lambda_f(v1, v2)  ~=  < phi(v1), phi(v2) >
with  phi(v) = f(A D1 H D0 v) / sqrt(m)   (f applied pointwise).

Each feature map returns features scaled so the dot product is the
unbiased estimator of the corresponding closed-form kernel
(core/estimators.py has the closed forms).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from . import pmodel
from .pmodel import PModelSpec


# --- pointwise f's of the paper ------------------------------------------------

def f_identity(y: jax.Array) -> jax.Array:
    return y


def f_heaviside(y: jax.Array) -> jax.Array:
    """f(x) = 1{x >= 0}  (angular kernel / arc-cosine b=0; also the hashing map)."""
    return (y >= 0).astype(y.dtype)


def f_sign(y: jax.Array) -> jax.Array:
    """+/-1 variant of the angular map: E[s1 s2] = 1 - 2 theta / pi."""
    return jnp.sign(y)


def f_relu(y: jax.Array) -> jax.Array:
    """arc-cosine b=1 (linear rectifier)."""
    return jax.nn.relu(y)


F_TABLE: Dict[str, Callable] = {
    "identity": f_identity,
    "heaviside": f_heaviside,
    "sign": f_sign,
    "relu": f_relu,
}


# --- feature maps phi (projection + f + scaling) -------------------------------

def phi_scalar(spec: PModelSpec, params, x: jax.Array, f: str | Callable) -> jax.Array:
    """phi(x) = f(proj(x)) / sqrt(m)  for scalar f from F_TABLE."""
    fn = F_TABLE[f] if isinstance(f, str) else f
    y = pmodel.project(spec, params, x)
    return fn(y) / jnp.sqrt(jnp.asarray(spec.m, y.dtype))


def phi_trig(spec: PModelSpec, params, x: jax.Array, sigma: float = 1.0) -> jax.Array:
    """Gaussian-kernel features: phi = [cos(y/s), sin(y/s)] / sqrt(m).

    <phi(v1), phi(v2)> -> E[cos((y1-y2)/s)] = exp(-||v1-v2||^2 / (2 s^2)).
    Output dim = 2m.
    """
    y = pmodel.project(spec, params, x) / sigma
    s = jnp.sqrt(jnp.asarray(spec.m, y.dtype))
    return jnp.concatenate([jnp.cos(y), jnp.sin(y)], axis=-1) / s


def phi_softmax_pos(spec: PModelSpec, params, x: jax.Array,
                    scale: float = 1.0, stabilize: bool = True) -> jax.Array:
    """Positive softmax-kernel features (FAVOR+ form; f = exp).

    phi(x) = exp(y - ||x||^2/2 - c) / sqrt(m),  y = proj(x / sqrt(scale))...
    Precisely: with q' = x * scale,  <phi(q'),phi(k')> ~ exp(<q',k'>) up to
    the global constant e^{-2c} which cancels in attention normalization.
    """
    x = x * scale
    y = pmodel.project(spec, params, x)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    z = y - sq
    if stabilize:
        z = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    return jnp.exp(z) / jnp.sqrt(jnp.asarray(spec.m, y.dtype))


def phi_softmax_trig(spec: PModelSpec, params, x: jax.Array,
                     scale: float = 1.0) -> jax.Array:
    """Trigonometric softmax features (paper's sin/cos comment, Sec 2.1 ex.3):
    exp(<q,k>) = e^{(|q|^2+|k|^2)/2} E[cos(y_q - y_k)]. Unbiased but signed."""
    x = x * scale
    y = pmodel.project(spec, params, x)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    s = jnp.sqrt(jnp.asarray(spec.m, y.dtype))
    amp = jnp.exp(sq)
    return jnp.concatenate([jnp.cos(y), jnp.sin(y)], axis=-1) * amp / s
