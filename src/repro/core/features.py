"""Pointwise nonlinearities f and the feature maps phi of the paper.

The estimator (eq. 13, k=2, beta=product, Psi=mean) is
    Lambda_f(v1, v2)  ~=  < phi(v1), phi(v2) >
with  phi(v) = f(A D1 H D0 v) / sqrt(m)   (f applied pointwise).

Each feature map returns features scaled so the dot product is the
unbiased estimator of the corresponding closed-form kernel
(core/estimators.py has the closed forms).

Every phi here routes through the FUSED spinner (pmodel.project_fused ->
kernels.ops.spinner_project): projection + f + scaling execute as one
dispatch (one Pallas pass on TPU), not as separate projection / pointwise
stages. ``grouped=True`` runs G independent P-models (leading axis on x
and on every param leaf) in a single fused call — the per-kv-head layout
of SRF attention.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from . import pmodel
from .pmodel import PModelSpec


# --- pointwise f's of the paper ------------------------------------------------

def f_identity(y: jax.Array) -> jax.Array:
    return y


def f_heaviside(y: jax.Array) -> jax.Array:
    """f(x) = 1{x >= 0}  (angular kernel / arc-cosine b=0; also the hashing map)."""
    return (y >= 0).astype(y.dtype)


def f_sign(y: jax.Array) -> jax.Array:
    """+/-1 variant of the angular map: E[s1 s2] = 1 - 2 theta / pi."""
    return jnp.sign(y)


def f_relu(y: jax.Array) -> jax.Array:
    """arc-cosine b=1 (linear rectifier)."""
    return jax.nn.relu(y)


F_TABLE: Dict[str, Callable] = {
    "identity": f_identity,
    "heaviside": f_heaviside,
    "sign": f_sign,
    "relu": f_relu,
}


def _inv_sqrt_m(spec: PModelSpec) -> float:
    return float(spec.m) ** -0.5


# --- feature maps phi (projection + f + scaling) -------------------------------

def phi_scalar(spec: PModelSpec, params, x: jax.Array, f: str | Callable,
               grouped: bool = False) -> jax.Array:
    """phi(x) = f(proj(x)) / sqrt(m); scalar f fused as the kernel epilogue
    (callables fall back to a separate pointwise stage)."""
    if isinstance(f, str):
        if f not in F_TABLE:      # 'exp'/'cos_sin' have different semantics
            raise KeyError(f"phi_scalar f must be one of {list(F_TABLE)}, "
                           f"got {f!r}")
        return pmodel.project_fused(spec, params, x, epilogue=f,
                                    out_scale=_inv_sqrt_m(spec),
                                    grouped=grouped)
    y = pmodel.project_fused(spec, params, x, grouped=grouped)
    return f(y) / jnp.sqrt(jnp.asarray(spec.m, y.dtype))


def phi_trig(spec: PModelSpec, params, x: jax.Array, sigma: float = 1.0,
             grouped: bool = False) -> jax.Array:
    """Gaussian-kernel features: phi = [cos(y/s), sin(y/s)] / sqrt(m).

    <phi(v1), phi(v2)> -> E[cos((y1-y2)/s)] = exp(-||v1-v2||^2 / (2 s^2)).
    Output dim = 2m; for concrete (Python-number) sigma the 1/sigma
    projection scale and the trig epilogue are fused into the single
    spinner pass. A traced/learnable sigma (a jax value, e.g. a bandwidth
    parameter under grad) keeps the fused projection but applies the
    scale + trig outside — fused epilogue scales are trace-time statics.
    """
    if isinstance(sigma, (int, float)):
        return pmodel.project_fused(spec, params, x, epilogue="cos_sin",
                                    y_scale=1.0 / float(sigma),
                                    out_scale=_inv_sqrt_m(spec),
                                    grouped=grouped)
    y = pmodel.project_fused(spec, params, x, grouped=grouped) / sigma
    s = jnp.sqrt(jnp.asarray(spec.m, y.dtype))
    return jnp.concatenate([jnp.cos(y), jnp.sin(y)], axis=-1) / s


def phi_softmax_pos(spec: PModelSpec, params, x: jax.Array,
                    scale: float = 1.0, stabilize: bool = True,
                    grouped: bool = False) -> jax.Array:
    """Positive softmax-kernel features (FAVOR+ form; f = exp).

    phi(x) = exp(y - ||x||^2/2 - c) / sqrt(m),  y = proj(x * scale).
    Precisely: with q' = x * scale,  <phi(q'),phi(k')> ~ exp(<q',k'>) up to
    the global constant e^{-2c} which cancels in attention normalization.

    With ``stabilize=False`` (keys) the whole exp(y - ||x||^2/2) runs
    inside the fused spinner (the kernel computes the subtrahend from its
    input tile via the HD isometry) — the same over/underflow exposure as
    the unshifted closed form. With ``stabilize=True`` (queries) the
    projection is still one fused pass but the epilogue stays outside in
    the overflow-safe exp(z - sg(max z)) form: a post-hoc divide by the
    row max would turn an under/overflowed kernel exp into NaN/inf for
    large-norm inputs — exactly what the shift exists to prevent.
    """
    x = x * scale
    if not stabilize:
        return pmodel.project_fused(spec, params, x, epilogue="exp",
                                    out_scale=_inv_sqrt_m(spec),
                                    grouped=grouped)
    y = pmodel.project_fused(spec, params, x, grouped=grouped)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    z = y - sq
    z = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    return jnp.exp(z) / jnp.sqrt(jnp.asarray(spec.m, y.dtype))


def phi_softmax_trig(spec: PModelSpec, params, x: jax.Array,
                     scale: float = 1.0, grouped: bool = False) -> jax.Array:
    """Trigonometric softmax features (paper's sin/cos comment, Sec 2.1 ex.3):
    exp(<q,k>) = e^{(|q|^2+|k|^2)/2} E[cos(y_q - y_k)]. Unbiased but signed."""
    x = x * scale
    z = pmodel.project_fused(spec, params, x, epilogue="cos_sin",
                             out_scale=_inv_sqrt_m(spec), grouped=grouped)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    return z * jnp.exp(sq)
