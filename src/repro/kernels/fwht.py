"""Pallas TPU kernel: Fast Walsh-Hadamard transform in Kronecker (MXU) form.

H_n = H_a (x) H_b  with n = a*b  =>  H_n x = vec( H_a . mat(x) . H_b ).

The log-radix butterfly FWHT is VPU-hostile on TPU (strided element
shuffles); the 2-factor Kronecker sandwich instead runs two dense matmuls
with small Hadamard factors resident in VMEM — exactly the shape the MXU
wants (a, b <= 128 for n <= 16384). HBM traffic: x in, y out, factors ~0.

Grid: 1-D over batch tiles. Each program holds an (TB, n) slice of x plus
both factors in VMEM and writes the transformed (TB, n) tile.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import transforms


def _fwht_kernel(x_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int, scale: float):
    x = x_ref[...]                       # (TB, n)
    tb = x.shape[0]
    ha = ha_ref[...]                     # (a, a) unnormalized Hadamard
    hb = hb_ref[...]                     # (b, b)
    xm = x.reshape(tb * a, b)
    z = jnp.dot(xm, hb, preferred_element_type=jnp.float32)      # X . H_b
    z = z.reshape(tb, a, b).transpose(0, 2, 1).reshape(tb * b, a)
    y = jnp.dot(z, ha, preferred_element_type=jnp.float32)       # (. )H_a^T = .H_a
    y = y.reshape(tb, b, a).transpose(0, 2, 1).reshape(tb, a * b)
    o_ref[...] = (y * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("normalized", "block_b", "interpret"))
def fwht_pallas(x: jax.Array, normalized: bool = True, block_b: int = 256,
                interpret: bool = True) -> jax.Array:
    """(B, n) -> (B, n); n = 2^k. TPU target; interpret=True validates on CPU."""
    bsz, n = x.shape
    assert transforms.is_pow2(n), f"n must be a power of two, got {n}"
    a, b = transforms.kron_factors(n)
    ha = transforms.hadamard(a, x.dtype, normalized=False)
    hb = transforms.hadamard(b, x.dtype, normalized=False)
    tb = min(block_b, bsz)
    grid = (pl.cdiv(bsz, tb),)
    scale = (1.0 / math.sqrt(n)) if normalized else 1.0
    kernel = functools.partial(_fwht_kernel, a=a, b=b, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), x.dtype),
        interpret=interpret,
    )(x, ha, hb)
