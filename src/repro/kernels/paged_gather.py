"""Pallas TPU kernel: gather non-contiguous cache pages for batched decode.

Paged serving stores each request's KV (or MLA-latent) history as a set
of fixed-size pages scattered through one pooled buffer; batched decode
attention needs each request's history contiguous. This kernel performs

    out[r, j*P:(j+1)*P, :] = pool[table[r, j], :, :]

with the block table prefetched as a scalar operand
(``PrefetchScalarGridSpec``), so the page id is known *before* the body
runs and the pool page is DMA'd straight into the output block — the
kernel body is a pure VMEM copy, and the gather is one grid step per
(request, page) with no gather/scatter HLO in between.

Unallocated table slots point at the reserved null page 0; the garbage
they fetch is masked by the attention length mask downstream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(tables_ref, pool_ref, out_ref):
    # index maps already routed the right page into pool_ref
    out_ref[0, 0] = pool_ref[0]


def _gather_dequant_kernel(tables_ref, pool_ref, scale_ref, out_ref):
    # int8 page * f32 per-row scale, fused into the same DMA'd copy: the
    # quantized page never round-trips through HBM at full width.
    out_ref[0, 0] = (pool_ref[0].astype(jnp.float32)
                     * scale_ref[0]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gather_pallas(pool: jax.Array, tables: jax.Array,
                        interpret: bool = True) -> jax.Array:
    """pool: (N, P, D); tables: (R, M) int32 page ids -> (R, M*P, D).

    Grid (R, M): one program per (request, page slot). The scalar-prefetch
    block table drives the input index map.
    """
    n, p, d = pool.shape
    r, m = tables.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, m),
        in_specs=[
            pl.BlockSpec((1, p, d), lambda i, j, tbl: (tbl[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, p, d), lambda i, j, tbl: (i, j, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, m, p, d), pool.dtype),
        interpret=interpret,
    )(tables, pool)
    return out.reshape(r, m * p, d)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def paged_gather_dequant_pallas(pool: jax.Array, scales: jax.Array,
                                tables: jax.Array,
                                out_dtype=jnp.float32,
                                interpret: bool = True) -> jax.Array:
    """Fused int8 page gather + dequant.

    pool: (N, P, D) int8; scales: (N, P, 1) f32 per-row (per token) scales;
    tables: (R, M) int32 page ids -> (R, M*P, D) ``out_dtype``.

    Same (R, M) grid and scalar-prefetched table as ``paged_gather_pallas``;
    the dequant multiply rides the VMEM copy so the int8 pool is the only
    HBM-resident form of the quantized cache.
    """
    n, p, d = pool.shape
    r, m = tables.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, m),
        in_specs=[
            pl.BlockSpec((1, p, d), lambda i, j, tbl: (tbl[i, j], 0, 0)),
            pl.BlockSpec((1, p, 1), lambda i, j, tbl: (tbl[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, p, d), lambda i, j, tbl: (i, j, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, m, p, d), jnp.dtype(out_dtype)),
        interpret=interpret,
    )(tables, pool, scales)
    return out.reshape(r, m * p, d)
