"""Pallas TPU kernel: fused SRF decode step (state update + readout).

Decode with SRF attention touches the O(m x dv) state three times if
written naively (update S, read S for the numerator, reduce z). This
kernel performs

    S' = S + phi_k^T v ;  z' = z + phi_k ;
    out = (phi_q S') / (phi_q . z' + eps)

in a single VMEM residency of the state tile. Decode is memory-bound
(roofline: bytes of S dominate), so 3x -> 1x state traffic is a direct
3x on the achievable decode rate.

Grid: (B*H,) — one program per (batch, head) state. State tiles are
donated/aliased so the update is in-place in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _srf_decode_kernel(s_ref, z_ref, pq_ref, pk_ref, v_ref, s_out, z_out,
                       o_ref, *, eps: float):
    s = s_ref[...]          # (1, m, dv)
    z = z_ref[...]          # (1, m)
    pq = pq_ref[...]        # (1, m)
    pk = pk_ref[...]        # (1, m)
    v = v_ref[...]          # (1, dv)
    s2 = s + pk[0][:, None] * v[0][None, :]
    z2 = z + pk
    num = jnp.dot(pq, s2[0], preferred_element_type=jnp.float32)   # (1, dv)
    den = jnp.sum(pq * z2, axis=-1, keepdims=True)                 # (1, 1)
    s_out[...] = s2.astype(s_out.dtype)
    z_out[...] = z2.astype(z_out.dtype)
    o_ref[...] = (num / (den + eps)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def srf_decode_pallas(s: jax.Array, z: jax.Array, phi_q: jax.Array,
                      phi_k: jax.Array, v: jax.Array, eps: float = 1e-6,
                      interpret: bool = True):
    """s: (B,H,m,dv) z: (B,H,m) phi_*: (B,H,m) v: (B,H,dv)
    -> (s', z', out) with out (B,H,dv). One grid step per (b,h)."""
    b, h, m, dv = s.shape
    bh = b * h
    sf = s.reshape(bh, m, dv)
    zf = z.reshape(bh, m)
    pqf = phi_q.reshape(bh, m)
    pkf = phi_k.reshape(bh, m)
    vf = v.reshape(bh, dv)
    kernel = functools.partial(_srf_decode_kernel, eps=eps)
    s2, z2, out = pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, m, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, dv), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, dv), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, m, dv), s.dtype),
            jax.ShapeDtypeStruct((bh, m), z.dtype),
            jax.ShapeDtypeStruct((bh, dv), v.dtype),
        ],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(sf, zf, pqf, pkf, vf)
    return (s2.reshape(b, h, m, dv), z2.reshape(b, h, m),
            out.reshape(b, h, dv))
