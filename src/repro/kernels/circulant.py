"""Pallas TPU kernel: block-circulant projection with fused feature epilogue.

The paper computes f(A x) with A an (m, n) structured matrix. On GPU/CPU the
fast path is FFT (O(n log n)); on TPU we instead *regenerate* each circulant
tile from the O(n) generator directly in VMEM and feed the MXU:

    HBM traffic:  g (nb*n floats)  +  x tile  +  y tile      [O(n + B n)]
    dense equiv:  W (m*n floats)   +  x tile  +  y tile      [O(m n + B n)]

For m = 2n..8n (SRF attention feature expansion) this cuts projection
weight traffic by m/nb·n = n, turning a memory-bound matvec into a
compute-bound MXU op — the paper's space claim converted into arithmetic
intensity (DESIGN.md Sec 2).

Tile generation: A[i, j] = g[b(i), (j - i mod n) mod n]. Within a row tile
the index matrix is a shifted iota; we gather from the doubled generator
gg = [g, g] so every row is a contiguous window (monotone gather, no mod).

The pointwise nonlinearity f runs as an epilogue while the tile is still
in VMEM (identity | relu | heaviside | exp(y - sq) | cos_sin).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPILOGUES = ("identity", "relu", "heaviside", "exp", "cos_sin")


def _epilogue(y, epilogue, sq):
    if epilogue == "identity":
        return y
    if epilogue == "relu":
        return jnp.maximum(y, 0.0)
    if epilogue == "heaviside":
        return (y >= 0).astype(y.dtype)
    if epilogue == "exp":
        return jnp.exp(y - sq)
    raise ValueError(epilogue)


def _circ_kernel(x_ref, gg_ref, sq_ref, o_ref, *, n: int, tm: int,
                 epilogue: str):
    """Grid (batch_tiles, row_tiles). Regenerate (TM, n) tile rows from gg."""
    j = pl.program_id(1)
    x = x_ref[...]                                   # (TB, n)
    gg = gg_ref[...]                                 # (nb, 2n) doubled gens
    row0 = j * tm
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (tm, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tm, n), 1)
    blk = rows // n
    off = rows % n
    # A[i, c] = g[blk, (c - off) mod n] = gg[blk, c - off + n]
    idx = cols - off + n                             # in [1, 2n)
    tile = gg[blk, idx]                              # (TM, n) gather in VMEM
    y = jax.lax.dot_general(
        x, tile, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (TB, TM)
    if epilogue == "cos_sin":
        o_ref[..., 0, :] = jnp.cos(y).astype(o_ref.dtype)
        o_ref[..., 1, :] = jnp.sin(y).astype(o_ref.dtype)
    else:
        sq = sq_ref[...][:, :1] if epilogue == "exp" else None  # (TB, 1)
        o_ref[...] = _epilogue(y, epilogue, sq).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "epilogue", "block_b",
                                             "block_m", "interpret"))
def circulant_project_pallas(g: jax.Array, x: jax.Array, m: int,
                             epilogue: str = "identity",
                             sq: Optional[jax.Array] = None,
                             block_b: int = 256, block_m: int = 256,
                             interpret: bool = True) -> jax.Array:
    """g: (nb, n) generators; x: (B, n) -> (B, m) (or (B, 2m) for cos_sin).

    Requires m % block_m == 0 or block_m >= m; n enters VMEM whole
    (n <= ~4096 for f32 — callers with bigger n use the jnp path).
    """
    assert epilogue in EPILOGUES, epilogue
    nb, n = g.shape
    bsz = x.shape[0]
    assert nb * n >= m, f"generators cover {nb*n} rows < m={m}"
    tb = min(block_b, bsz)
    tm = min(block_m, m)
    assert m % tm == 0, f"m={m} must tile by block_m={tm}"
    gg = jnp.concatenate([g, g], axis=-1)            # (nb, 2n)
    if sq is None:
        sq = jnp.zeros((bsz, 1), x.dtype)
    sq = sq.reshape(bsz, 1)
    grid = (pl.cdiv(bsz, tb), m // tm)
    kernel = functools.partial(_circ_kernel, n=n, tm=tm, epilogue=epilogue)
    if epilogue == "cos_sin":
        out_shape = jax.ShapeDtypeStruct((bsz, 2, m), x.dtype)
        out_specs = pl.BlockSpec((tb, 2, tm), lambda i, j: (i, 0, j))
    else:
        out_shape = jax.ShapeDtypeStruct((bsz, m), x.dtype)
        out_specs = pl.BlockSpec((tb, tm), lambda i, j: (i, j))
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, n), lambda i, j: (i, 0)),
            pl.BlockSpec((nb, 2 * n), lambda i, j: (0, 0)),
            pl.BlockSpec((tb, 1), lambda i, j: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, gg, sq)
    if epilogue == "cos_sin":
        y = jnp.concatenate([y[:, 0, :], y[:, 1, :]], axis=-1)
    return y
