"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth; kernel tests sweep shapes and
dtypes and assert allclose against these.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import transforms


def fwht_ref(x: jax.Array, normalized: bool = True) -> jax.Array:
    """(B, n) -> (B, n) Walsh-Hadamard transform (Sylvester order)."""
    return transforms.fwht(x, normalized=normalized)


def circulant_project_ref(g: jax.Array, x: jax.Array, m: int,
                          epilogue: str = "identity",
                          sq: Optional[jax.Array] = None) -> jax.Array:
    """Block-circulant projection with fused feature epilogue.

    g: (nb, n) block generators; x: (B, n); out: (B, m) —
    y[B, i] = sum_j x[B, j] g[b(i), (j - i') mod n],  i = b(i)*n + i'.
    epilogues: identity | relu | heaviside | exp (exp(y - sq[B]) ) |
               cos_sin (out dim 2m: [cos(y), sin(y)]).
    """
    nb, n = g.shape
    i = jnp.arange(nb * n)
    blk = i // n
    off = i % n
    j = jnp.arange(n)
    a = g[blk[:, None], (j[None, :] - off[:, None]) % n][:m]   # (m, n)
    y = x @ a.T
    if epilogue == "identity":
        return y
    if epilogue == "relu":
        return jax.nn.relu(y)
    if epilogue == "heaviside":
        return (y >= 0).astype(y.dtype)
    if epilogue == "exp":
        assert sq is not None
        return jnp.exp(y - sq[:, None])
    if epilogue == "cos_sin":
        return jnp.concatenate([jnp.cos(y), jnp.sin(y)], axis=-1)
    raise ValueError(epilogue)


def srf_decode_ref(s: jax.Array, z: jax.Array, phi_q: jax.Array,
                   phi_k: jax.Array, v: jax.Array, eps: float = 1e-6
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused SRF decode-step state update + readout.

    s: (B, H, m, dv)  z: (B, H, m)  phi_q/phi_k: (B, H, m)  v: (B, H, dv)
    returns (s', z', out) with out: (B, H, dv).
    """
    s2 = s + phi_k[..., :, None] * v[..., None, :]
    z2 = z + phi_k
    num = jnp.einsum("bhm,bhmd->bhd", phi_q, s2)
    den = jnp.einsum("bhm,bhm->bh", phi_q, z2)
    return s2, z2, num / (den[..., None] + eps)


def paged_gather_ref(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather cache pages into per-request contiguous views.

    pool: (N, P, D) pooled pages; tables: (R, M) int32 page ids
    -> (R, M*P, D). Out-of-range ids clamp (matching the kernel's
    behavior of routing bad ids onto a real page; callers mask).
    """
    n = pool.shape[0]
    idx = jnp.clip(tables, 0, n - 1)
    r, m = tables.shape
    out = pool[idx]                                  # (R, M, P, D)
    return out.reshape(r, m * pool.shape[1], pool.shape[2])
