"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth; kernel tests sweep shapes and
dtypes and assert allclose against these.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import structured, transforms


def fwht_ref(x: jax.Array, normalized: bool = True) -> jax.Array:
    """(B, n) -> (B, n) Walsh-Hadamard transform (Sylvester order)."""
    return transforms.fwht(x, normalized=normalized)


def circulant_project_ref(g: jax.Array, x: jax.Array, m: int,
                          epilogue: str = "identity",
                          sq: Optional[jax.Array] = None) -> jax.Array:
    """Block-circulant projection with fused feature epilogue.

    g: (nb, n) block generators; x: (B, n); out: (B, m) —
    y[B, i] = sum_j x[B, j] g[b(i), (j - i') mod n],  i = b(i)*n + i'.
    epilogues: identity | relu | heaviside | exp (exp(y - sq[B]) ) |
               cos_sin (out dim 2m: [cos(y), sin(y)]).
    """
    nb, n = g.shape
    i = jnp.arange(nb * n)
    blk = i // n
    off = i % n
    j = jnp.arange(n)
    a = g[blk[:, None], (j[None, :] - off[:, None]) % n][:m]   # (m, n)
    y = x @ a.T
    if epilogue == "identity":
        return y
    if epilogue == "relu":
        return jax.nn.relu(y)
    if epilogue == "heaviside":
        return (y >= 0).astype(y.dtype)
    if epilogue == "exp":
        assert sq is not None
        return jnp.exp(y - sq[:, None])
    if epilogue == "cos_sin":
        return jnp.concatenate([jnp.cos(y), jnp.sin(y)], axis=-1)
    raise ValueError(epilogue)


def _spinner_epilogue(y, x, epilogue: str, out_scale: float):
    """Pointwise f of the spinner; ``x`` is the pre-HD input (for ``exp``
    the subtrahend 0.5||x||^2 equals 0.5||v||^2 by the HD isometry)."""
    if epilogue == "identity":
        r = y
    elif epilogue == "relu":
        r = jax.nn.relu(y)
    elif epilogue == "heaviside":
        r = (y >= 0).astype(y.dtype)
    elif epilogue == "sign":
        r = jnp.sign(y)
    elif epilogue == "exp":
        xf = x.astype(jnp.float32)
        sq = 0.5 * jnp.sum(xf * xf, axis=-1, keepdims=True)
        r = jnp.exp(y.astype(jnp.float32) - sq).astype(y.dtype)
    elif epilogue == "cos_sin":
        r = jnp.concatenate([jnp.cos(y), jnp.sin(y)], axis=-1)
    else:
        raise ValueError(epilogue)
    return r if out_scale == 1.0 else r * jnp.asarray(out_scale, r.dtype)


def _skew_matvec_diag(w: jax.Array, d1, g: jax.Array, m: int) -> jax.Array:
    """Block skew-circulant matvec of (d1 ⊙ w), the D1 diagonal FOLDED into
    the complex skew modulation (d1 · e^{iπj/n} is one combined elementwise
    factor — one fewer full-width pass than d1-mul-then-matvec).

    w: (..., n); g: (nb, n) -> (..., m). All blocks share one input FFT
    and one batched inverse FFT.
    """
    n = w.shape[-1]
    d = structured._skew_modulation(n)
    dd = d if d1 is None else d * structured._f32(d1).astype(jnp.complex64)
    fx = jnp.fft.fft(structured._f32(w).astype(jnp.complex64) * dd, n=n)
    fg = jnp.fft.fft(structured._f32(g).astype(jnp.complex64) * d, n=n)
    y = jnp.fft.ifft(fx[..., None, :] * jnp.conj(fg), n=n) * jnp.conj(d)
    y = y.real.astype(w.dtype)                                # (..., nb, n)
    return y.reshape(*w.shape[:-1], -1)[..., :m]


def _hd_kron(x: jax.Array, d0: jax.Array, d1) -> jax.Array:
    """D1 · H · D0 · x with the Kronecker-form FWHT and the 1/sqrt(n)
    normalization FOLDED into the (constant) left Hadamard factor — one
    fewer full-width scaling pass than hd_preprocess(use_kron=True).
    Pass d1=None to skip the output diagonal (the skew path folds it into
    its complex modulation instead)."""
    n = x.shape[-1]
    a, b = transforms.kron_factors(n)
    ha = transforms.hadamard(a, x.dtype, normalized=False) \
        * jnp.asarray(1.0 / math.sqrt(n), x.dtype)
    hb = transforms.hadamard(b, x.dtype, normalized=False)
    xm = (d0 * x).reshape(*x.shape[:-1], a, b)
    y = jnp.einsum("pa,...ab,bq->...pq", ha, xm, hb)
    y = y.reshape(*x.shape[:-1], n)
    return y if d1 is None else d1 * y


def _spinner_one(kind: str, m: int, epilogue: str, y_scale: float,
                 out_scale: float, g, h, d0, d1, x):
    params = {"g": g} if h is None else {"g": g, "h": h}
    if kind == "skew_circulant":
        w = x if d0 is None else _hd_kron(x, d0, None)
        y = _skew_matvec_diag(w, None if d0 is None else d1, g, m)
    else:
        v = x if d0 is None else _hd_kron(x, d0, d1)
        y = structured.matvec(kind, params, v, m)
    if y_scale != 1.0:
        y = y * jnp.asarray(y_scale, y.dtype)
    return _spinner_epilogue(y, x, epilogue, out_scale)


def spinner_project_ref(kind: str, g: jax.Array, x: jax.Array, m: int,
                        d0: Optional[jax.Array] = None,
                        d1: Optional[jax.Array] = None,
                        h: Optional[jax.Array] = None,
                        epilogue: str = "identity",
                        y_scale: float = 1.0,
                        out_scale: float = 1.0) -> jax.Array:
    """Fused spinner  f(A . D1 H D0 . x)  as ONE differentiable jnp graph.

    x: (G, B, n); g (and optional ldr ``h``) carry a leading group axis G;
    d0/d1: (G, n) or None (no HD). Output (G, B, m) — (G, B, 2m) for
    cos_sin. Uses the Kronecker-form FWHT and the FFT structured matvec,
    so under jit this is a single fused dispatch (no HBM round trips
    between HD / projection / f) — the CPU/GPU realization of the fusion
    the Pallas kernel performs on TPU, and the backward rule for it.
    """
    fn = partial(_spinner_one, kind, m, epilogue, y_scale, out_scale)
    if x.shape[0] == 1:                  # ungrouped: skip the vmap wrapper
        sq = lambda t: None if t is None else t[0]
        return fn(sq(g), sq(h), sq(d0), sq(d1), x[0])[None]
    axes = (0, None if h is None else 0, None if d0 is None else 0,
            None if d1 is None else 0, 0)
    return jax.vmap(fn, in_axes=axes)(g, h, d0, d1, x)


def spinner_project_seeded_ref(kind: str, seeds: jax.Array, x: jax.Array,
                               m: int, *, r: int = 1, ldr_nnz: int = 4,
                               use_hd: bool = True,
                               epilogue: str = "identity",
                               y_scale: float = 1.0,
                               out_scale: float = 1.0) -> jax.Array:
    """Seed-mode reference: rebuild the exact param dict the seed encodes
    (``kernels.seedgen.seeded_params`` — the generator oracle) and run the
    materialized reference on it. Params exist only transiently inside
    the trace; nothing is stored between calls. Bit-identical to calling
    :func:`spinner_project_ref` on the oracle params by construction, and
    differentiable w.r.t. ``x`` (the generation subgraph is constant)."""
    from . import seedgen
    n = x.shape[-1]
    params = seedgen.grouped_params(kind, n, m, seeds.reshape(-1), r=r,
                                    ldr_nnz=ldr_nnz, use_hd=use_hd)
    return spinner_project_ref(kind, params["g"], x, m,
                               d0=params.get("d0"), d1=params.get("d1"),
                               h=params.get("h"), epilogue=epilogue,
                               y_scale=y_scale, out_scale=out_scale)


def srf_decode_ref(s: jax.Array, z: jax.Array, phi_q: jax.Array,
                   phi_k: jax.Array, v: jax.Array, eps: float = 1e-6
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused SRF decode-step state update + readout.

    s: (B, H, m, dv)  z: (B, H, m)  phi_q/phi_k: (B, H, m)  v: (B, H, dv)
    returns (s', z', out) with out: (B, H, dv).
    """
    s2 = s + phi_k[..., :, None] * v[..., None, :]
    z2 = z + phi_k
    num = jnp.einsum("bhm,bhmd->bhd", phi_q, s2)
    den = jnp.einsum("bhm,bhm->bh", phi_q, z2)
    return s2, z2, num / (den[..., None] + eps)


def paged_gather_ref(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather cache pages into per-request contiguous views.

    pool: (N, P, D) pooled pages; tables: (R, M) int32 page ids
    -> (R, M*P, D). Out-of-range ids clamp (matching the kernel's
    behavior of routing bad ids onto a real page; callers mask).
    """
    n = pool.shape[0]
    idx = jnp.clip(tables, 0, n - 1)
    r, m = tables.shape
    out = pool[idx]                                  # (R, M, P, D)
    return out.reshape(r, m * pool.shape[1], pool.shape[2])


def paged_gather_dequant_ref(pool: jax.Array, scales: jax.Array,
                             tables: jax.Array,
                             out_dtype=jnp.float32) -> jax.Array:
    """Reference for the fused int8 gather + dequant.

    pool: (N, P, D) int8; scales: (N, P, 1) f32 per-row scales;
    tables: (R, M) -> (R, M*P, D) ``out_dtype``.
    """
    n, p, d = pool.shape
    idx = jnp.clip(tables, 0, n - 1)
    r, m = tables.shape
    out = pool[idx].astype(jnp.float32) * scales[idx]
    return out.astype(out_dtype).reshape(r, m * p, d)
