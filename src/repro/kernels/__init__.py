"""Pallas TPU kernels for the paper's compute hot-spots.

fwht        — Walsh-Hadamard transform in MXU (Kronecker) form
circulant   — block-circulant projection, implicit tile generation, fused f
srf_decode  — fused SRF decode-step state update + readout

Each kernel has a pure-jnp oracle in ref.py; ops.py provides the public
wrappers with CPU-interpret / jnp-fallback routing.
"""
from . import ops, ref
from .ops import circulant_project, fwht, srf_decode

__all__ = ["ops", "ref", "circulant_project", "fwht", "srf_decode"]
