"""Pallas TPU kernels for the paper's compute hot-spots.

spinner     — FUSED  f(A . D1 H D0 . x): HD sandwich + implicit-tile
              structured projection + pointwise epilogue in one pass
              (the whole P-model pipeline; see README.md)
fwht        — Walsh-Hadamard transform in MXU (Kronecker) form
circulant   — block-circulant projection, implicit tile generation, fused f
              (subsumed by spinner; kept as the minimal single-stage kernel)
srf_decode  — fused SRF decode-step state update + readout
paged_gather— page-table gather for the paged serving cache

Each kernel has a pure-jnp oracle in ref.py; ops.py provides the public
wrappers with CPU-interpret / jnp-fallback routing (README.md documents
the routing table and VMEM budget model).
"""
from . import ops, ref
from .ops import (circulant_project, fwht, paged_gather, spinner_plan,
                  spinner_project, srf_decode)

__all__ = ["ops", "ref", "circulant_project", "fwht", "paged_gather",
           "spinner_plan", "spinner_project", "srf_decode"]
