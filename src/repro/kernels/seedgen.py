"""Counter-based Gaussian regeneration for the seeded spinner.

The paper's space-complexity story taken to its limit: instead of storing
the O(n) generator ``g`` (let alone the (m, n) matrix), store ONE 32-bit
seed and regenerate every matrix entry *at its position* when the kernel
needs it. The PRNG is a counter-based threefry2x32 (the same 20-round
permutation JAX's PRNG is built on) + Box-Muller, evaluated elementwise
at the entry's FLAT POSITION in the canonical parameter array:

    value(seed, domain, p) = BoxMuller(threefry2x32((seed, domain), (p, 0)))

Because generation is a pure elementwise function of (seed, domain,
position), any tiling of the computation — the Pallas kernel's (tm, n)
row tiles, the jnp reference's full-array materialization, the dense
test oracle — produces bit-identical values: there is no sequential
stream to keep in sync, and the autotuner's block-size choices can never
change results. ``seeded_params`` is the generator oracle: it rebuilds
the exact ``structured.init``-shaped param dict from a seed, so
``materialize`` / tests can compare the zero-storage path against the
materialized one bit for bit (on the interpret/ref routes; native TPU
transcendentals may differ in the last ulp).

Domain constants separate the independent streams a spinner block
consumes (generator core, the two HD Rademacher diagonals, the ldr
h-vector index/sign draws, and seed folding for per-head / per-request
derivation). All generation is f32 regardless of the activation dtype —
there is no stored tensor whose dtype could disagree.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

# Domain separation constants (the second threefry key word).
DOM_G = 0       # generator core g
DOM_D0 = 1      # HD input Rademacher diagonal
DOM_D1 = 2      # HD output Rademacher diagonal
DOM_H_IDX = 3   # ldr h-vector support draw (uniform keys, top-nnz)
DOM_H_SGN = 4   # ldr h-vector signs
DOM_FOLD = 7    # fold_seed sub-stream derivation

_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)
_PARITY = 0x1BD11BDA


def _rotl(x: jax.Array, d: int) -> jax.Array:
    return (x << jnp.uint32(d)) | (x >> jnp.uint32(32 - d))


def threefry2x32(k0, k1, c0, c1):
    """The standard 20-round threefry-2x32 block cipher, elementwise over
    broadcastable uint32 inputs: key (k0, k1), counter (c0, c1) -> two
    independent uint32 streams."""
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_PARITY))
    x0 = jnp.asarray(c0, jnp.uint32) + k0
    x1 = jnp.asarray(c1, jnp.uint32) + k1
    for i in range(5):
        for r in (_ROT_A if i % 2 == 0 else _ROT_B):
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def _bits2(seed, domain: int, pos: jax.Array):
    """Two uint32 streams at flat positions ``pos`` of (seed, domain)."""
    c0 = pos.astype(jnp.uint32)
    return threefry2x32(jnp.asarray(seed, jnp.uint32), jnp.uint32(domain),
                        c0, jnp.zeros_like(c0))


def _u01(bits: jax.Array) -> jax.Array:
    """uint32 -> f32 uniform in [0, 1): mantissa-fill then subtract 1."""
    f = jax.lax.bitcast_convert_type(
        (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000), jnp.float32)
    return f - jnp.float32(1.0)


def normal_at(seed, domain: int, pos: jax.Array) -> jax.Array:
    """f32 standard normals at flat positions ``pos`` (any shape), via
    Box-Muller over the position's two counter streams."""
    b0, b1 = _bits2(seed, domain, pos)
    u1 = jnp.float32(1.0) - _u01(b0)                 # (0, 1] — log-safe
    u2 = _u01(b1)
    rad = jnp.sqrt(jnp.float32(-2.0) * jnp.log(u1))
    return rad * jnp.cos(jnp.float32(2.0 * math.pi) * u2)


def sign_at(seed, domain: int, pos: jax.Array) -> jax.Array:
    """f32 Rademacher (+/-1) draws at flat positions ``pos``."""
    b0, _ = _bits2(seed, domain, pos)
    return jnp.where(b0 >> jnp.uint32(31) > 0,
                     jnp.float32(1.0), jnp.float32(-1.0))


def uniform_bits_at(seed, domain: int, pos: jax.Array) -> jax.Array:
    """Raw uint32 stream at flat positions ``pos`` (ldr support draw)."""
    b0, _ = _bits2(seed, domain, pos)
    return b0


def fold_seed(seed, data) -> jax.Array:
    """Derive a sub-seed: an independent uint32 stream keyed by ``data``
    (per-head index, per-request embed seed, ...). Broadcasting applies:
    fold_seed((H, 1), (1, B)) -> (H, B)."""
    d = jnp.asarray(data, jnp.uint32)
    x0, _ = threefry2x32(jnp.asarray(seed, jnp.uint32), jnp.uint32(DOM_FOLD),
                         d, jnp.zeros_like(d))
    return x0


# ---------------------------------------------------------------------------
# in-kernel tile regeneration (shared by the Pallas kernel and the tests)
# ---------------------------------------------------------------------------

def gen_tile(kind: str, seed, rows: jax.Array, cols: jax.Array, *,
             n: int, m: int, nb: int) -> jax.Array:
    """Regenerate the (tm, n) row tile A[rows, cols] straight from the
    seed — the zero-storage analogue of ``spinner._regen_tile``.

    ``rows``/``cols`` are int32 index grids (rows may exceed m on padded
    tiles; positions stay in-range by construction, the garbage rows'
    write-back is dropped by the out BlockSpec). Every entry is generated
    at its flat position in the canonical ``structured.init`` param
    array, so values match ``seeded_params`` bit for bit.
    """
    if kind in ("circulant", "skew_circulant"):
        blk = jnp.minimum(rows // n, nb - 1)
        off = rows % n
        pos = blk * n + (cols - off) % n             # flat into (nb, n) g
        val = normal_at(seed, DOM_G, pos)
        if kind == "skew_circulant":
            val = jnp.where(cols < off, -val, val)   # wrapped entries negated
        return val
    if kind == "toeplitz":
        d = jnp.clip(cols - rows, -(m - 1), n - 1)
        pos = jnp.where(d >= 0, d, n - 1 - d)        # structured._toeplitz_dense
        return normal_at(seed, DOM_G, pos)
    if kind == "hankel":
        pos = jnp.clip(rows + cols, 0, n + m - 2)
        return normal_at(seed, DOM_G, pos)
    if kind == "unstructured":
        pos = jnp.minimum(rows, m - 1) * n + cols    # flat into (m, n) g
        return normal_at(seed, DOM_G, pos)
    raise ValueError(kind)


def hd_signs(seed, n: int) -> tuple:
    """(d0, d1) f32 Rademacher diagonals of the HD preconditioner."""
    pos = jnp.arange(n, dtype=jnp.int32)
    return sign_at(seed, DOM_D0, pos), sign_at(seed, DOM_D1, pos)


# ---------------------------------------------------------------------------
# generator oracle: rebuild the structured.init param dict from a seed
# ---------------------------------------------------------------------------

def seeded_params(kind: str, n: int, m: int, seed, *, r: int = 1,
                  ldr_nnz: int = 4, use_hd: bool = True
                  ) -> Dict[str, jax.Array]:
    """The materialized twin of the zero-storage path: the exact f32
    param dict (``structured.init`` shapes) the seed encodes. Used by
    ``materialize`` / diagnostics / the ref+backward routes, and as the
    bit-exactness oracle in kernel tests."""
    from repro.core import structured
    b = structured.n_blocks(kind, m, n)
    if kind == "unstructured":
        g = normal_at(seed, DOM_G, jnp.arange(m * n)).reshape(m, n)
        params = {"g": g}
    elif kind in ("circulant", "skew_circulant"):
        params = {"g": normal_at(seed, DOM_G, jnp.arange(b * n)).reshape(b, n)}
    elif kind in ("toeplitz", "hankel"):
        params = {"g": normal_at(seed, DOM_G, jnp.arange(n + m - 1))}
    elif kind == "ldr":
        flat = jnp.arange(b * r * n)
        g = normal_at(seed, DOM_G, flat).reshape(b, r, n)
        # h support: the ldr_nnz smallest uniform keys per (block, rank)
        # row — a deterministic without-replacement draw; signs from an
        # independent stream, magnitude 1/sqrt(nnz * r) as in the paper.
        keys = uniform_bits_at(seed, DOM_H_IDX, flat).reshape(b, r, n)
        rank = jnp.argsort(jnp.argsort(keys, axis=-1), axis=-1)
        sgn = sign_at(seed, DOM_H_SGN, flat).reshape(b, r, n)
        val = sgn * jnp.float32(1.0 / math.sqrt(ldr_nnz * r))
        params = {"g": g, "h": jnp.where(rank < ldr_nnz, val, 0.0)}
    else:
        raise ValueError(f"unknown structured kind: {kind}")
    if use_hd:
        params["d0"], params["d1"] = hd_signs(seed, n)
    return params


def grouped_params(kind: str, n: int, m: int, seeds: jax.Array, *,
                   r: int = 1, ldr_nnz: int = 4, use_hd: bool = True
                   ) -> Dict[str, jax.Array]:
    """``seeded_params`` vmapped over a (G,) seed vector: every leaf gains
    the leading group axis the grouped spinner dispatch expects."""
    return jax.vmap(lambda s: seeded_params(kind, n, m, s, r=r,
                                            ldr_nnz=ldr_nnz,
                                            use_hd=use_hd))(seeds)
