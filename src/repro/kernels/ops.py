"""Public jit'd wrappers for the Pallas kernels with automatic fallback.

On TPU the Pallas path compiles natively; on CPU (this container) kernels
run in ``interpret=True`` mode for correctness, and large shapes route to
the pure-jnp reference (same semantics, faster than interpreting).

``use_pallas``: None = auto (pallas-interpret for small, jnp for big on
CPU; pallas-native on TPU), True/False = force.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import circulant as _circ
from . import fwht as _fwht
from . import paged_gather as _pgather
from . import ref as _ref
from . import srf_decode as _dec


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _route(use_pallas: Optional[bool], work_elems: int,
           interp_budget: int = 1 << 22) -> str:
    """-> 'native' | 'interpret' | 'ref'."""
    if use_pallas is False:
        return "ref"
    if _on_tpu():
        return "native"
    if use_pallas is True:
        return "interpret"
    return "interpret" if work_elems <= interp_budget else "ref"


def fwht(x: jax.Array, normalized: bool = True,
         use_pallas: Optional[bool] = None) -> jax.Array:
    route = _route(use_pallas, x.size)
    if route == "ref":
        return _ref.fwht_ref(x, normalized)
    return _fwht.fwht_pallas(x, normalized, interpret=(route == "interpret"))


def circulant_project(g: jax.Array, x: jax.Array, m: int,
                      epilogue: str = "identity",
                      sq: Optional[jax.Array] = None,
                      use_pallas: Optional[bool] = None) -> jax.Array:
    route = _route(use_pallas, x.shape[0] * m)
    if route == "ref":
        return _ref.circulant_project_ref(g, x, m, epilogue, sq)
    return _circ.circulant_project_pallas(
        g, x, m, epilogue, sq, interpret=(route == "interpret"))


def paged_gather(pool: jax.Array, tables: jax.Array,
                 use_pallas: Optional[bool] = None) -> jax.Array:
    """pool (N, P, D), tables (R, M) -> (R, M*P, D) contiguous history."""
    r, m = tables.shape
    route = _route(use_pallas, r * m * pool.shape[1] * pool.shape[2])
    if route == "ref":
        return _ref.paged_gather_ref(pool, tables)
    return _pgather.paged_gather_pallas(pool, tables,
                                        interpret=(route == "interpret"))


def srf_decode(s, z, phi_q, phi_k, v, eps: float = 1e-6,
               use_pallas: Optional[bool] = None):
    route = _route(use_pallas, s.size)
    if route == "ref":
        return _ref.srf_decode_ref(s, z, phi_q, phi_k, v, eps)
    return _dec.srf_decode_pallas(s, z, phi_q, phi_k, v, eps,
                                  interpret=(route == "interpret"))
