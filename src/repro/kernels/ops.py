"""Public jit'd wrappers for the Pallas kernels with automatic fallback.

On TPU the Pallas path compiles natively; on CPU (this container) kernels
run in ``interpret=True`` mode for correctness, and large shapes route to
the pure-jnp reference (same semantics, faster than interpreting).

``use_pallas``: None = auto (pallas-interpret for small, jnp for big on
CPU; pallas-native on TPU), True/False = force.

Routing cost model: every kernel routes on its TRUE work estimate (the
number of MACs / elements moved, B*n*m-style), not on input sizes — see
kernels/README.md for the table. ``REPRO_FORCE_PALLAS`` overrides the
auto route for debugging: ``1``/``true`` force the Pallas path (native on
TPU, interpret elsewhere), ``native``/``interpret`` force that exact
mode, ``0``/``false``/``ref`` force the jnp reference.

Every dispatch runs through ``repro.obs.profiling.dispatch``: the call is
wrapped in a ``jax.named_scope`` (profiler/HLO-visible, free at runtime)
and, after ``obs.enable_kernel_timing(registry)``, eager dispatches are
timed to completion into ``kernel_dispatch_seconds{kernel=...}``.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transforms
from repro.obs import profiling as _prof

from . import circulant as _circ
from . import fwht as _fwht
from . import paged_gather as _pgather
from . import ref as _ref
from . import seedgen as _seedgen
from . import spinner as _spin
from . import srf_decode as _dec


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _route(use_pallas: Optional[bool], work_elems: int,
           interp_budget: int = 1 << 24,
           auto_interpret: bool = True) -> str:
    """-> 'native' | 'interpret' | 'ref'.

    ``work_elems`` is the kernel's true work estimate (MACs or elements
    moved); the interpreter budget is compared against it, so all kernels
    flip to the jnp reference at the same *work* level, not at
    incomparable input-size levels.

    ``auto_interpret=False`` disables the small-shape interpreter pick in
    auto mode: off-TPU the jnp ref is chosen unless Pallas is explicitly
    forced. Hot-path ops (the fused spinner) use this — the interpreter
    is a correctness vehicle, measurably slower than the ref on CPU.
    """
    env = os.environ.get("REPRO_FORCE_PALLAS")
    if env:
        e = env.strip().lower()
        if e in ("0", "false", "ref"):
            return "ref"
        if e in ("native", "interpret"):
            return e
        if e in ("1", "true"):
            return "native" if _on_tpu() else "interpret"
        raise ValueError(     # a typo'd debug override must not misroute
            f"REPRO_FORCE_PALLAS={env!r}: expected 1/true/0/false/"
            "ref/native/interpret")
    if use_pallas is False:
        return "ref"
    if _on_tpu():
        return "native"
    if use_pallas is True:
        return "interpret"
    if not auto_interpret:
        return "ref"
    return "interpret" if work_elems <= interp_budget else "ref"


def fwht(x: jax.Array, normalized: bool = True,
         use_pallas: Optional[bool] = None) -> jax.Array:
    n = x.shape[-1]
    a, b = transforms.kron_factors(n)
    route = _route(use_pallas, x.size * (a + b))     # Kronecker-sandwich MACs
    if route == "ref":
        return _prof.dispatch("fwht", lambda: _ref.fwht_ref(x, normalized))
    return _prof.dispatch("fwht", lambda: _fwht.fwht_pallas(
        x, normalized, interpret=(route == "interpret")))


def circulant_project(g: jax.Array, x: jax.Array, m: int,
                      epilogue: str = "identity",
                      sq: Optional[jax.Array] = None,
                      use_pallas: Optional[bool] = None) -> jax.Array:
    route = _route(use_pallas, x.shape[0] * x.shape[-1] * m)   # B*n*m MACs
    if route == "ref":
        return _prof.dispatch("circulant_project",
                              lambda: _ref.circulant_project_ref(
                                  g, x, m, epilogue, sq))
    return _prof.dispatch("circulant_project",
                          lambda: _circ.circulant_project_pallas(
                              g, x, m, epilogue, sq,
                              interpret=(route == "interpret")))


def paged_gather(pool: jax.Array, tables: jax.Array,
                 use_pallas: Optional[bool] = None) -> jax.Array:
    """pool (N, P, D), tables (R, M) -> (R, M*P, D) contiguous history."""
    r, m = tables.shape
    route = _route(use_pallas, r * m * pool.shape[1] * pool.shape[2])
    if route == "ref":
        return _prof.dispatch("paged_gather",
                              lambda: _ref.paged_gather_ref(pool, tables))
    return _prof.dispatch("paged_gather",
                          lambda: _pgather.paged_gather_pallas(
                              pool, tables, interpret=(route == "interpret")))


def paged_gather_dequant(pool: jax.Array, scales: jax.Array,
                         tables: jax.Array, out_dtype=jnp.float32,
                         use_pallas: Optional[bool] = None) -> jax.Array:
    """int8 pool (N, P, D) + scales (N, P, 1), tables (R, M) ->
    (R, M*P, D) dequantized history in ``out_dtype`` (fused: the int8
    page never materializes at full width in HBM)."""
    r, m = tables.shape
    route = _route(use_pallas, r * m * pool.shape[1] * pool.shape[2])
    if route == "ref":
        return _prof.dispatch("paged_gather_dequant",
                              lambda: _ref.paged_gather_dequant_ref(
                                  pool, scales, tables, out_dtype))
    return _prof.dispatch("paged_gather_dequant",
                          lambda: _pgather.paged_gather_dequant_pallas(
                              pool, scales, tables, out_dtype,
                              interpret=(route == "interpret")))


def srf_decode(s, z, phi_q, phi_k, v, eps: float = 1e-6,
               use_pallas: Optional[bool] = None):
    route = _route(use_pallas, s.size)               # state bytes dominate
    if route == "ref":
        return _prof.dispatch("srf_decode",
                              lambda: _ref.srf_decode_ref(
                                  s, z, phi_q, phi_k, v, eps))
    return _prof.dispatch("srf_decode",
                          lambda: _dec.srf_decode_pallas(
                              s, z, phi_q, phi_k, v, eps,
                              interpret=(route == "interpret")))


# ---------------------------------------------------------------------------
# fused structured spinner  f(A . D1 H D0 . x)
# ---------------------------------------------------------------------------

_VMEM_BUDGET = 8 * 1024 * 1024     # bytes; ~half of a 16 MB VMEM core
_BLOCK_B_CANDIDATES = (512, 256, 128, 64, 32, 16, 8)
_BLOCK_M_CANDIDATES = (2048, 1024, 512, 256, 128)
_plan_cache: Dict[tuple, Tuple[int, int]] = {}


def _spinner_vmem_bytes(kind: str, n: int, m: int, tb: int, tm: int,
                        use_hd: bool, epilogue: str,
                        itemsize: int = 4, seeded: bool = False) -> int:
    """Resident bytes of one spinner program (VMEM feasibility model).

    Input/output tiles, generators, and d0/d1 are VMEM-resident at the
    INPUT dtype (``itemsize``). Everything the kernel COMPUTES with is
    f32 regardless of input dtype: the HD/sq scratch, the Kronecker
    Hadamard factors, the sandwich intermediate, the regenerated A tile
    (the dot consumes ``tile.astype(f32)``) and the pre-epilogue y — so
    those terms never shrink with a narrower input dtype.
    """
    f32 = 4
    by = tb * n * itemsize    # x tile
    by += (tb * n + tb) * f32                        # HD scratch + sq scratch
    by += tm * n * f32        # regenerated / streamed A tile (f32 for the dot)
    by += tb * tm * f32       # pre-epilogue y (f32)
    by += tb * tm * (2 if epilogue == "cos_sin" else 1) * itemsize  # out tile
    if use_hd:
        a, b = transforms.kron_factors(n)
        by += (a * a + b * b) * f32                  # hadamard factors
        by += 2 * n * itemsize                       # d0 / d1
        by += tb * n * f32                           # sandwich intermediate
    if seeded:
        # no resident generators; the counter-PRNG's uint32 grids and
        # Box-Muller temporaries live alongside the regenerated tile
        by += 2 * tm * n * 4
        return by
    if kind in ("circulant", "skew_circulant"):
        by += 2 * n * -(-m // n) * itemsize          # doubled generators
    elif kind in ("toeplitz", "hankel"):
        by += (n + m - 1) * itemsize
    # unstructured streams its (tm, n) tile — already counted above
    return by


def spinner_plan(kind: str, n: int, m: int, *, use_hd: bool = True,
                 epilogue: str = "identity", dtype=jnp.float32,
                 budget: int = _VMEM_BUDGET,
                 seeded: bool = False) -> Tuple[int, int]:
    """Pick (block_b, block_m) for the spinner kernel: sweep the candidate
    grid against the VMEM budget, preferring large row tiles (they
    amortize grid overhead) then large batch tiles. Cached per shape AND
    per dtype — bf16 tiles are half the resident bytes of f32 tiles, so
    the two must not share a plan (a bf16 warm-up would hand f32 an
    over-budget block). Serving factories pre-warm it (launch/steps.py)."""
    dt = jnp.dtype(dtype)
    key = (kind, n, m, use_hd, epilogue, dt.name, budget, seeded)
    if key in _plan_cache:
        return _plan_cache[key]
    best = (_BLOCK_B_CANDIDATES[-1], _BLOCK_M_CANDIDATES[-1])
    found = False
    for tm in _BLOCK_M_CANDIDATES:
        if found:
            break
        for tb in _BLOCK_B_CANDIDATES:
            if _spinner_vmem_bytes(kind, n, m, tb, min(tm, m), use_hd,
                                   epilogue, dt.itemsize, seeded) <= budget:
                best = (tb, tm)
                found = True
                break
    _plan_cache[key] = best
    return best


def _spinner_pallas_vjp(kind: str, m: int, use_hd: bool, epilogue: str,
                        y_scale: float, out_scale: float, tb: int, tm: int,
                        interpret: bool):
    """Pallas forward + jnp-reference backward (Pallas kernels have no
    native autodiff; the ref graph IS the semantics, so its VJP is exact
    up to float re-association)."""
    fwd_fn = functools.partial(
        _spin.spinner_project_pallas, kind, m=m, use_hd=use_hd,
        epilogue=epilogue, y_scale=y_scale, out_scale=out_scale,
        block_b=tb, block_m=tm, interpret=interpret)
    ref_fn = functools.partial(
        _ref.spinner_project_ref, kind, m=m, epilogue=epilogue,
        y_scale=y_scale, out_scale=out_scale)

    @jax.custom_vjp
    def f(g, x, d0, d1):
        return fwd_fn(g, x, d0=d0, d1=d1)

    def fwd(g, x, d0, d1):
        return f(g, x, d0, d1), (g, x, d0, d1)

    def bwd(res, dy):
        g, x, d0, d1 = res
        _, vjp = jax.vjp(lambda gg, xx, dd0, dd1:
                         ref_fn(gg, xx, d0=dd0, d1=dd1), g, x, d0, d1)
        return vjp(dy)

    f.defvjp(fwd, bwd)
    return f


@functools.partial(jax.jit, static_argnames=(
    "kind", "m", "epilogue", "y_scale", "out_scale", "grouped", "route",
    "block_b", "block_m"))
def _spinner_call(kind, g, x, m, d0, d1, h, *, epilogue, y_scale, out_scale,
                  grouped, route, block_b, block_m):
    """Single jit entry for both spinner routes: the group lift / leading-
    dim flatten / output reshape all trace away, so an eager caller pays
    exactly one dispatch (consumers under their own jit inline this)."""
    n = x.shape[-1]
    if grouped:
        gsz, lead = x.shape[0], x.shape[1:-1]
        xf = x.reshape(gsz, -1, n)
    else:
        gsz, lead = 1, x.shape[:-1]
        xf = x.reshape(1, -1, n)
        g = g[None]
        h = None if h is None else h[None]
        d0 = None if d0 is None else d0[None]
        d1 = None if d1 is None else d1[None]
    if route == "ref":
        y = _ref.spinner_project_ref(kind, g, xf, m, d0=d0, d1=d1, h=h,
                                     epilogue=epilogue, y_scale=y_scale,
                                     out_scale=out_scale)
    else:
        fn = _spinner_pallas_vjp(kind, m, d0 is not None, epilogue, y_scale,
                                 out_scale, block_b, block_m,
                                 interpret=(route == "interpret"))
        y = fn(g, xf, d0, d1)
    out_dim = 2 * m if epilogue == "cos_sin" else m
    shape = ((gsz,) + lead + (out_dim,)) if grouped else (lead + (out_dim,))
    return y.reshape(shape)


def spinner_project(kind: str, params: Dict[str, jax.Array], x: jax.Array,
                    m: int, epilogue: str = "identity",
                    y_scale: float = 1.0, out_scale: float = 1.0,
                    grouped: bool = False,
                    use_pallas: Optional[bool] = None,
                    block_b: Optional[int] = None,
                    block_m: Optional[int] = None) -> jax.Array:
    """One-pass  f(y_scale * A . D1 H D0 . x) * out_scale  for any P-model.

    params: the pmodel.init dict ({"g", optional "h", "d0", "d1"}); HD is
    applied iff "d0" is present. x: (..., n) — or (G, ..., n) with
    ``grouped=True`` and a leading group axis G on every param leaf
    (per-kv-head P-models in SRF attention run as one fused dispatch).

    Output (..., m), or (..., 2m) = [cos | sin] for the cos_sin epilogue.
    epilogues: identity | relu | heaviside | sign | exp | cos_sin; ``exp``
    computes exp(y - 0.5||x||^2) with the subtrahend taken in-kernel
    (valid because the normalized HD block is an isometry).

    Kinds circulant / skew_circulant / toeplitz / hankel run as implicit-
    tile Pallas kernels; unstructured streams dense row tiles through the
    same fused kernel; ldr always takes the fused jnp reference. The
    Pallas path carries a jnp-reference VJP, so it is safe under grad.
    """
    g = params["g"]
    h = params.get("h")
    d0 = params.get("d0")
    d1 = params.get("d1")
    use_hd = d0 is not None
    n = x.shape[-1]
    work = (x.size // n) * n * m

    pallas_ok = (kind in _spin.PALLAS_KINDS
                 and (not use_hd or transforms.is_pow2(n))
                 and n <= 8192 and n + m - 1 <= (1 << 22))
    route = _route(use_pallas, work, auto_interpret=False)
    if not pallas_ok:
        route = "ref"
    if route != "ref" and (block_b is None or block_m is None):
        auto_b, auto_m = spinner_plan(kind, n, m, use_hd=use_hd,
                                      epilogue=epilogue, dtype=x.dtype)
        block_b = block_b or auto_b
        block_m = block_m or auto_m
    return _prof.dispatch(
        "spinner_project",
        lambda: _spinner_call(kind, g, x, m, d0, d1, h, epilogue=epilogue,
                              y_scale=y_scale, out_scale=out_scale,
                              grouped=grouped, route=route,
                              block_b=block_b, block_m=block_m))


# ---------------------------------------------------------------------------
# seed mode: zero-storage spinner (one uint32 per projection)
# ---------------------------------------------------------------------------

def _spinner_seeded_vjp(kind: str, m: int, r: int, ldr_nnz: int,
                        use_hd: bool, epilogue: str, y_scale: float,
                        out_scale: float, tb: int, tm: int, interpret: bool):
    """Seeded Pallas forward + jnp-reference backward. The backward
    regenerates the oracle params from the seeds and differentiates the
    materialized reference w.r.t. x only — the seeds are integers, their
    cotangent is the symbolic float0 zero."""
    fwd_fn = functools.partial(
        _spin.spinner_project_seeded_pallas, kind, m=m, use_hd=use_hd,
        epilogue=epilogue, y_scale=y_scale, out_scale=out_scale,
        block_b=tb, block_m=tm, interpret=interpret)

    @jax.custom_vjp
    def f(seeds, x):
        return fwd_fn(seeds, x)

    def fwd(seeds, x):
        return f(seeds, x), (seeds, x)

    def bwd(res, dy):
        seeds, x = res
        n = x.shape[-1]
        params = _seedgen.grouped_params(kind, n, m, seeds, r=r,
                                         ldr_nnz=ldr_nnz, use_hd=use_hd)
        _, vjp = jax.vjp(
            lambda xx: _ref.spinner_project_ref(
                kind, params["g"], xx, m, d0=params.get("d0"),
                d1=params.get("d1"), h=params.get("h"), epilogue=epilogue,
                y_scale=y_scale, out_scale=out_scale), x)
        dx, = vjp(dy)
        return np.zeros(seeds.shape, jax.dtypes.float0), dx

    f.defvjp(fwd, bwd)
    return f


@functools.partial(jax.jit, static_argnames=(
    "kind", "m", "r", "ldr_nnz", "use_hd", "epilogue", "y_scale",
    "out_scale", "grouped", "route", "block_b", "block_m"))
def _spinner_seeded_call(kind, seeds, x, m, *, r, ldr_nnz, use_hd, epilogue,
                         y_scale, out_scale, grouped, route, block_b,
                         block_m):
    """Single jit entry for the seeded routes (mirror of _spinner_call)."""
    n = x.shape[-1]
    if grouped:
        gsz, lead = x.shape[0], x.shape[1:-1]
        xf = x.reshape(gsz, -1, n)
        sd = seeds.astype(jnp.uint32).reshape(gsz)
    else:
        gsz, lead = 1, x.shape[:-1]
        xf = x.reshape(1, -1, n)
        sd = jnp.asarray(seeds, jnp.uint32).reshape(1)
    if route == "ref":
        y = _ref.spinner_project_seeded_ref(kind, sd, xf, m, r=r,
                                            ldr_nnz=ldr_nnz, use_hd=use_hd,
                                            epilogue=epilogue,
                                            y_scale=y_scale,
                                            out_scale=out_scale)
    else:
        fn = _spinner_seeded_vjp(kind, m, r, ldr_nnz, use_hd, epilogue,
                                 y_scale, out_scale, block_b, block_m,
                                 interpret=(route == "interpret"))
        y = fn(sd, xf)
    out_dim = 2 * m if epilogue == "cos_sin" else m
    shape = ((gsz,) + lead + (out_dim,)) if grouped else (lead + (out_dim,))
    return y.reshape(shape)


def spinner_project_seeded(kind: str, seeds: jax.Array, x: jax.Array,
                           m: int, *, r: int = 1, ldr_nnz: int = 4,
                           use_hd: bool = True, epilogue: str = "identity",
                           y_scale: float = 1.0, out_scale: float = 1.0,
                           grouped: bool = False,
                           use_pallas: Optional[bool] = None,
                           block_b: Optional[int] = None,
                           block_m: Optional[int] = None) -> jax.Array:
    """Zero-storage  f(y_scale * A . D1 H D0 . x) * out_scale  where the
    whole projection — generator core AND the HD Rademacher diagonals —
    is regenerated on the fly from ``seeds`` (uint32; scalar, or (G,)
    with ``grouped=True``). No (m,)- or (m,n)-sized parameter tensor ever
    exists: the Pallas routes generate entries in VMEM per tile; the ref
    route materializes the oracle params transiently inside its trace.

    Same routing contract as :func:`spinner_project` (``ldr`` and custom
    shapes take the ref path); bit-identical to running the materialized
    spinner on ``kernels.seedgen.seeded_params(...)`` on the interpret /
    ref routes. Differentiable w.r.t. ``x``.
    """
    n = x.shape[-1]
    work = (x.size // n) * n * m

    pallas_ok = (kind in _spin.PALLAS_KINDS
                 and (not use_hd or transforms.is_pow2(n))
                 and n <= 8192 and n + m - 1 <= (1 << 22))
    route = _route(use_pallas, work, auto_interpret=False)
    if not pallas_ok:
        route = "ref"
    if route != "ref" and (block_b is None or block_m is None):
        auto_b, auto_m = spinner_plan(kind, n, m, use_hd=use_hd,
                                      epilogue=epilogue, dtype=x.dtype,
                                      seeded=True)
        block_b = block_b or auto_b
        block_m = block_m or auto_m
    return _prof.dispatch(
        "spinner_project_seeded",
        lambda: _spinner_seeded_call(kind, seeds, x, m, r=r,
                                     ldr_nnz=ldr_nnz, use_hd=use_hd,
                                     epilogue=epilogue, y_scale=y_scale,
                                     out_scale=out_scale, grouped=grouped,
                                     route=route, block_b=block_b,
                                     block_m=block_m))
