"""Pallas TPU kernel: the fused structured spinner  f(A . D1 H D0 . x).

The paper's whole pipeline (Step-1 HD preconditioner -> structured
projection -> pointwise f) is one cheap operator, but executed naively it
is 3+ dispatches with an HBM round trip between each:

    u = D0 x ; w = H u ; v = D1 w      (transforms.hd_preprocess)
    y = A v                            (structured.matvec, FFT)
    out = f(y)                         (pointwise epilogue)

This kernel runs the whole chain in a single ``pallas_call``: per batch
tile the HD sandwich is computed ONCE into VMEM scratch (Kronecker-form
FWHT — the MXU sandwich of kernels/fwht.py), then every row tile of the
structured matrix A is REGENERATED in VMEM from its O(n) generator and
fed straight to the MXU, with f fused as an epilogue before the single
write-back.  HBM traffic: x in, f(y) out, generators (O(n)); no
intermediate ever leaves the chip.

Implicit tile regeneration (A is never materialized in HBM), with
``rows = j*tm + iota`` the global row ids of the tile and ``cols`` the
column iota:

  circulant       A[i,j] = g[i//n, (j - i) mod n]
                  -> gather gg[blk, cols - off + n],  gg = [g, g]
  skew_circulant  wrapped entries negated
                  -> same gather from gg = [-g, g]
  toeplitz        A[i,j] = gen(j - i), gen(d>=0) = g[d], gen(d<0) = g[n-1-d]
                  -> gather glin[cols - rows + m - 1],
                     glin = [flip(g[n:]), g[:n]]          (length n+m-1)
  hankel          A[i,j] = g[i + j]  -> gather g[rows + cols]
  unstructured    dense g, streamed per row tile by BlockSpec (no gather
                  — still fuses HD + matmul + epilogue in one pass)

``ldr`` tiles cost O(r n) per entry to regenerate and stay on the jnp
reference path (kernels/ref.py).

Grid: (groups, batch_tiles, row_tiles); the group axis carries
independent P-models (one per kv head in SRF attention) so per-head
feature maps run as ONE kernel instead of a vmap of dispatches.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import transforms

from . import seedgen

EPILOGUES = ("identity", "relu", "heaviside", "sign", "exp", "cos_sin")
PALLAS_KINDS = ("circulant", "skew_circulant", "toeplitz", "hankel",
                "unstructured")


def _apply_epilogue(y, epilogue, sq, out_scale):
    if epilogue == "identity":
        r = y
    elif epilogue == "relu":
        r = jnp.maximum(y, 0.0)
    elif epilogue == "heaviside":
        r = (y >= 0).astype(y.dtype)
    elif epilogue == "sign":
        r = jnp.sign(y)
    elif epilogue == "exp":
        r = jnp.exp(y - sq)
    else:
        raise ValueError(epilogue)
    return r if out_scale == 1.0 else r * out_scale


def _regen_tile(kind, gt, j, *, n, m, tm, nb, gl):
    """Rebuild the (tm, n) row tile of A in VMEM from the O(n) generator.

    Indices from padded row tiles (rows >= m) are clamped; those rows are
    garbage but their write-back is dropped by the out BlockSpec.
    """
    rows = j * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tm, n), 1)
    if kind in ("circulant", "skew_circulant"):
        blk = jnp.minimum(rows // n, nb - 1)
        off = rows % n
        idx = cols - off + n                     # in [1, 2n); sign folded in gt
        return gt[blk, idx]
    if kind == "toeplitz":
        idx = jnp.clip(cols - rows + (m - 1), 0, gl - 1)
        return gt[0][idx]
    if kind == "hankel":
        idx = jnp.clip(rows + cols, 0, gl - 1)
        return gt[0][idx]
    raise ValueError(kind)


def _write_tile(o_ref, y, epilogue: str, sq_ref, out_scale: float):
    """Fused epilogue + the single write-back (shared by the materialized
    and the seeded kernels — identical tail, bit for bit)."""
    if epilogue == "cos_sin":
        s = out_scale
        o_ref[0, :, 0, :] = (jnp.cos(y) * s).astype(o_ref.dtype)
        o_ref[0, :, 1, :] = (jnp.sin(y) * s).astype(o_ref.dtype)
    else:
        sq = sq_ref[...] if epilogue == "exp" else None
        o_ref[0] = _apply_epilogue(y, epilogue, sq, out_scale).astype(o_ref.dtype)


def _spinner_kernel(*refs, kind: str, n: int, m: int, tb: int, tm: int,
                    a: int, b: int, nb: int, gl: int, use_hd: bool,
                    epilogue: str, y_scale: float, out_scale: float):
    it = iter(refs)
    x_ref = next(it)
    if use_hd:
        d0_ref, d1_ref, ha_ref, hb_ref = next(it), next(it), next(it), next(it)
    gt_ref = next(it)
    o_ref = next(it)
    hd_ref = next(it)                            # VMEM scratch (tb, n) f32
    sq_ref = next(it)                            # VMEM scratch (tb, 1) f32
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _hd():                                   # once per (group, batch tile)
        x = x_ref[0].astype(jnp.float32)         # (tb, n)
        if epilogue == "exp":                    # ||v|| = ||x|| (HD isometry)
            sq_ref[...] = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
        if use_hd:
            u = x * d0_ref[0, 0].astype(jnp.float32)
            z = jnp.dot(u.reshape(tb * a, b), hb_ref[...],
                        preferred_element_type=jnp.float32)
            z = z.reshape(tb, a, b).transpose(0, 2, 1).reshape(tb * b, a)
            w = jnp.dot(z, ha_ref[...], preferred_element_type=jnp.float32)
            w = w.reshape(tb, b, a).transpose(0, 2, 1).reshape(tb, n)
            x = w * (1.0 / math.sqrt(n)) * d1_ref[0, 0].astype(jnp.float32)
        hd_ref[...] = x

    v = hd_ref[...]                              # (tb, n) f32
    if kind == "unstructured":
        tile = gt_ref[0]                         # (tm, n) streamed by BlockSpec
    else:
        tile = _regen_tile(kind, gt_ref[0], j, n=n, m=m, tm=tm, nb=nb, gl=gl)
    y = jax.lax.dot_general(v, tile.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (tb, tm)
    if y_scale != 1.0:
        y = y * y_scale
    _write_tile(o_ref, y, epilogue, sq_ref, out_scale)


def _gen_table(kind: str, g: jax.Array, n: int) -> jax.Array:
    """Per-kind generator layout consumed by ``_regen_tile`` (leading G)."""
    if kind == "circulant":
        return jnp.concatenate([g, g], axis=-1)            # (G, nb, 2n)
    if kind == "skew_circulant":
        return jnp.concatenate([-g, g], axis=-1)           # wrapped -> -g
    if kind == "toeplitz":                                 # glin[d + m - 1]
        return jnp.concatenate([jnp.flip(g[..., n:], -1), g[..., :n]],
                               axis=-1)[:, None, :]        # (G, 1, n+m-1)
    if kind == "hankel":
        return g[:, None, :]                               # (G, 1, n+m-1)
    if kind == "unstructured":
        return g                                           # (G, m, n) dense
    raise ValueError(kind)


@functools.partial(jax.jit, static_argnames=(
    "kind", "m", "use_hd", "epilogue", "y_scale", "out_scale",
    "block_b", "block_m", "interpret"))
def spinner_project_pallas(kind: str, g: jax.Array, x: jax.Array, m: int,
                           d0: Optional[jax.Array] = None,
                           d1: Optional[jax.Array] = None,
                           use_hd: bool = True,
                           epilogue: str = "identity",
                           y_scale: float = 1.0, out_scale: float = 1.0,
                           block_b: int = 256, block_m: int = 512,
                           interpret: bool = True) -> jax.Array:
    """x: (G, B, n) -> (G, B, m)  ((G, B, 2m) for cos_sin: [cos | sin]).

    g: generators with leading group axis — (G, nb, n) for circulant /
    skew_circulant, (G, n+m-1) for toeplitz / hankel, (G, m, n) dense.
    d0/d1: (G, n) Rademacher diagonals when ``use_hd``.

    All arithmetic is f32 in VMEM (bf16 inputs upcast on load, cast back
    on the single write). Awkward B / m (not multiples of the block
    sizes) are handled by grid padding: OOB gathers clamp, OOB writes
    drop.
    """
    assert epilogue in EPILOGUES, epilogue
    assert kind in PALLAS_KINDS, kind
    gsz, bsz, n = x.shape
    if use_hd:
        assert transforms.is_pow2(n), f"HD needs power-of-two n, got {n}"
    tb = min(block_b, bsz)
    tm = min(block_m, m)
    gt = _gen_table(kind, g, n)
    nb, gl = gt.shape[-2], gt.shape[-1]
    grid = (gsz, pl.cdiv(bsz, tb), pl.cdiv(m, tm))

    in_specs = [pl.BlockSpec((1, tb, n), lambda gi, i, j: (gi, i, 0))]
    inputs = [x]
    a = b = 1
    if use_hd:
        a, b = transforms.kron_factors(n)
        ha = transforms.hadamard(a, jnp.float32, normalized=False)
        hb = transforms.hadamard(b, jnp.float32, normalized=False)
        in_specs += [pl.BlockSpec((1, 1, n), lambda gi, i, j: (gi, 0, 0)),
                     pl.BlockSpec((1, 1, n), lambda gi, i, j: (gi, 0, 0)),
                     pl.BlockSpec((a, a), lambda gi, i, j: (0, 0)),
                     pl.BlockSpec((b, b), lambda gi, i, j: (0, 0))]
        inputs += [d0[:, None, :], d1[:, None, :], ha, hb]
    if kind == "unstructured":                   # stream dense row tiles
        in_specs += [pl.BlockSpec((1, tm, n), lambda gi, i, j: (gi, j, 0))]
    else:                                        # O(n) generator resident
        in_specs += [pl.BlockSpec((1, nb, gl), lambda gi, i, j: (gi, 0, 0))]
    inputs += [gt]

    if epilogue == "cos_sin":
        out_shape = jax.ShapeDtypeStruct((gsz, bsz, 2, m), x.dtype)
        out_specs = pl.BlockSpec((1, tb, 2, tm), lambda gi, i, j: (gi, i, 0, j))
    else:
        out_shape = jax.ShapeDtypeStruct((gsz, bsz, m), x.dtype)
        out_specs = pl.BlockSpec((1, tb, tm), lambda gi, i, j: (gi, i, j))

    kernel = functools.partial(
        _spinner_kernel, kind=kind, n=n, m=m, tb=tb, tm=tm, a=a, b=b,
        nb=nb, gl=gl, use_hd=use_hd, epilogue=epilogue,
        y_scale=y_scale, out_scale=out_scale)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((tb, n), jnp.float32),
                        pltpu.VMEM((tb, 1), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    if epilogue == "cos_sin":
        y = y.reshape(gsz, bsz, 2 * m)           # row-major: [cos | sin]
    return y


# ---------------------------------------------------------------------------
# seed mode: regenerate g / D0 / D1 from a 32-bit seed INSIDE the kernel
# ---------------------------------------------------------------------------

def _seeded_spinner_kernel(*refs, kind: str, n: int, m: int, tb: int,
                           tm: int, a: int, b: int, nb: int, use_hd: bool,
                           epilogue: str, y_scale: float, out_scale: float):
    """The fused spinner with ZERO generator inputs: every A-tile entry
    and both HD diagonals are regenerated in VMEM from the group's seed
    via the counter-based PRNG (kernels/seedgen.py). HBM traffic is x in,
    f(y) out, and one uint32 per group — the O(1)-storage limit of the
    paper's randomness recycling.

    Values are generated at FLAT PARAM POSITIONS, so they match the
    materialized ``seedgen.seeded_params`` oracle bit for bit and are
    independent of the (tb, tm) tiling the autotuner picks.
    """
    it = iter(refs)
    x_ref = next(it)
    seed_ref = next(it)                          # (1, 1) uint32 per group
    if use_hd:
        ha_ref, hb_ref = next(it), next(it)
    o_ref = next(it)
    hd_ref = next(it)                            # VMEM scratch (tb, n) f32
    sq_ref = next(it)                            # VMEM scratch (tb, 1) f32
    j = pl.program_id(2)
    seed = seed_ref[0, 0]

    @pl.when(j == 0)
    def _hd():                                   # once per (group, batch tile)
        x = x_ref[0].astype(jnp.float32)         # (tb, n)
        if epilogue == "exp":                    # ||v|| = ||x|| (HD isometry)
            sq_ref[...] = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
        if use_hd:
            pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
            d0 = seedgen.sign_at(seed, seedgen.DOM_D0, pos)
            d1 = seedgen.sign_at(seed, seedgen.DOM_D1, pos)
            u = x * d0
            z = jnp.dot(u.reshape(tb * a, b), hb_ref[...],
                        preferred_element_type=jnp.float32)
            z = z.reshape(tb, a, b).transpose(0, 2, 1).reshape(tb * b, a)
            w = jnp.dot(z, ha_ref[...], preferred_element_type=jnp.float32)
            w = w.reshape(tb, b, a).transpose(0, 2, 1).reshape(tb, n)
            x = w * (1.0 / math.sqrt(n)) * d1
        hd_ref[...] = x

    v = hd_ref[...]                              # (tb, n) f32
    rows = j * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tm, n), 1)
    tile = seedgen.gen_tile(kind, seed, rows, cols, n=n, m=m, nb=nb)
    y = jax.lax.dot_general(v, tile, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (tb, tm)
    if y_scale != 1.0:
        y = y * y_scale
    _write_tile(o_ref, y, epilogue, sq_ref, out_scale)


@functools.partial(jax.jit, static_argnames=(
    "kind", "m", "use_hd", "epilogue", "y_scale", "out_scale",
    "block_b", "block_m", "interpret"))
def spinner_project_seeded_pallas(kind: str, seeds: jax.Array, x: jax.Array,
                                  m: int, use_hd: bool = True,
                                  epilogue: str = "identity",
                                  y_scale: float = 1.0,
                                  out_scale: float = 1.0,
                                  block_b: int = 256, block_m: int = 512,
                                  interpret: bool = True) -> jax.Array:
    """Seed-mode twin of :func:`spinner_project_pallas`.

    x: (G, B, n) -> (G, B, m) ((G, B, 2m) for cos_sin); ``seeds``: (G,)
    uint32, one independent projection per group. No generator, d0 or d1
    tensors exist anywhere — each grid step regenerates what it consumes.
    """
    assert epilogue in EPILOGUES, epilogue
    assert kind in PALLAS_KINDS, kind
    gsz, bsz, n = x.shape
    if use_hd:
        assert transforms.is_pow2(n), f"HD needs power-of-two n, got {n}"
    tb = min(block_b, bsz)
    tm = min(block_m, m)
    nb = -(-m // n) if kind in ("circulant", "skew_circulant") else 1
    grid = (gsz, pl.cdiv(bsz, tb), pl.cdiv(m, tm))

    in_specs = [pl.BlockSpec((1, tb, n), lambda gi, i, j: (gi, i, 0)),
                pl.BlockSpec((1, 1), lambda gi, i, j: (gi, 0))]
    inputs = [x, seeds.astype(jnp.uint32).reshape(gsz, 1)]
    a = b = 1
    if use_hd:
        a, b = transforms.kron_factors(n)
        ha = transforms.hadamard(a, jnp.float32, normalized=False)
        hb = transforms.hadamard(b, jnp.float32, normalized=False)
        in_specs += [pl.BlockSpec((a, a), lambda gi, i, j: (0, 0)),
                     pl.BlockSpec((b, b), lambda gi, i, j: (0, 0))]
        inputs += [ha, hb]

    if epilogue == "cos_sin":
        out_shape = jax.ShapeDtypeStruct((gsz, bsz, 2, m), x.dtype)
        out_specs = pl.BlockSpec((1, tb, 2, tm), lambda gi, i, j: (gi, i, 0, j))
    else:
        out_shape = jax.ShapeDtypeStruct((gsz, bsz, m), x.dtype)
        out_specs = pl.BlockSpec((1, tb, tm), lambda gi, i, j: (gi, i, j))

    kernel = functools.partial(
        _seeded_spinner_kernel, kind=kind, n=n, m=m, tb=tb, tm=tm, a=a, b=b,
        nb=nb, use_hd=use_hd, epilogue=epilogue,
        y_scale=y_scale, out_scale=out_scale)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((tb, n), jnp.float32),
                        pltpu.VMEM((tb, 1), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    if epilogue == "cos_sin":
        y = y.reshape(gsz, bsz, 2 * m)           # row-major: [cos | sin]
    return y
