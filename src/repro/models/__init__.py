"""Model zoo: one config-driven implementation covering all assigned archs."""
from . import attention, frontends, hooks, layers, moe, ssm, transformer

__all__ = ["attention", "frontends", "hooks", "layers", "moe", "ssm",
           "transformer"]
