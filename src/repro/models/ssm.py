"""Mamba-2 (SSD, state-space duality) block — chunked scan form.

Follows the minimal-SSD formulation of Dao & Gu (arXiv:2405.21060):
scalar-per-head decay A, per-step dt, shared B/C (n_groups=1),
depthwise causal conv on (x, B, C), gated RMSNorm, out projection.

Train/prefill run a chunk-parallel scan (O(L c) per head with chunk c);
decode is a single recurrent state update. The decode state
(B, nh, state, hd) is sequence-length-free — the same O(1)-in-L serving
story as SRF attention, which is why the hybrid/ssm archs run the
long_500k cells natively.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def ssm_init(rng, cfg, dtype) -> Dict:
    """Projections are SPLIT by role (z / x / BC / dt) instead of one merged
    in_proj so each piece gets a clean TP sharding (x,z: column-parallel;
    BC/dt: replicated — they are tiny)."""
    keys = jax.random.split(rng, 8)
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "wz": layers.dense_init(keys[0], d, di, dtype),
        "wx": layers.dense_init(keys[1], d, di, dtype),
        "wbc": layers.dense_init(keys[2], d, 2 * ns, dtype),
        "wdt": layers.dense_init(keys[3], d, nh, dtype),
        "conv_x": (jax.random.normal(keys[4], (cfg.ssm_conv, di)) * 0.1).astype(dtype),
        "conv_bc": (jax.random.normal(keys[5], (cfg.ssm_conv, 2 * ns)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * ns,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "d_skip": jnp.ones((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": layers.dense_init(keys[6], di, d, dtype),
    }


def _project(p, cfg, x):
    """-> z (di), xbc_raw (di + 2ns), dt_raw (nh)."""
    z = x @ p["wz"]
    xbc = jnp.concatenate([x @ p["wx"], x @ p["wbc"]], axis=-1)
    dt = x @ p["wdt"]
    return z, xbc, dt


def _conv_w(p):
    return jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)


def init_ssm_cache(cfg, batch: int, dtype) -> Dict:
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
            "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                              cfg.ssm_head_dim), jnp.float32),
            "idx": jnp.zeros((), jnp.int32)}


def _causal_conv(w, b, x):
    """Depthwise causal conv via k static shifts. x: (B, L, C), w: (k, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return y + b


def _split_xbc(cfg, xbc):
    di, ns = cfg.d_inner, cfg.ssm_state
    return jnp.split(xbc, [di, di + ns], axis=-1)


def ssm_apply(p, cfg, x: jax.Array, mode: str, cache: Optional[Dict] = None
              ) -> Tuple[jax.Array, Optional[Dict]]:
    if mode == "decode":
        return _ssm_decode(p, cfg, x, cache)
    b, l, d = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc_raw, dt = _project(p, cfg, x)
    xbc = jax.nn.silu(_causal_conv(_conv_w(p), p["conv_b"], xbc_raw))
    xs, bs, cs = _split_xbc(cfg, xbc)
    xs = xs.reshape(b, l, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (nh,) negative
    dta = dt * a                                           # (B, L, nh)

    c = min(cfg.ssm_chunk, l)
    lp = l
    if l % c:
        # zero-pad the DERIVED tensors to a chunk multiple: dta=dt=0 makes
        # padded steps exact identities for the state; padded outputs are
        # sliced off below.
        pad = c - l % c
        lp = l + pad
        p2 = ((0, 0), (0, pad))
        xs = jnp.pad(xs, p2 + ((0, 0), (0, 0)))
        bs = jnp.pad(bs, p2 + ((0, 0),))
        cs = jnp.pad(cs, p2 + ((0, 0),))
        dta = jnp.pad(dta, p2 + ((0, 0),))
        dt = jnp.pad(dt, p2 + ((0, 0),))
    nc = lp // c
    xs_c = xs.reshape(b, nc, c, nh, hd).transpose(1, 0, 2, 3, 4)
    bs_c = bs.reshape(b, nc, c, ns).transpose(1, 0, 2, 3)
    cs_c = cs.reshape(b, nc, c, ns).transpose(1, 0, 2, 3)
    dta_c = dta.reshape(b, nc, c, nh).transpose(1, 0, 2, 3)
    dt_c = dt.reshape(b, nc, c, nh).transpose(1, 0, 2, 3)
    del lp

    def step(state, inp):
        xc, bc, cc, dtac, dtc = inp                        # per-chunk slices
        cum = jnp.cumsum(dtac, axis=1)                     # (B, c, nh) <= 0
        # intra-chunk: G[b,h,i,j] = (C_i.B_j) exp(cum_i - cum_j) dt_j, j <= i
        scores = jnp.einsum("bis,bjs->bij", cc, bc)        # (B, c, c)
        diff = cum[:, :, None, :] - cum[:, None, :, :]     # (B, c, c, nh)
        # clamp BEFORE exp: in the masked (j > i) region diff > 0 and
        # exp overflows to inf -> 0*inf = NaN in the where-gradient. The
        # valid (j <= i) region always has diff <= 0, so min(diff, 0) is
        # exact there and keeps the backward finite.
        decay = jnp.exp(jnp.minimum(diff, 0.0))
        tri = jnp.tril(jnp.ones((c, c), bool))
        gate = jnp.where(tri[None, :, :, None], decay, 0.0)
        # the (B, c, c, nh) gate tensor dominates SSD HBM traffic; compute
        # the mask/exp in f32 for stability, contract in bf16 (2x less
        # bytes through the MXU — EXPERIMENTS.md §Perf-hillclimb)
        g = (scores[..., None] * gate * dtc[:, None, :, :]).astype(xc.dtype)
        y_intra = jnp.einsum("bijh,bjhd->bihd", g, xc).astype(jnp.float32)
        # inter-chunk: y_i += C_i . (exp(cum_i) S)
        y_inter = jnp.einsum("bis,bih,bhsd->bihd", cc, jnp.exp(cum), state)
        # state update: S' = exp(cum_T) S + sum_j exp(cum_T - cum_j) dt_j B_j (x) x_j
        tot = cum[:, -1, :]                                # (B, nh)
        w = jnp.exp(tot[:, None, :] - cum) * dtc           # (B, c, nh)
        s_new = jnp.exp(tot)[:, :, None, None] * state + \
            jnp.einsum("bjh,bjs,bjhd->bhsd", w, bs_cast(bc), xc.astype(jnp.float32))
        return s_new, (y_intra + y_inter)

    def bs_cast(bc):
        return bc.astype(jnp.float32)

    s0 = jnp.zeros((b, nh, ns, hd), jnp.float32)
    # checkpoint the chunk body: backward recomputes the (B, c, c, nh)
    # gate tensor per chunk instead of keeping all chunks' gates alive
    # (peak regression otherwise; EXPERIMENTS.md §Perf-hillclimb)
    s_fin, ys = jax.lax.scan(jax.checkpoint(step), s0,
                             (xs_c, bs_c, cs_c, dta_c, dt_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, -1, nh, hd)[:, :l]
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)[:, :l]
    y = y.reshape(b, l, di).astype(x.dtype)
    y = layers.rmsnorm({"w": p["norm_w"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]

    new_cache = None
    if mode == "prefill":
        # last ssm_conv-1 RAW (pre-conv) xbc inputs feed the decode conv
        k1 = cfg.ssm_conv - 1
        xbc_tail = jnp.pad(xbc_raw, ((0, 0), (k1, 0), (0, 0)))[:, l:l + k1]
        new_cache = {"conv": xbc_tail.astype(x.dtype), "ssm": s_fin,
                     "idx": jnp.asarray(l, jnp.int32)}
    return out, new_cache


def _ssm_decode(p, cfg, x, cache):
    """Single-token recurrence. x: (B, 1, d)."""
    b = x.shape[0]
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc_new, dt = _project(p, cfg, x)
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B, k, cd)
    xbc = jnp.einsum("bkc,kc->bc", window, _conv_w(p)) + p["conv_b"]
    xbc = jax.nn.silu(xbc)[:, None, :]
    xs, bs, cs = _split_xbc(cfg, xbc)
    xs = xs.reshape(b, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                 # (B, nh)
    s = cache["ssm"] * decay[:, :, None, None] + \
        jnp.einsum("bh,bs,bhd->bhsd", dt, bs[:, 0].astype(jnp.float32),
                   xs.astype(jnp.float32))
    y = jnp.einsum("bs,bhsd->bhd", cs[:, 0], s)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = layers.rmsnorm({"w": p["norm_w"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    new_cache = {"conv": window[:, 1:], "ssm": s, "idx": cache["idx"] + 1}
    return out, new_cache


def paged_ssm_step(p, cfg, x: jax.Array, q_valid: jax.Array, pool: Dict,
                  slots: jax.Array) -> Tuple[jax.Array, Dict]:
    """Paged serving step: C tokens per request against a carried state.

    x: (B, C, d); q_valid: (B, C) bool (dense prefix — padding only at the
    chunk tail); pool: {"conv": (S, k-1, cd), "ssm": (S, nh, ns, hd)};
    slots: (B,) page ids. Covers both chunked prefill (C = chunk) and
    decode (C = 1); invalid steps get dt = 0, which makes their state
    update an exact identity, and the conv tail is re-gathered from the
    last valid inputs so tail padding never leaks into the next chunk.
    """
    b, c, d = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    k1 = cfg.ssm_conv - 1
    conv_st = pool["conv"][slots]                        # (B, k-1, cd)
    ssm_st = pool["ssm"][slots]                          # (B, nh, ns, hd)

    z, xbc_raw, dt = _project(p, cfg, x)
    xbc_raw = xbc_raw * q_valid[..., None].astype(xbc_raw.dtype)
    full = jnp.concatenate([conv_st.astype(xbc_raw.dtype), xbc_raw], axis=1)
    w = _conv_w(p)
    y = sum(full[:, i:i + c, :] * w[i] for i in range(cfg.ssm_conv))
    xbc = jax.nn.silu(y + p["conv_b"])
    xs, bs, cs = _split_xbc(cfg, xbc)
    xs = xs.reshape(b, c, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    dt = dt * q_valid.astype(jnp.float32)[..., None]     # identity on pads
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    def step(state, inp):
        x_t, b_t, c_t, dt_t = inp                        # (B, nh, hd) ...
        decay = jnp.exp(dt_t * a)                        # (B, nh)
        state = state * decay[:, :, None, None] + \
            jnp.einsum("bh,bs,bhd->bhsd", dt_t, b_t.astype(jnp.float32),
                       x_t.astype(jnp.float32))
        y_t = jnp.einsum("bs,bhsd->bhd", c_t, state)
        return state, y_t

    xs_t = xs.transpose(1, 0, 2, 3)
    bs_t = bs.transpose(1, 0, 2)
    cs_t = cs.transpose(1, 0, 2)
    dt_t = dt.transpose(1, 0, 2)
    s_fin, ys = jax.lax.scan(step, ssm_st, (xs_t, bs_t, cs_t, dt_t))
    y = ys.transpose(1, 0, 2, 3)                         # (B, C, nh, hd)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(b, c, di).astype(x.dtype)
    y = layers.rmsnorm({"w": p["norm_w"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]

    # conv tail = last k-1 inputs ending at the final VALID token
    n_valid = jnp.sum(q_valid.astype(jnp.int32), axis=1)           # (B,)
    idx = n_valid[:, None] + jnp.arange(k1)[None, :]               # (B, k-1)
    tail = jnp.take_along_axis(full, idx[..., None], axis=1)
    new_pool = {"conv": pool["conv"].at[slots].set(tail.astype(pool["conv"].dtype)),
                "ssm": pool["ssm"].at[slots].set(s_fin)}
    return out, new_pool
