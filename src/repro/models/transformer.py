"""Unified model: decoder LMs (dense/MoE/MLA), SSM, hybrid, enc-dec, VLM.

One config-driven implementation covering all ten assigned architectures.
Layers are grouped into SEGMENTS of identical structure and executed with
``lax.scan`` over stacked parameters (constant-size HLO at any depth —
what makes 512-device compiles fast) with selectable remat.

Public API (pure functions):
    init(rng, cfg)                       -> params
    forward(params, cfg, batch)          -> (logits, aux)     train mode
    loss_fn(params, cfg, batch)          -> (loss, metrics)
    init_serve_cache(cfg, batch, maxlen) -> cache
    prefill(params, cfg, batch, cache)   -> (last_logits, cache)
    decode_step(params, cfg, cache, tok) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention, frontends, hooks, layers, moe, ssm


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

def segments(cfg) -> List[Tuple[str, int]]:
    """[(layer_kind, count)] for the decoder stack."""
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("hybrid", cfg.n_layers)]
    if cfg.is_encdec:
        return [("dense_cross", cfg.n_layers)]
    if cfg.is_moe:
        segs = []
        if cfg.moe_first_dense:
            segs.append(("dense", cfg.moe_first_dense))
        segs.append(("moe", cfg.n_layers - cfg.moe_first_dense))
        return segs
    return [("dense", cfg.n_layers)]


def _layer_plan(cfg) -> List[Tuple[str, int, Tuple[str, ...]]]:
    """Serving-state plan: per segment ``(kind, count, components)``.

    ``components`` names the decode-state objects EVERY layer of the
    segment owns — ``"attn"`` (kv / mla pages or the srf constant state,
    resolved by ``serving.paged_cache.attn_family_for``) and/or ``"ssm"``
    (the ssd constant state). Hybrid layers own both; the enc-dec
    encoder memory is model-level (one pool, not per layer) and is keyed
    off ``cfg.is_encdec`` by the pool plan instead."""
    plan = []
    for kind, count in segments(cfg):
        if kind == "ssm":
            comps: Tuple[str, ...] = ("ssm",)
        elif kind == "hybrid":
            comps = ("attn", "ssm")
        else:
            comps = ("attn",)
        plan.append((kind, count, comps))
    return plan


def layer_init(rng, cfg, kind: str, dtype) -> Dict:
    keys = jax.random.split(rng, 8)
    d = cfg.d_model
    p: Dict = {"ln1": layers.rmsnorm_init(d, dtype)}
    if kind == "ssm":
        p["ssm"] = ssm.ssm_init(keys[0], cfg, dtype)
        return p
    if kind == "hybrid":
        p["attn"] = attention.attn_init(keys[0], cfg, dtype)
        p["ssm"] = ssm.ssm_init(keys[1], cfg, dtype)
        p["fuse_na"] = layers.rmsnorm_init(d, dtype)
        p["fuse_ns"] = layers.rmsnorm_init(d, dtype)
        p["ln2"] = layers.rmsnorm_init(d, dtype)
        p["mlp"] = layers.mlp_init(keys[2], d, cfg.d_ff, dtype)
        return p
    p["attn"] = attention.attn_init(keys[0], cfg, dtype)
    p["ln2"] = layers.rmsnorm_init(d, dtype)
    if kind == "dense_cross":
        p["ln_x"] = layers.rmsnorm_init(d, dtype)
        p["cross"] = attention.cross_attn_init(keys[1], cfg, dtype)
        p["mlp"] = layers.mlp_init(keys[2], d, cfg.d_ff, dtype)
    elif kind == "moe":
        p["moe"] = moe.moe_init(keys[1], cfg, dtype)
    else:  # dense
        p["mlp"] = layers.mlp_init(keys[1], d, cfg.d_ff, dtype)
    return p


def layer_apply(p, cfg, kind: str, x, positions, mode: str,
                cache: Optional[Dict], pos3=None, memory=None
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """-> (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h, new_cache = ssm.ssm_apply(p["ssm"], cfg, layers.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                     mode, cache)
        return x + h, new_cache, aux
    if kind == "hybrid":
        h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, cache_a = attention.attention(p["attn"], cfg, h, positions, mode,
                                         None if cache is None else cache["attn"], pos3)
        s, cache_s = ssm.ssm_apply(p["ssm"], cfg, h, mode,
                                   None if cache is None else cache["ssm"])
        fused = 0.5 * (layers.rmsnorm(p["fuse_na"], a, cfg.norm_eps)
                       + layers.rmsnorm(p["fuse_ns"], s, cfg.norm_eps))
        x = x + fused
        x = x + layers.mlp(p["mlp"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
        new_cache = None
        if cache_a is not None or cache_s is not None:
            new_cache = {"attn": cache_a, "ssm": cache_s}
        return x, new_cache, aux
    # attention families
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = attention.attention(p["attn"], cfg, h, positions, mode,
                                       cache, pos3)
    x = x + a
    if kind == "dense_cross" and memory is not None:
        x = x + attention.cross_attention(
            p["cross"], cfg, layers.rmsnorm(p["ln_x"], x, cfg.norm_eps), memory)
    h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y, aux = moe.moe_apply(p["moe"], cfg, h2)
    else:
        y = layers.mlp(p["mlp"], h2)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init(rng, cfg) -> Dict:
    dt = _dtype(cfg)
    keys = jax.random.split(rng, 8)
    params: Dict = {"embed": layers.embed_init(keys[0], cfg.padded_vocab,
                                               cfg.d_model, dt)}
    segs = segments(cfg)
    params["segments"] = []
    for i, (kind, count) in enumerate(segs):
        lkeys = jax.random.split(jax.random.fold_in(keys[1], i), count)
        stacked = jax.vmap(lambda k: layer_init(k, cfg, kind, dt))(lkeys)
        params["segments"].append(stacked)
    if cfg.is_encdec:
        ekeys = jax.random.split(keys[2], cfg.enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: layer_init(k, cfg, "dense", dt))(ekeys)
        params["enc_norm"] = layers.rmsnorm_init(cfg.d_model, dt)
    if cfg.frontend != "none":
        params["frontend"] = frontends.frontend_init(keys[3], cfg, dt)
    params["final_norm"] = layers.rmsnorm_init(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        params["head"] = layers.dense_init(keys[4], cfg.d_model,
                                           cfg.padded_vocab, dt)
    return params


# ---------------------------------------------------------------------------
# segment runners (scan over stacked layers)
# ---------------------------------------------------------------------------

@jax.custom_jvp
def _barrier(x: jax.Array) -> jax.Array:
    """``lax.optimization_barrier`` with a differentiation rule.

    The raw primitive has no JVP/transpose registration (jax 0.4.x), so any
    ``grad`` through the scan body raises NotImplementedError. The barrier is
    the identity on values, so the tangent passes through unbarriered — it
    must stay a plain identity to be transposable for reverse mode.
    """
    return jax.lax.optimization_barrier(x)


@_barrier.defjvp
def _barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return _barrier(x), t


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)




def run_segment(stacked, cfg, kind: str, x, positions, mode: str,
                caches=None, pos3=None, memory=None):
    """scan over layers of one segment. Returns (x, new_caches, aux_sum)."""
    if mode in ("train", "encoder"):
        count = jax.tree.leaves(stacked)[0].shape[0]
        g = cfg.scan_group if (cfg.scan_group > 1 and
                               count % cfg.scan_group == 0) else 1

        def one_layer(x, lp):
            y, _, aux = layer_apply(lp, cfg, kind, x, positions, mode,
                                    None, pos3, memory)
            return y, aux

        # NESTED remat when g > 1: the outer checkpoint makes the scan save
        # the residual only every g layers ((L/g, B, T, d) stack — XLA
        # widens it to f32, so size matters); the inner per-layer
        # checkpoints make the group backward recompute ONE layer's
        # internals at a time instead of g at once. Both measured in
        # EXPERIMENTS.md §Perf.
        inner = _remat(cfg, one_layer) if g > 1 else one_layer

        def body(x, lp_group):
            # sequence-parallel residual: between layers x is sharded over
            # ('data' x batch, 'model' x sequence) — Megatron SP. The scan's
            # saved-for-backward residual stack inherits this sharding, so
            # its per-device footprint drops by the TP width. XLA inserts
            # the all-gather (pre-attention) / reduce-scatter (post-wo)
            # pair automatically from the sharding constraint.
            x = hooks.constrain(_barrier(x), "residual")
            aux = jnp.zeros((), jnp.float32)
            for i in range(g):
                lp = jax.tree.map(lambda a: a[i], lp_group) if g > 1 \
                    else lp_group
                x, a = inner(x, lp)
                aux = aux + a
            return _barrier(x), aux

        body = _remat(cfg, body)
        grouped = stacked if g == 1 else jax.tree.map(
            lambda a: a.reshape(count // g, g, *a.shape[1:]), stacked)
        x, auxs = jax.lax.scan(body, x, grouped)
        return x, None, jnp.sum(auxs)
    if mode == "prefill":
        def body(x, inp):
            lp, cproto = inp           # cproto: pre-allocated cache buffers
            y, cache, _ = layer_apply(lp, cfg, kind, x, positions, "prefill",
                                      cproto, pos3, memory)
            return y, cache
        x, new_caches = jax.lax.scan(body, x, (stacked, caches))
        return x, new_caches, jnp.zeros(())
    if mode == "decode":
        def body(x, inp):
            lp, cache = inp
            y, new_cache, _ = layer_apply(lp, cfg, kind, x, positions,
                                          "decode", cache, pos3, memory)
            return y, new_cache
        x, new_caches = jax.lax.scan(body, x, (stacked, caches))
        return x, new_caches, jnp.zeros(())
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# embedding / inputs
# ---------------------------------------------------------------------------

def encode_memory(params, cfg, enc_emb: jax.Array) -> jax.Array:
    """Run the encoder once: (B, enc_len, feat) -> (B, enc_len, d_model).
    Shared by training/prefill (``embed_inputs``) and the paged engine,
    which encodes per request at admission and caches the result in the
    read-only encoder-memory pool — the computation (and its bits) is the
    same either way."""
    dt = _dtype(cfg)
    enc_x = frontends.frontend_apply(params["frontend"], cfg,
                                     enc_emb).astype(dt)
    b, s, _ = enc_x.shape
    enc_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc_x, _, _ = run_segment(params["encoder"], cfg, "dense", enc_x,
                              enc_pos, "encoder")
    return layers.rmsnorm(params["enc_norm"], enc_x, cfg.norm_eps)


def embed_inputs(params, cfg, batch: Dict, decode: bool = False):
    """-> (x, positions, pos3, memory). Handles vlm/audio stubs + encdec."""
    dt = _dtype(cfg)
    pos3 = batch.get("pos3")
    memory = None
    if cfg.is_encdec:
        memory = encode_memory(params, cfg, batch["enc_emb"])
    tokens = batch["tokens"]
    x = layers.embed(params["embed"], tokens).astype(dt)
    if cfg.frontend == "vision_stub" and not decode and "vision_emb" in batch:
        v = frontends.frontend_apply(params["frontend"], cfg,
                                     batch["vision_emb"]).astype(dt)
        x = jnp.concatenate([v, x], axis=1)
    b, l, _ = x.shape
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
    x = hooks.constrain(x, "activation")
    return x, positions, pos3, memory


def _logits(params, cfg, x):
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]
    logits = x @ w
    return hooks.constrain(logits, "logits")


# ---------------------------------------------------------------------------
# training entry points
# ---------------------------------------------------------------------------

def forward(params, cfg, batch: Dict) -> Tuple[jax.Array, jax.Array]:
    x, positions, pos3, memory = embed_inputs(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    for seg_params, (kind, _) in zip(params["segments"], segments(cfg)):
        x, _, aux = run_segment(seg_params, cfg, kind, x, positions, "train",
                                pos3=pos3, memory=memory)
        aux_total = aux_total + aux
    return _logits(params, cfg, x), aux_total


def loss_fn(params, cfg, batch: Dict, aux_weight: float = 0.01
            ) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:      # vlm: vision prefix unlabeled
        logits = logits[:, -labels.shape[1]:]
    xent = layers.cross_entropy(logits, labels, cfg.vocab)
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------

def init_serve_cache(cfg, batch_size: int, max_len: int) -> Dict:
    dt = _dtype(cfg)
    segs = segments(cfg)
    caches = []
    for kind, count in segs:
        def one(_):
            if kind == "ssm":
                return ssm.init_ssm_cache(cfg, batch_size, dt)
            if kind == "hybrid":
                return {"attn": attention.init_cache(cfg, batch_size, max_len, dt),
                        "ssm": ssm.init_ssm_cache(cfg, batch_size, dt)}
            return attention.init_cache(cfg, batch_size, max_len, dt)
        caches.append(jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one(i) for i in range(count)]))
    out = {"segments": caches, "pos": jnp.zeros((), jnp.int32)}
    if cfg.is_encdec:
        out["memory"] = jnp.zeros((batch_size, cfg.enc_len, cfg.d_model), dt)
    return out


def prefill(params, cfg, batch: Dict, cache: Dict) -> Tuple[jax.Array, Dict]:
    x, positions, pos3, memory = embed_inputs(params, cfg, batch)
    new_segs = []
    for seg_params, seg_cache, (kind, _) in zip(params["segments"],
                                                cache["segments"], segments(cfg)):
        x, new_c, _ = run_segment(seg_params, cfg, kind, x, positions,
                                  "prefill", caches=seg_cache, pos3=pos3,
                                  memory=memory)
        new_segs.append(new_c)
    logits = _logits(params, cfg, x[:, -1:])
    out = {"segments": new_segs, "pos": jnp.asarray(x.shape[1], jnp.int32)}
    if cfg.is_encdec:
        out["memory"] = memory
    return logits, out


def paged_step(params, cfg, pools: Dict, tokens: jax.Array,
               positions: jax.Array, q_valid: jax.Array,
               tables: jax.Array, slots: jax.Array,
               tp_axis: Optional[str] = None,
               embed_seeds: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict]:
    """One batched step against pooled paged caches (serving hot path).

    tokens: (B, C) int32 — C = 1 for batched decode, C = prefill chunk
    for chunked prefill; both run through the same code. positions: (B, C)
    absolute positions; q_valid: (B, C) validity (False rows/tails are
    padding); tables: (B, M) page ids into the paged-domain pools;
    slots: (B,) slot ids into the constant-state pools and the enc-dec
    memory pool (0 = null slot for padded rows). ``pools`` is the full
    container from ``serving.paged_cache.init_pools`` ({"paged", "slot"}
    per-segment lists + optional "memory"). Returns
    (logits (B, C, V_padded), pools').

    Layers scan over (stacked params, stacked per-layer pools of BOTH
    domains — hybrid layers carry a kv sub-pool and an ssd sub-pool
    side by side); tables / positions are loop constants, so the whole
    step stays one jit'd program regardless of batch composition. For
    enc-dec the per-request encoder memory is gathered ONCE from the
    memory pool (paged-gather with a width-1 table of slot ids) and
    cross-attended by every decoder layer.

    ``tp_axis``: set when running per-shard inside the mesh-serving
    shard_map (``launch.steps.make_paged_step(mesh=...)``): ``cfg`` is
    then the shard-local view (head counts divided), the pools hold the
    local head block, and attention all-gathers its per-shard head
    outputs over the named mesh axis (``collectives.stitch_heads``)
    before the replicated-wo contraction. Everything outside (self and
    cross) attention — including the ssd half of hybrid layers — is
    replicated: each shard repeats the identical constant-state update.

    ``embed_seeds``: optional (B,) uint32 per-request projection seeds
    for seeded-SRF configs (0 = base projection); forwarded into every
    SRF attention layer's feature maps (zero-storage personalization).
    """
    dt = _dtype(cfg)
    x = layers.embed(params["embed"], tokens).astype(dt)
    x = hooks.constrain(x, "activation")
    memory = None
    mem_pool = pools.get("memory")
    if mem_pool is not None:
        memory = attention._paged_hist(mem_pool, slots[:, None]).astype(dt)
    new_paged, new_slot = [], []
    for seg_params, pseg, sseg, (kind, _) in zip(
            params["segments"], pools["paged"], pools["slot"], segments(cfg)):
        def body(x, inp):
            lp, lpp, lsp = inp
            y, npp, nsp = _paged_layer(lp, cfg, kind, x, positions, q_valid,
                                       lpp, lsp, tables, slots, memory,
                                       tp_axis, embed_seeds)
            return y, (npp, nsp)
        x, (np_, ns_) = jax.lax.scan(body, x, (seg_params, pseg, sseg))
        new_paged.append(np_)
        new_slot.append(ns_)
    out_pools = {"paged": new_paged, "slot": new_slot}
    if mem_pool is not None:
        out_pools["memory"] = mem_pool        # read-only: pass through
    return _logits(params, cfg, x), out_pools


def _paged_layer(p, cfg, kind: str, x, positions, q_valid, lpaged, lslot,
                 tables, slots, memory=None, tp_axis: Optional[str] = None,
                 embed_seeds: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[Dict], Optional[Dict]]:
    """Single-layer paged step (mirrors ``layer_apply`` for serving).
    -> (x, new_paged_pools, new_slot_pools), each keyed by component."""
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "ssm":
        if tp_axis is not None:     # ssd pools always replicate (shard.py)
            raise ValueError("tp_axis is not supported for pure ssm stacks")
        y, new_ssm = ssm.paged_ssm_step(p["ssm"], cfg, h, q_valid,
                                        lslot["ssm"], slots)
        return x + y, None, {"ssm": new_ssm}
    attn_in_slot = cfg.attn_impl == "srf"   # srf state is a constant slot
    ctx = {"pool": (lslot if attn_in_slot else lpaged)["attn"],
           "tables": tables, "slots": slots, "q_valid": q_valid,
           "tp_axis": tp_axis}
    if embed_seeds is not None:
        ctx["embed_seeds"] = embed_seeds
    a, new_attn = attention.attention(p["attn"], cfg, h, positions, "paged",
                                      ctx)
    if kind == "hybrid":
        s, new_ssm = ssm.paged_ssm_step(p["ssm"], cfg, h, q_valid,
                                        lslot["ssm"], slots)
        fused = 0.5 * (layers.rmsnorm(p["fuse_na"], a, cfg.norm_eps)
                       + layers.rmsnorm(p["fuse_ns"], s, cfg.norm_eps))
        x = x + fused
        x = x + layers.mlp(p["mlp"], layers.rmsnorm(p["ln2"], x, cfg.norm_eps))
        new_s = {"ssm": new_ssm}
        if attn_in_slot:
            new_s["attn"] = new_attn
            return x, None, new_s
        return x, {"attn": new_attn}, new_s
    x = x + a
    if kind == "dense_cross" and memory is not None:
        x = x + attention.paged_cross_attention(
            p["cross"], cfg, layers.rmsnorm(p["ln_x"], x, cfg.norm_eps),
            memory, tp_axis)
    h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        # q_valid keeps padded chunk-tail tokens out of expert capacity:
        # without it real tokens' slot positions (and thus drops) depend
        # on batch padding, breaking cross-replica determinism
        y, _ = moe.moe_apply(p["moe"], cfg, h2, valid=q_valid)
    else:
        y = layers.mlp(p["mlp"], h2)
    x = x + y
    if attn_in_slot:
        return x, None, {"attn": new_attn}
    return x, {"attn": new_attn}, None


def decode_step(params, cfg, cache: Dict, tokens: jax.Array,
                pos3: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """tokens: (B, 1) int32. Returns logits (B, 1, V)."""
    dt = _dtype(cfg)
    b = tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    x = layers.embed(params["embed"], tokens).astype(dt)
    x = hooks.constrain(x, "activation")
    memory = cache.get("memory")
    new_segs = []
    for seg_params, seg_cache, (kind, _) in zip(params["segments"],
                                                cache["segments"], segments(cfg)):
        x, new_c, _ = run_segment(seg_params, cfg, kind, x, positions,
                                  "decode", caches=seg_cache, pos3=pos3,
                                  memory=memory)
        new_segs.append(new_c)
    logits = _logits(params, cfg, x)
    out = {"segments": new_segs, "pos": pos + 1}
    if memory is not None:
        out["memory"] = memory
    return logits, out
