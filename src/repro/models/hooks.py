"""Sharding-constraint hook. Models call ``constrain(x, role)`` at a few
activation boundaries; the launcher installs a mesh-aware implementation
(distributed/sharding.py). Default is identity so models import mesh-free.
"""
from __future__ import annotations

_fn = lambda x, role: x


def constrain(x, role: str):
    return _fn(x, role)


def set_constrainer(fn) -> None:
    global _fn
    _fn = fn


def reset() -> None:
    global _fn
    _fn = lambda x, role: x
