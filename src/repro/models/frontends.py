"""Modality frontends for [audio]/[vlm] archs — STUBS by spec.

``input_specs()`` provides precomputed frame/patch embeddings; the only
learned piece here is a linear adapter into d_model (so the backbone sees
a realistic projected stream and the adapter shards like any weight).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import layers

# feature dims of the precomputed stub embeddings
AUDIO_FEAT_DIM = 160     # fbank-ish frame features
VISION_FEAT_DIM = 1176   # 14x14x2x3 qwen2-vl patchify


def synthetic_audio_features(rng: np.random.Generator, cfg) -> np.ndarray:
    """One request's synthetic (enc_len, AUDIO_FEAT_DIM) frontend frames —
    the shared generator behind the serving launcher, benchmarks, and the
    parity tests (one definition, so every consumer draws the same
    distribution from the same rng stream)."""
    return (rng.standard_normal((cfg.enc_len, AUDIO_FEAT_DIM))
            * 0.2).astype(np.float32)

def frontend_init(rng, cfg, dtype) -> Dict:
    if cfg.frontend == "audio_stub":
        return {"adapter": layers.dense_init(rng, AUDIO_FEAT_DIM, cfg.d_model, dtype)}
    if cfg.frontend == "vision_stub":
        return {"adapter": layers.dense_init(rng, VISION_FEAT_DIM, cfg.d_model, dtype)}
    return {}


def frontend_apply(p, cfg, feats: jax.Array) -> jax.Array:
    """(B, T, feat_dim) precomputed features -> (B, T, d_model)."""
    return feats @ p["adapter"]
