"""Mixture-of-Experts FFN: top-k routing, capacity-bounded scatter dispatch,
shared experts (DeepSeek style), load-balance aux loss.

Dispatch is scatter/gather based (not the GShard one-hot einsum, whose
(T, E, C) dispatch tensor is infeasible at top-6/E=64): slot positions come
from running per-expert cumulative counts, tokens beyond capacity are
dropped (mode='drop' scatter), and the (E, C, d) buffer is sharded over the
'model' axis (expert parallelism) by the launch-time sharding constraints —
XLA inserts the canonical MoE all-to-all at the token->expert resharding
boundary.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import hooks, layers

_constrain = hooks.constrain


def moe_init(rng, cfg, dtype) -> Dict:
    keys = jax.random.split(rng, 5)
    d, e, ffe = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    s = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(keys[0], (d, e)) * s).astype(jnp.float32),
        "wi": (jax.random.normal(keys[1], (e, d, ffe)) * s).astype(dtype),
        "wg": (jax.random.normal(keys[2], (e, d, ffe)) * s).astype(dtype),
        "wo": (jax.random.normal(keys[3], (e, ffe, d)) /
               math.sqrt(ffe)).astype(dtype),
    }
    if cfg.moe_shared > 0:
        p["shared"] = layers.mlp_init(keys[4], d, cfg.moe_shared * ffe, dtype)
    return p


def moe_apply(p, cfg, x: jax.Array, valid=None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (out, aux_loss).

    Dispatch is GROUP-LOCAL (groups = batch rows, the GShard trick): slot
    positions come from a per-group cumulative count (a local cumsum — no
    distributed prefix sum), capacity is enforced per group, and the
    dispatch buffer is (B, E, C, d) sharded P(dp, 'model', -, -) — batch
    rows stay on their data shard while experts live on their model shard,
    so the only cross-shard movement is the canonical token->expert
    all-to-all of the scatter payload. (A single global (E, C, d) buffer
    forces XLA to all-reduce the whole buffer across data shards:
    3.2 TB/device/step on moonshot train_4k — measured, EXPERIMENTS.md
    §Perf-hillclimb.)

    ``valid``: optional (B, L) bool — tokens marked False are EXCLUDED
    from dispatch entirely (no slot, no capacity use, zero gate). The
    paged serving step passes its q_valid mask: padded chunk-tail rows
    otherwise compete for per-expert capacity and shift real tokens'
    second-choice slots, making outputs depend on batch padding.
    """
    b, l, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    cap = int(cfg.moe_capacity_factor * l * k / e)
    cap = max(8, ((cap + 7) // 8) * 8)

    logits = jnp.einsum("bld,de->ble", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (B, L, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    if valid is not None:
        gates = gates * valid[..., None].astype(gates.dtype)

    # positions within each group, sequential over the k routing slots
    pos = []
    base = jnp.zeros((b, e), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(idx[:, :, j], e, dtype=jnp.int32)   # (B, L, E)
        if valid is not None:
            oh = oh * valid[..., None].astype(oh.dtype)
        before = jnp.cumsum(oh, axis=1) - oh + base[:, None, :]
        pos.append(jnp.sum(before * oh, axis=-1))               # (B, L)
        base = base + jnp.sum(oh, axis=1)
    pos = jnp.stack(pos, axis=2)                                # (B, L, k)
    keep = pos < cap
    if valid is not None:
        keep = keep & valid[..., None]
    safe_pos = jnp.where(keep, pos, cap)                        # OOB -> drop

    # INDEX dispatch: scatter int32 token ids into the slot map (tiny —
    # the data-dependent scatter that XLA must replicate across shards is
    # (B, E, C) ints, not payloads), then GATHER payloads consumer-side
    # (buf is born with its (dp, 'model') sharding; the only payload
    # collective is the pre-gather x all-gather over 'model' — the same
    # one Megatron-SP issues before any FFN).
    sent = l                                                    # OOB sentinel
    tok_ids = jnp.broadcast_to(jnp.arange(l)[:, None], (l, k)).reshape(-1)

    def build_slots(idxg, posg, gg):
        st = jnp.full((e, cap), sent, jnp.int32)
        st = st.at[idxg.reshape(-1), posg.reshape(-1)].set(tok_ids,
                                                           mode="drop")
        sg = jnp.zeros((e, cap), jnp.float32)
        sg = sg.at[idxg.reshape(-1), posg.reshape(-1)].set(gg.reshape(-1),
                                                           mode="drop")
        return st, sg

    slot_tok, slot_gate = jax.vmap(build_slots)(
        idx, safe_pos, (gates * keep).astype(jnp.float32))      # (B, E, C)

    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    buf = jax.vmap(lambda xg, st: xg[st])(xpad, slot_tok)       # (B, E, C, d)
    buf = _constrain(buf, "moe_buf")

    # expert FFN (batched over groups and experts; E is the EP axis)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wg"])) * \
        jnp.einsum("becd,edf->becf", buf, p["wi"])
    y = jnp.einsum("becf,efd->becd", h, p["wo"])
    y = _constrain(y, "moe_buf")

    # combine: scatter-add weighted slots back onto tokens. Partial sums
    # per model shard -> one (B, L, d) all-reduce (row-parallel pattern).
    def combine(yg, st, sg):
        w = yg * sg[..., None].astype(yg.dtype)
        out = jnp.zeros((l + 1, d), yg.dtype)
        return out.at[st.reshape(-1)].add(w.reshape(-1, d))[:l]

    out = jax.vmap(combine)(y, slot_tok, slot_gate)             # (B, L, d)

    if cfg.moe_shared > 0:
        out = out + layers.mlp(p["shared"], x.reshape(-1, d)).reshape(b, l, d)

    # switch-style load balance loss
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[:, :, 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return out, aux


def moe_dense_reference(p, cfg, x: jax.Array) -> jax.Array:
    """O(T*E) oracle: run every expert on every token, weight by the same
    (renormalized) top-k gates, no capacity drops. Tests compare against
    moe_apply with capacity_factor large enough that nothing drops."""
    b, l, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    w = jnp.zeros_like(probs).at[jnp.arange(xf.shape[0])[:, None], idx].set(gates)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["wg"])) * \
        jnp.einsum("td,edf->tef", xf, p["wi"])
    y = jnp.einsum("tef,efd->ted", h, p["wo"])
    out = jnp.einsum("te,ted->td", w.astype(y.dtype), y)
    if cfg.moe_shared > 0:
        out = out + layers.mlp(p["shared"], xf)
    return out.reshape(b, l, d)
