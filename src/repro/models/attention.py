"""Attention blocks: full softmax GQA, MLA (DeepSeek latent), and the
paper's SRF attention, with train / prefill / decode entry points.

Modes
-----
train    causal, no cache
encoder  bidirectional, no cache
prefill  causal, returns a decode cache
decode   single new token against the cache

Caches
------
full GQA : {"k","v": (B, Hkv, S, hd), "idx": ()}                O(S)
MLA      : {"c": (B, S, kv_lora), "kpe": (B, S, rope), "idx"}   O(S), tiny/token
SRF      : {"s": (B, Hq, m, hd), "z": (B, Hq, m), "idx"}        O(m) — seq-free
           (the paper's space reduction: no KV cache at all)
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import srf_attention as srf
from repro.core.srf_attention import SRFConfig
from repro.core.transforms import is_pow2
from repro.distributed.collectives import stitch_heads
from repro.kernels import ops as kops
from . import layers


def srf_cfg(cfg) -> SRFConfig:
    dim = cfg.mla_qk_dim if cfg.is_mla else cfg.head_dim
    return SRFConfig(kind=cfg.srf.kind, n_features=cfg.srf.n_features,
                     head_dim=dim, feature=cfg.srf.feature, r=cfg.srf.r,
                     use_hd=is_pow2(dim), chunk=cfg.srf.chunk,
                     seeded=cfg.srf.seeded)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(rng, cfg, dtype) -> Dict:
    keys = jax.random.split(rng, 12)
    d = cfg.d_model
    p: Dict = {}
    if cfg.is_mla:
        p["wq"] = layers.dense_init(keys[0], d, cfg.n_heads * cfg.mla_qk_dim, dtype)
        p["wdkv"] = layers.dense_init(keys[1], d, cfg.mla_kv_lora, dtype)
        p["wkpe"] = layers.dense_init(keys[2], d, cfg.mla_qk_rope, dtype)
        p["wuk"] = layers.dense_init(keys[3], cfg.mla_kv_lora,
                                     cfg.n_heads * cfg.mla_qk_nope, dtype)
        p["wuv"] = layers.dense_init(keys[4], cfg.mla_kv_lora,
                                     cfg.n_heads * cfg.mla_v_dim, dtype)
        p["wo"] = layers.dense_init(keys[5], cfg.n_heads * cfg.mla_v_dim, d, dtype)
    else:
        p["wq"] = layers.dense_init(keys[0], d, cfg.q_dim, dtype)
        p["wk"] = layers.dense_init(keys[1], d, cfg.kv_dim, dtype)
        p["wv"] = layers.dense_init(keys[2], d, cfg.kv_dim, dtype)
        p["wo"] = layers.dense_init(keys[3], cfg.q_dim, d, dtype)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
            p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
            p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm:
        hd = cfg.mla_qk_dim if cfg.is_mla else cfg.head_dim
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    if cfg.attn_impl == "srf":
        sc = srf_cfg(cfg)
        n_pm = cfg.n_heads if cfg.is_mla else cfg.n_kv_heads
        p["srf"] = srf.init(keys[6], sc, n_pm, dtype)
    return p


def cross_attn_init(rng, cfg, dtype) -> Dict:
    keys = jax.random.split(rng, 4)
    d = cfg.d_model
    return {"wq": layers.dense_init(keys[0], d, cfg.q_dim, dtype),
            "wk": layers.dense_init(keys[1], d, cfg.kv_dim, dtype),
            "wv": layers.dense_init(keys[2], d, cfg.kv_dim, dtype),
            "wo": layers.dense_init(keys[3], cfg.q_dim, d, dtype)}


def init_cache(cfg, batch: int, max_len: int, dtype) -> Dict:
    """Allocate the decode cache (shape depends on attn_impl)."""
    if cfg.attn_impl == "srf":
        sc = srf_cfg(cfg)
        dv = cfg.mla_v_dim if cfg.is_mla else cfg.head_dim
        return {"s": jnp.zeros((batch, cfg.n_heads, sc.feat_dim, dv), dtype),
                "z": jnp.zeros((batch, cfg.n_heads, sc.feat_dim), dtype),
                "idx": jnp.zeros((), jnp.int32)}
    if cfg.is_mla:
        return {"c": jnp.zeros((batch, max_len, cfg.mla_kv_lora), dtype),
                "kpe": jnp.zeros((batch, max_len, cfg.mla_qk_rope), dtype),
                "idx": jnp.zeros((), jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        shp = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
        return {"k": jnp.zeros(shp, jnp.int8),
                "v": jnp.zeros(shp, jnp.int8),
                "k_scale": jnp.zeros(shp[:-1] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shp[:-1] + (1,), jnp.float32),
                "idx": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.head_dim), dtype),
            "idx": jnp.zeros((), jnp.int32)}


def _quantize_kv(x: jax.Array):
    """(B, H, L, hd) -> (int8 values, f32 per-token-per-head scales)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
    return q.astype(jnp.int8), s


def _dequantize_kv(q: jax.Array, s: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _split_heads(x, n_heads, hd):
    b, l, _ = x.shape
    return x.reshape(b, l, n_heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, l, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * hd)


ATTN_Q_CHUNK = 1024   # query-chunked attention block (memory: qc*S probs
                      # instead of L*S; the chunk body is rematerialized)


def _attn_block(qg, k, v, scale, mask):
    """qg: (B,Hkv,G,qc,hd); mask: (qc,S) or (B,qc,S) or None -> (...,qc,dv).

    Scores/softmax in f32 (stability); the probability matrix is cast back
    to the input dtype for the PV contraction — under sequence sharding
    that contraction carries the model-axis psum, and a bf16 psum ships
    half the bytes of the f32 one (flash-attention kernels do the same)."""
    logits = jnp.einsum("bhgld,bhsd->bhgls", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        while mask.ndim < logits.ndim:
            mask = mask[None]
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgls,bhsd->bhgld", w, v)


def _softmax_attn(q, k, v, scale, causal: bool, kv_valid=None,
                  q_chunk: int = ATTN_Q_CHUNK):
    """q: (B,Hq,L,hd) k,v: (B,Hkv,S,hd). GQA via head grouping.

    Long query axes are processed in rematerialized chunks so the (qc, S)
    probability block is the only live attention buffer — the unchunked
    (L, S) f32 probs are 89 GB/device at prefill_32k (measured; §Perf)."""
    b, hq, l, hd = q.shape
    hkv = k.shape[1]
    s = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, l, hd)
    dv = v.shape[-1]

    base_mask = None
    if kv_valid is not None:
        base_mask = kv_valid[None, :]                      # (1, S)

    if l <= q_chunk or l % q_chunk != 0:
        mask = base_mask
        if causal:
            tri = jnp.tril(jnp.ones((l, s), bool), k=s - l)
            mask = tri if mask is None else (tri & mask)
        out = _attn_block(qg, k, v, scale, mask)
        return out.reshape(b, hq, l, dv).astype(q.dtype)

    nc = l // q_chunk
    qc_all = qg.reshape(b, hkv, g, nc, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    offs = jnp.arange(nc) * q_chunk

    @jax.checkpoint
    def block(carry, inp):
        qc, off = inp
        mask = base_mask
        if causal:
            rows = off + jnp.arange(q_chunk)[:, None]      # absolute q pos
            cols = jnp.arange(s)[None, :]
            tri = rows + (s - l) >= cols
            mask = tri if mask is None else (tri & mask[0][None])
        return carry, _attn_block(qc, k, v, scale, mask)

    _, outs = jax.lax.scan(block, 0, (qc_all, offs))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, l, dv)
    return out.reshape(b, hq, l, dv).astype(q.dtype)


def _repeat_kv(x, g):
    """(B, Hkv, ...)-> (B, Hkv*g, ...)."""
    return jnp.repeat(x, g, axis=1)


# ---------------------------------------------------------------------------
# paged serving helpers (see serving/paged_cache.py for the pool layouts)
# ---------------------------------------------------------------------------

def _paged_scatter(pool_arr: jax.Array, new: jax.Array, tables: jax.Array,
                   positions: jax.Array, q_valid: jax.Array) -> jax.Array:
    """Write per-token rows into cache pages.

    pool_arr: (N, P, ...) pages; new: (B, C, ...) one row per token;
    tables: (B, M) page ids; positions: (B, C) absolute token positions.
    Invalid tokens are routed out of range and dropped."""
    n, p = pool_arr.shape[:2]
    b, c = positions.shape
    page = jnp.take_along_axis(tables, positions // p, axis=1,
                               mode="clip")                        # (B, C)
    dest = page * p + positions % p
    dest = jnp.where(q_valid, dest, n * p).reshape(-1)             # OOB -> drop
    flat = pool_arr.reshape((n * p,) + pool_arr.shape[2:])
    flat = flat.at[dest].set(new.reshape((b * c,) + new.shape[2:])
                             .astype(pool_arr.dtype), mode="drop")
    return flat.reshape(pool_arr.shape)


def _paged_hist(pool_arr: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather a request-contiguous history view: (N, P, ...) + (B, M)
    -> (B, M*P, ...) via the paged-gather kernel."""
    n, p = pool_arr.shape[:2]
    d = 1
    for s in pool_arr.shape[2:]:
        d *= s
    hist = kops.paged_gather(pool_arr.reshape(n, p, d), tables)
    b = tables.shape[0]
    return hist.reshape((b, tables.shape[1] * p) + pool_arr.shape[2:])


def _paged_hist_dq(pool_arr: jax.Array, scale_arr: jax.Array,
                   tables: jax.Array, dtype) -> jax.Array:
    """int8 variant of :func:`_paged_hist`: (N, P, ...) int8 pages +
    (N, P, 1) f32 scales -> (B, M*P, ...) ``dtype`` history, dequant
    fused into the gather kernel."""
    n, p = pool_arr.shape[:2]
    d = 1
    for s in pool_arr.shape[2:]:
        d *= s
    hist = kops.paged_gather_dequant(pool_arr.reshape(n, p, d), scale_arr,
                                     tables, out_dtype=dtype)
    b = tables.shape[0]
    return hist.reshape((b, tables.shape[1] * p) + pool_arr.shape[2:])


def _quantize_paged_kv(x: jax.Array, tp_axis: Optional[str] = None):
    """(B, C, Hkv, hd) chunk rows -> (int8 rows, (B, C, 1) f32 scales).

    One scale per cached token (= per page row). Under head-sharded TP
    each shard sees only its local heads, so the max-abs is pmax'd over
    the model axis — every shard then stores the same (replicated) scale
    pool and quantization is bit-identical to the single-host layout."""
    mx = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1))
    if tp_axis is not None:
        mx = jax.lax.pmax(mx, tp_axis)
    s = jnp.maximum(mx / 127.0, 1e-8)[..., None]               # (B, C, 1)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def _paged_softmax(q, k, v, scale, positions):
    """Batched chunk attention against gathered pages.

    q: (B, Hq, C, hd); k, v: (B, Hkv, T, hd); positions: (B, C) absolute
    positions of the chunk tokens. Column t of the history is visible to
    chunk row i iff t <= positions[:, i] (the new tokens were already
    scattered into the history, so the diagonal is included)."""
    b, hq, c, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, c, hd)
    logits = jnp.einsum("bhgld,bhsd->bhgls", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(t)[None, :] <= positions.reshape(b * c, 1)
    mask = mask.reshape(b, 1, 1, c, t)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgls,bhsd->bhgld", w, v)
    return out.reshape(b, hq, c, v.shape[-1]).astype(q.dtype)


def _paged_full(cfg, q, k, v, positions, ctx):
    """Full-KV paged path: scatter the chunk's k/v into pages, gather the
    whole history, attend. Works for decode (C=1) and chunked prefill,
    for bf16/f32 pools and int8 pools (detected by the scale leaves;
    dequant is fused into the gather)."""
    pool, tables, q_valid = ctx["pool"], ctx["tables"], ctx["q_valid"]
    kt = k.transpose(0, 2, 1, 3)                       # (B, C, Hkv, hd)
    vt = v.transpose(0, 2, 1, 3)
    if "k_scale" in pool:
        kq, ks = _quantize_paged_kv(kt, ctx.get("tp_axis"))
        vq, vs = _quantize_paged_kv(vt, ctx.get("tp_axis"))
        new_pool = {
            "k": _paged_scatter(pool["k"], kq, tables, positions, q_valid),
            "v": _paged_scatter(pool["v"], vq, tables, positions, q_valid),
            "k_scale": _paged_scatter(pool["k_scale"], ks, tables,
                                      positions, q_valid),
            "v_scale": _paged_scatter(pool["v_scale"], vs, tables,
                                      positions, q_valid)}
        kf = _paged_hist_dq(new_pool["k"], new_pool["k_scale"], tables,
                            q.dtype).transpose(0, 2, 1, 3)
        vf = _paged_hist_dq(new_pool["v"], new_pool["v_scale"], tables,
                            q.dtype).transpose(0, 2, 1, 3)
    else:
        new_pool = {
            "k": _paged_scatter(pool["k"], kt, tables, positions, q_valid),
            "v": _paged_scatter(pool["v"], vt, tables, positions, q_valid)}
        kf = _paged_hist(new_pool["k"], tables).transpose(0, 2, 1, 3)
        vf = _paged_hist(new_pool["v"], tables).transpose(0, 2, 1, 3)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    out = _paged_softmax(q, kf.astype(q.dtype), vf.astype(q.dtype), scale,
                         positions)
    return out, new_pool


def _paged_srf(sc, pool, slots, phi_q, phi_k, v, q_valid):
    """SRF paged path: the state is one constant-size page per request
    (the paper's O(m d) object) at the request's slot in the slot-domain
    pool (``serving.paged_cache``).

    Chunked prefill processes C tokens causally against the carried
    state; decode (C=1) routes through the fused srf_decode kernel.
    Invalid chunk rows have phi_k/v zeroed, which makes their state
    contribution an exact no-op."""
    b, h, c, m = phi_q.shape
    s = pool["s"][slots]                               # (B, Hq, m, dv)
    z = pool["z"][slots]
    valid = q_valid[:, None, :, None].astype(phi_k.dtype)
    phi_k = phi_k * valid
    v = v * valid
    if c == 1:
        s2, z2, out = kops.srf_decode(s.astype(jnp.float32),
                                      z.astype(jnp.float32),
                                      phi_q[:, :, 0].astype(jnp.float32),
                                      phi_k[:, :, 0].astype(jnp.float32),
                                      v[:, :, 0].astype(jnp.float32))
        out = out[:, :, None, :]
    else:
        tri = jnp.tril(jnp.ones((c, c), phi_q.dtype))
        attn = jnp.einsum("bhim,bhjm->bhij", phi_q, phi_k) * tri
        num = jnp.einsum("bhij,bhjd->bhid", attn, v) \
            + jnp.einsum("bhim,bhmd->bhid", phi_q, s.astype(phi_q.dtype))
        den = jnp.einsum("bhij->bhi", attn) \
            + jnp.einsum("bhim,bhm->bhi", phi_q, z.astype(phi_q.dtype))
        out = num / (den[..., None] + 1e-6)
        s2 = s + jnp.einsum("bhjm,bhjd->bhmd", phi_k, v).astype(s.dtype)
        z2 = z + jnp.sum(phi_k, axis=-2).astype(z.dtype)
    new_pool = {"s": pool["s"].at[slots].set(s2.astype(pool["s"].dtype)),
                "z": pool["z"].at[slots].set(z2.astype(pool["z"].dtype))}
    return out.astype(phi_q.dtype), new_pool


# ---------------------------------------------------------------------------
# full / SRF GQA attention
# ---------------------------------------------------------------------------

def attention(p, cfg, x: jax.Array, positions: jax.Array, mode: str,
              cache: Optional[Dict] = None, pos3: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Optional[Dict]]:
    if cfg.is_mla:
        return _mla_attention(p, cfg, x, positions, mode, cache)
    b, l, d = x.shape
    q = x @ p["wq"] + (p.get("bq", 0) if cfg.qkv_bias else 0)
    k = x @ p["wk"] + (p.get("bk", 0) if cfg.qkv_bias else 0)
    v = x @ p["wv"] + (p.get("bv", 0) if cfg.qkv_bias else 0)
    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = layers.head_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.head_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.m_rope and pos3 is not None:
        q = layers.apply_m_rope(q, pos3, cfg.rope_theta, cfg.m_rope_sections)
        k = layers.apply_m_rope(k, pos3, cfg.rope_theta, cfg.m_rope_sections)
    else:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)

    if mode == "paged":
        if cfg.attn_impl == "srf":
            sc = srf_cfg(cfg)
            g = cfg.n_heads // cfg.n_kv_heads
            b_, hq_, l_, hd_ = q.shape
            es = cache.get("embed_seeds")        # (B,) per-request seeds
            qg = q.reshape(b_, cfg.n_kv_heads, g * l_, hd_)
            phi_q = srf.feature_map(sc, p["srf"], qg, is_query=True,
                                    embed_seeds=es)
            phi_q = phi_q.reshape(b_, hq_, l_, -1)
            phi_k = _repeat_kv(srf.feature_map(sc, p["srf"], k,
                                               is_query=False,
                                               embed_seeds=es), g)
            out, new_pool = _paged_srf(sc, cache["pool"], cache["slots"],
                                       phi_q, phi_k, _repeat_kv(v, g),
                                       cache["q_valid"])
        else:
            out, new_pool = _paged_full(cfg, q, k, v, positions, cache)
        if cache.get("tp_axis"):
            # stitch the local head block back to the full head axis; the
            # replicated-wo contraction then reduces in single-host order
            # (greedy tokens stay bit-identical to the unsharded engine)
            out = stitch_heads(out, cache["tp_axis"])
        return _merge_heads(out) @ p["wo"], new_pool
    if cfg.attn_impl == "srf":
        out, cache = _srf_paths(p, cfg, q, k, v, mode, cache)
    else:
        out, cache = _full_paths(cfg, q, k, v, positions, mode, cache)
    return _merge_heads(out) @ p["wo"], cache


def _full_paths(cfg, q, k, v, positions, mode, cache):
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if mode in ("train", "encoder"):
        return _softmax_attn(q, k, v, scale, causal=(mode == "train")), None
    quant = "k_scale" in (cache or {})
    if mode == "prefill":
        out = _softmax_attn(q, k, v, scale, causal=True)
        l = k.shape[2]
        new = {"idx": jnp.asarray(l, jnp.int32)}
        if quant:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new["k"] = jax.lax.dynamic_update_slice(cache["k"], kq,
                                                    (0, 0, 0, 0))
            new["v"] = jax.lax.dynamic_update_slice(cache["v"], vq,
                                                    (0, 0, 0, 0))
            new["k_scale"] = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, 0, 0, 0))
            new["v_scale"] = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, 0, 0, 0))
        else:
            new["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            new["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        return out, new
    if mode == "decode":
        idx = cache["idx"]
        new = {"idx": idx + 1}
        if quant:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new["k"] = jax.lax.dynamic_update_slice(cache["k"], kq,
                                                    (0, 0, idx, 0))
            new["v"] = jax.lax.dynamic_update_slice(cache["v"], vq,
                                                    (0, 0, idx, 0))
            new["k_scale"] = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, 0, idx, 0))
            new["v_scale"] = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, 0, idx, 0))
            kf = _dequantize_kv(new["k"], new["k_scale"], q.dtype)
            vf = _dequantize_kv(new["v"], new["v_scale"], q.dtype)
        else:
            new["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, idx, 0))
            new["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, idx, 0))
            kf, vf = new["k"], new["v"]
        s = new["k"].shape[2]
        valid = jnp.arange(s) <= idx
        out = _softmax_attn(q, kf, vf, scale, causal=False, kv_valid=valid)
        return out, new
    raise ValueError(mode)


def _srf_paths(p, cfg, q, k, v, mode, cache):
    sc = srf_cfg(cfg)
    g = cfg.n_heads // cfg.n_kv_heads
    # feature maps per kv head; group q-heads onto their kv head's P-model
    b, hq, l, hd = q.shape
    qg = q.reshape(b, cfg.n_kv_heads, g * l, hd)
    phi_q = srf.feature_map(sc, p["srf"], qg, is_query=True)
    phi_q = phi_q.reshape(b, hq, l, -1)
    phi_k = srf.feature_map(sc, p["srf"], k, is_query=False)
    phi_k = _repeat_kv(phi_k, g)
    vr = _repeat_kv(v, g)
    if mode == "encoder":
        return srf.attention_noncausal(phi_q, phi_k, vr), None
    if mode == "train":
        return srf.attention_causal(sc, phi_q, phi_k, vr), None
    if mode == "prefill":
        out = srf.attention_causal(sc, phi_q, phi_k, vr)
        s, z = srf.prefill_state(phi_k, vr)
        return out, {"s": s.astype(v.dtype), "z": z.astype(v.dtype),
                     "idx": jnp.asarray(l, jnp.int32)}
    if mode == "decode":
        state = (cache["s"], cache["z"])
        (s, z), out = srf.decode_step(state, phi_q, phi_k, vr)
        return out, {"s": s, "z": z, "idx": cache["idx"] + 1}
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def _mla_qkv(p, cfg, x, c, kpe, positions, kpos=None):
    """Decompress latent c into per-head k/v; build roped q."""
    b, l, _ = x.shape
    s = c.shape[1]
    h = cfg.n_heads
    q = (x @ p["wq"]).reshape(b, l, h, cfg.mla_qk_dim).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = layers.head_rmsnorm(p["q_norm"], q, cfg.norm_eps)
    qn, qp = jnp.split(q, [cfg.mla_qk_nope], axis=-1)
    qp = layers.apply_rope(qp, positions, cfg.rope_theta)
    kn = (c @ p["wuk"]).reshape(b, s, h, cfg.mla_qk_nope).transpose(0, 2, 1, 3)
    v = (c @ p["wuv"]).reshape(b, s, h, cfg.mla_v_dim).transpose(0, 2, 1, 3)
    kp = kpe[:, None, :, :]                                 # (B,1,S,rope)
    kpos_arr = kpos if kpos is not None else positions
    kp = layers.apply_rope(kp, kpos_arr, cfg.rope_theta)
    q_full = jnp.concatenate([qn, qp], axis=-1)
    k_full = jnp.concatenate([kn, jnp.broadcast_to(kp, (b, h, s, cfg.mla_qk_rope))],
                             axis=-1)
    return q_full, k_full, v


def _mla_attention(p, cfg, x, positions, mode, cache):
    b, l, d = x.shape
    scale = 1.0 / math.sqrt(cfg.mla_qk_dim)
    c_new = x @ p["wdkv"]                                   # (B,L,lora)
    kpe_new = x @ p["wkpe"]                                 # (B,L,rope)

    if mode == "paged":
        pool, tables, q_valid = cache["pool"], cache["tables"], cache["q_valid"]
        if cfg.attn_impl == "srf":
            # SRF needs only the chunk's own k/v: build them from the fresh
            # latent and fold into the carried O(m d) state.
            q, k, v = _mla_qkv(p, cfg, x, c_new, kpe_new, positions,
                               kpos=positions)
            sc = srf_cfg(cfg)
            es = cache.get("embed_seeds")
            phi_q = srf.feature_map(sc, p["srf"], q, is_query=True,
                                    embed_seeds=es)
            phi_k = srf.feature_map(sc, p["srf"], k, is_query=False,
                                    embed_seeds=es)
            out, new_pool = _paged_srf(sc, pool, cache["slots"], phi_q,
                                       phi_k, v, q_valid)
            if cache.get("tp_axis"):
                out = stitch_heads(out, cache["tp_axis"])
            return _merge_heads(out) @ p["wo"], new_pool
        new_pool = {
            "c": _paged_scatter(pool["c"], c_new, tables, positions, q_valid),
            "kpe": _paged_scatter(pool["kpe"], kpe_new, tables, positions,
                                  q_valid)}
        cc = _paged_hist(new_pool["c"], tables).astype(x.dtype)
        kk = _paged_hist(new_pool["kpe"], tables).astype(x.dtype)
        t = cc.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        q, k, v = _mla_qkv(p, cfg, x, cc, kk, positions, kpos=kpos)
        out = _paged_softmax(q, k, v, scale, positions)
        return _merge_heads(out) @ p["wo"], new_pool

    if mode in ("train", "encoder", "prefill"):
        q, k, v = _mla_qkv(p, cfg, x, c_new, kpe_new, positions)
        if cfg.attn_impl == "srf":
            sc = srf_cfg(cfg)
            phi_q = srf.feature_map(sc, p["srf"], q, is_query=True)
            phi_k = srf.feature_map(sc, p["srf"], k, is_query=False)
            out = (srf.attention_noncausal(phi_q, phi_k, v) if mode == "encoder"
                   else srf.attention_causal(sc, phi_q, phi_k, v))
            new_cache = None
            if mode == "prefill":
                s, z = srf.prefill_state(phi_k, v)
                new_cache = {"s": s.astype(x.dtype), "z": z.astype(x.dtype),
                             "idx": jnp.asarray(l, jnp.int32)}
        else:
            out = _softmax_attn(q, k, v, scale, causal=(mode != "encoder"))
            new_cache = None
            if mode == "prefill":
                ck = jax.lax.dynamic_update_slice(cache["c"],
                                                  c_new.astype(cache["c"].dtype),
                                                  (0, 0, 0))
                kk = jax.lax.dynamic_update_slice(cache["kpe"],
                                                  kpe_new.astype(cache["kpe"].dtype),
                                                  (0, 0, 0))
                new_cache = {"c": ck, "kpe": kk, "idx": jnp.asarray(l, jnp.int32)}
        return _merge_heads(out) @ p["wo"], new_cache

    if mode == "decode":
        if cfg.attn_impl == "srf":
            q, k, v = _mla_qkv(p, cfg, x, c_new, kpe_new, positions)
            sc = srf_cfg(cfg)
            phi_q = srf.feature_map(sc, p["srf"], q, is_query=True)
            phi_k = srf.feature_map(sc, p["srf"], k, is_query=False)
            (s, z), out = srf.decode_step((cache["s"], cache["z"]), phi_q, phi_k, v)
            new_cache = {"s": s, "z": z, "idx": cache["idx"] + 1}
            return _merge_heads(out) @ p["wo"], new_cache
        idx = cache["idx"]
        cc = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype),
                                          (0, idx, 0))
        kk = jax.lax.dynamic_update_slice(cache["kpe"],
                                          kpe_new.astype(cache["kpe"].dtype),
                                          (0, idx, 0))
        smax = cc.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(smax)[None], (b, smax))
        q, k, v = _mla_qkv(p, cfg, x, cc, kk, positions, kpos=kpos)
        valid = jnp.arange(smax) <= idx
        out = _softmax_attn(q, k, v, scale, causal=False, kv_valid=valid)
        return _merge_heads(out) @ p["wo"], {"c": cc, "kpe": kk, "idx": idx + 1}
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_attention(p, cfg, x: jax.Array, memory: jax.Array) -> jax.Array:
    """Exact softmax cross-attention (encoder memory is short)."""
    b, l, d = x.shape
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(memory @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(memory @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    out = _softmax_attn(q, k, v, 1.0 / math.sqrt(cfg.head_dim), causal=False)
    return _merge_heads(out) @ p["wo"]


def paged_cross_attention(p, cfg, x: jax.Array, memory: jax.Array,
                          tp_axis: Optional[str] = None) -> jax.Array:
    """Cross-attention for the paged engine: ``memory`` rows are the
    per-request encoder memories gathered from the read-only memory pool.
    Same math as :func:`cross_attention` per batch row (bit-identical to
    the legacy engine's per-slot path); under head-sharded TP the local
    head block is stitched back before the replicated-wo contraction —
    the same bit-exactness trick as self-attention (shard.py)."""
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(memory @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(memory @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    out = _softmax_attn(q, k, v, 1.0 / math.sqrt(cfg.head_dim), causal=False)
    if tp_axis:
        out = stitch_heads(out, tp_axis)
    return _merge_heads(out) @ p["wo"]
