"""Shared layer primitives: norms, RoPE / M-RoPE, MLP, embeddings.

Functional style: ``init_*`` returns a params dict; ``apply`` fns are pure.
Weight layout convention: 2-D matrices (in_dim, out_dim); head axes are
merged into out_dim so tensor-parallel sharding rules stay 2-D.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def dense_init(rng, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim)) * s).astype(dtype)


def rmsnorm_init(dim: int, dtype):
    return {"w": jnp.ones((dim,), dtype)}


def rmsnorm(p, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["w"].astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(w: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """qk-norm: normalize the last (head_dim) axis of (..., H, L, hd)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


# --- rotary embeddings ----------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, L, hd); positions: (B, L) int32. Half-split convention."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * inv  # (B,1,L,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


def apply_m_rope(x: jax.Array, positions3: jax.Array, theta: float,
                 sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions3: (3, B, L) = (t, h, w) ids.

    The hd/2 frequency slots are split into ``sections`` (sum = hd/2);
    each section uses the position row of its modality axis.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    assert sum(sections) == hd // 2, (sections, hd)
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=hd // 2)
    pos = positions3[sec_id]                          # (hd/2, B, L)
    ang = pos.transpose(1, 2, 0).astype(jnp.float32) * inv    # (B, L, hd/2)
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# --- MLP -------------------------------------------------------------------------

def mlp_init(rng, d: int, ff: int, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"wi": dense_init(k1, d, ff, dtype),
            "wg": dense_init(k2, d, ff, dtype),
            "wo": dense_init(k3, ff, d, dtype)}


def mlp(p, x: jax.Array) -> jax.Array:
    """SwiGLU."""
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


# --- embeddings / head ------------------------------------------------------------

def embed_init(rng, vocab: int, d: int, dtype) -> Dict:
    return {"tok": (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)}


def embed(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p_head: jax.Array, x: jax.Array) -> jax.Array:
    """(B, L, d) @ (d, V) in f32 for a stable softmax-xent."""
    return x.astype(jnp.float32) @ p_head.astype(jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab: int) -> jax.Array:
    """Mean xent over valid labels; labels >= vocab or < 0 are masked
    (covers the vocab-padding tokens).

    Written fusion-friendly for bf16 logits: the f32 upcast happens INSIDE
    the reductions (single consumer -> XLA fuses the convert+exp into the
    reduce loop) so no (B, L, V) f32 buffer is ever materialized. A naive
    ``logits.astype(f32)`` up front costs e.g. 40 GB/device for qwen3-4b
    train_4k (measured; EXPERIMENTS.md §Perf)."""
    mask = (labels >= 0) & (labels < vocab)
    safe = jnp.where(mask, labels, 0)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    z = jnp.sum(jnp.exp((logits - m).astype(jnp.float32)), axis=-1)
    logz = jnp.log(z) + m[..., 0].astype(jnp.float32)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold.astype(jnp.float32)) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
