"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def warmup_cosine(step, base_lr: float, warmup: int, total: int,
                  min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / max(1, warmup)
    frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * frac))
    return jnp.where(step < warmup, warm, base_lr * cos)


def constant(step, base_lr: float):
    return jnp.full((), base_lr, jnp.float32)
