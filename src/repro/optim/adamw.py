"""AdamW with global-norm clipping and path-based weight-decay masking.

No optax dependency — states are plain pytrees mirroring the params, so
the ZeRO-1 sharding rules (distributed/sharding.py) apply directly.
Moments are fp32 regardless of param dtype (bf16-safe training).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


NO_DECAY_TOKENS = ("ln", "norm", "bias", "a_log", "dt_bias", "d_skip",
                   "fuse_n", "b_", "bq", "bk", "bv")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def decay_mask(params) -> Dict:
    def f(path, x):
        p = _path_str(path).lower()
        return not any(tok in p for tok in NO_DECAY_TOKENS)
    return jax.tree_util.tree_map_with_path(f, params)


def init(params) -> Dict:
    zeros = lambda x: jnp.zeros(x.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state, params, lr, cfg: AdamWConfig = AdamWConfig()
           ) -> Tuple[Dict, Dict, Dict]:
    """-> (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mask = decay_mask(params)

    def upd(g, mu, nu, p, m):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        step = (mu2 / c1) / (jnp.sqrt(nu2 / c2) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if m else 0.0
        p2 = p.astype(jnp.float32) - lr * (step + wd)
        # barrier: force the bf16 downcast BEFORE the ZeRO-1 un-shard
        # all-gather; otherwise XLA gathers the f32 updated params (2x
        # bytes — the dominant all-gather on the MoE cells, measured).
        return jax.lax.optimization_barrier(p2.astype(p.dtype)), mu2, nu2

    flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params, mask,
                        is_leaf=lambda x: x is None)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_params, new_state, {"grad_norm": gnorm}
