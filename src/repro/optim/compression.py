"""Structured-JL gradient compression (the paper's f=identity case) for
cross-pod data parallelism, with error feedback.

Gradients cross the slow DCN (`pod`) boundary as m/n-size sketches:

    sketch      y = A x          A = circulant P-model, O(n) storage,
                                 regenerated from a shared seed on both ends
    unsketch    x' = A^T y / m   unbiased: rows of A are marginally N(0, I_n)

Error feedback (Karimireddy et al. style) keeps the bias from hurting
convergence: each worker accumulates (x - unsketch(sketch(x))) locally and
adds it to the next step's gradient before sketching.

This is exactly the paper's space/time story applied to collectives: the
projection itself costs O(n log n) (FFT path) and the matrix is never
materialized or shipped.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import structured


@dataclass(frozen=True)
class CompressionConfig:
    kind: str = "circulant"
    ratio: int = 4              # n / m  (bytes saved on the wire)
    chunk: int = 4096           # n — projection block length
    seed: int = 17
    error_feedback: bool = True
    min_size: int = 1024        # leaves smaller than this ship uncompressed
    scaling: str = "contractive"   # contractive: x' = A^T A x / n;
    # "unbiased" (A^T A x / m, E[C(x)] = x) DIVERGES under EF: eigenvalues
    # of I - A^T A/m reach ~ -(sqrt(n/m)+1)^2+1 (measured in test_optim).
    whiten: bool = True            # normalize the generator spectrum to
    # unit modulus (Romberg's random convolution — the paper's ref [35]):
    # the full circulant becomes orthogonal, so A^T A / n is an EXACT
    # row-space projection (eigenvalues in [0, 1]) and error feedback is
    # provably stable with delta = m/n. Without whitening max_w |g^(w)|^2/n
    # ~ log n and EF still blows up. Rotate ``seed`` per step so the
    # projection's null space is re-drawn.


def _leaf_key(cc: CompressionConfig, idx: int, step=0) -> jax.Array:
    """step may be a traced int (seed rotation inside jit)."""
    k = jax.random.fold_in(jax.random.PRNGKey(cc.seed), idx)
    return jax.random.fold_in(k, step)


def _gen(cc: CompressionConfig, idx: int, step=0) -> Dict[str, jax.Array]:
    """Generator params for the chunk projection (same on every worker)."""
    m = cc.chunk // cc.ratio
    p = structured.init(_leaf_key(cc, idx, step), cc.kind, m, cc.chunk)
    if cc.whiten and cc.kind == "circulant":
        spec = jnp.fft.rfft(p["g"], axis=-1)
        spec = spec / (jnp.abs(spec) + 1e-20)
        g = jnp.fft.irfft(spec, n=cc.chunk, axis=-1)
        p = dict(p, g=g * jnp.sqrt(jnp.asarray(cc.chunk, g.dtype)))
    return p


def compress_leaf(x: jax.Array, cc: CompressionConfig, idx: int,
                  step=0) -> jax.Array:
    n = cc.chunk
    m = n // cc.ratio
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad)).reshape(-1, n)
    g = _gen(cc, idx, step)
    return structured.matvec(cc.kind, g, flat, m)          # (K, m)


def decompress_leaf(y: jax.Array, cc: CompressionConfig, idx: int,
                    shape, dtype, step=0) -> jax.Array:
    n = cc.chunk
    m = n // cc.ratio
    g = _gen(cc, idx, step)
    yp = jnp.pad(y, ((0, 0), (0, n - m)))
    denom = n if cc.scaling == "contractive" else m
    # A^T y: circulant transpose-correlation == circular convolution with g
    xhat = structured._circ_conv(yp, g["g"][0]) / denom    # (K, n)
    size = 1
    for s in shape:
        size *= s
    return xhat.reshape(-1)[:size].reshape(shape).astype(dtype)


def _should_compress(x, cc) -> bool:
    return x.size >= cc.min_size


def compress_tree(tree, cc: CompressionConfig, step=0):
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, x in enumerate(leaves):
        out.append(compress_leaf(x, cc, i, step)
                   if _should_compress(x, cc) else x)
    return jax.tree.unflatten(treedef, out)


def decompress_tree(ctree, proto, cc: CompressionConfig, step=0):
    cleaves, treedef = jax.tree.flatten(ctree)
    pleaves = jax.tree.leaves(proto)
    out = []
    for i, (y, p) in enumerate(zip(cleaves, pleaves)):
        out.append(decompress_leaf(y, cc, i, p.shape, p.dtype, step)
                   if _should_compress(p, cc) else y)
    return jax.tree.unflatten(treedef, out)


def roundtrip_with_feedback(grads, err, cc: CompressionConfig, step=0
                            ) -> Tuple[Dict, Dict, Dict]:
    """One worker's step: -> (sketch_to_allreduce, local_reconstruction,
    new_error). The caller means sketches across pods, then decompresses.
    Pass the (possibly traced) training step to rotate the sketch."""
    g_in = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err) \
        if cc.error_feedback else grads
    sk = compress_tree(g_in, cc, step)
    recon = decompress_tree(sk, grads, cc, step)
    new_err = jax.tree.map(
        lambda gi, r: (gi.astype(jnp.float32) - r.astype(jnp.float32)),
        g_in, recon) if cc.error_feedback else err
    return sk, recon, new_err


def init_error(params) -> Dict:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def wire_bytes(tree, cc: CompressionConfig) -> Tuple[int, int]:
    """(uncompressed, compressed) f32 bytes crossing the pod boundary."""
    raw = comp = 0
    for x in jax.tree.leaves(tree):
        raw += x.size * 4
        if _should_compress(x, cc):
            n = cc.chunk
            k = -(-x.size // n)
            comp += k * (n // cc.ratio) * 4
        else:
            comp += x.size * 4
    return raw, comp
