"""repro.optim subsystem."""
