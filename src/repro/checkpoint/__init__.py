"""repro.checkpoint subsystem."""
