"""Fault-tolerant checkpointing: atomic, integrity-checked, async, keep-k.

Layout (per step):
    <dir>/step_00000420/arrays.npz     flattened key-path -> array
    <dir>/step_00000420/manifest.json  shapes, dtypes, sha256, metadata
    <dir>/step_00000420/COMMITTED      written last -> crash-safe marker

Writes go to ``.tmp-<step>`` and are renamed only after fsync — a job
killed mid-save never corrupts the latest checkpoint. ``restore`` picks
the newest COMMITTED step. bf16 arrays round-trip via a uint16 view.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"

# Key-path aliases applied on restore when a target key is missing:
# (regex, replacement) rewriting the NEW layout's key into the legacy
# stored key. Default migration: SRF params moved from one dict
# ('.../srf/g') to a tuple of per-block dicts ('.../srf/0/g') with the
# spinner-pipeline API. Callers can pass their own list to restore().
LEGACY_KEY_ALIASES: List[Tuple[str, str]] = [
    (r"(^|/)srf/0/", r"\1srf/"),
]


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(1) if async_save else None
        self._pending: Optional[Future] = None

    # ---------------- save ----------------

    def save(self, step: int, tree, metadata: Optional[Dict] = None,
             blocking: bool = False):
        """Snapshot to host memory synchronously, write in the background."""
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        meta = dict(metadata or {})
        if self._pool is None or blocking:
            self.wait()
            self._write(step, host, meta)
        else:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host, meta)
        return step

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = os.path.join(self.dir, f".tmp-{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        stored, manifest = {}, {"step": step, "metadata": meta, "arrays": {}}
        for k, v in host.items():
            dt = str(v.dtype)
            if dt == _BF16:
                stored[k] = v.view(np.uint16)
            else:
                stored[k] = v
            manifest["arrays"][k] = {
                "shape": list(v.shape), "dtype": dt,
                "sha256": hashlib.sha256(np.ascontiguousarray(stored[k])
                                         .tobytes()).hexdigest(),
            }
        np.savez(os.path.join(tmp, "arrays.npz"), **stored)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.available_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------

    def available_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: Optional[int] = None,
                verify: bool = True,
                key_aliases: Optional[List[Tuple[str, str]]] = None
                ) -> Tuple[Any, int, Dict]:
        """Load into the structure of ``target_tree`` (shapes must match
        unless the elastic resharder is used first).

        ``key_aliases``: (regex, replacement) pairs tried on target keys
        the checkpoint lacks, mapping them onto legacy stored keys;
        defaults to ``LEGACY_KEY_ALIASES``."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        arrays = {}
        for k, info in manifest["arrays"].items():
            v = data[k]
            if verify:
                h = hashlib.sha256(np.ascontiguousarray(v).tobytes()).hexdigest()
                if h != info["sha256"]:
                    raise IOError(f"checksum mismatch for {k} at step {step}")
            if info["dtype"] == _BF16:
                v = v.view(jnp.bfloat16)
            arrays[k] = v
        flat_target = _flatten(target_tree)
        missing = set(flat_target) - set(arrays)
        aliases = LEGACY_KEY_ALIASES if key_aliases is None else key_aliases
        for key in sorted(missing):
            for pat, repl in aliases:
                legacy = re.sub(pat, repl, key)
                if legacy != key and legacy in arrays:
                    arrays[key] = arrays[legacy]
                    missing.discard(key)
                    break
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        new_leaves = []
        for pth, leaf in leaves_p:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in pth)
            new_leaves.append(jnp.asarray(arrays[key]))
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return tree, step, manifest.get("metadata", {})
