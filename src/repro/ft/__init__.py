"""repro.ft subsystem."""
