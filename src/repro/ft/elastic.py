"""Elastic scaling: reshard a training state onto a grown/shrunk mesh.

Checkpoints store logically-global arrays (per-host shard files on real
fleets; single archive here), so elasticity is a *placement* change:
rebuild the mesh with the surviving host count, recompute shardings from
the same logical rules, and device_put. Data streams re-split by the new
shard count (deterministic synth streams make this exact). The only
constraint is divisibility, checked here with a fallback chain.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def viable_data_axis(n_devices: int, model: int) -> int:
    if n_devices % model:
        raise ValueError(f"{n_devices} devices not divisible by model={model}")
    return n_devices // model


def remesh(devices, model_parallel: int, axis_names=("data", "model")) -> Mesh:
    """Build the largest (data, model) mesh from surviving devices."""
    n = len(devices)
    data = viable_data_axis(n, model_parallel)
    arr = np.asarray(devices)[: data * model_parallel].reshape(
        data, model_parallel)
    return Mesh(arr, axis_names)


def reshard_tree(tree, specs, mesh: Mesh):
    """Place a (host-global) pytree onto ``mesh`` per the spec pytree,
    degrading any axis that no longer divides to replication."""
    def place(x, spec):
        spec = _degrade(spec, x.shape, mesh)
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(place, tree, specs)


def _degrade(spec: P, shape, mesh: Mesh) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, names in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            out.append(None)
            continue
        names_t = names if isinstance(names, tuple) else (names,)
        total = 1
        for nme in names_t:
            total *= sizes.get(nme, 1)
        out.append(names if shape[dim] % total == 0 else None)
    return P(*out)


def shrink_plan(old_hosts: int, failed: Tuple[int, ...], model: int
                ) -> Dict[str, int]:
    """Controller-side plan after host failures: new data-axis width and
    the data-shard remapping (streams are functions of shard id)."""
    alive = [h for h in range(old_hosts) if h not in failed]
    new_data = len(alive)
    return {"alive_hosts": len(alive), "new_data_axis": new_data,
            "shard_of_host": {h: i for i, h in enumerate(alive)}}
