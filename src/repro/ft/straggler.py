"""Straggler detection & mitigation policy.

On a real fleet every host reports step wall-times; the controller flags
hosts whose EMA exceeds ``threshold`` x the fleet median and applies a
policy (re-assign that host's data shard to a hot spare / exclude it and
shrink the data axis via ft.elastic). The detection logic is pure and
unit-tested with synthetic timings; the trainer wires it to real timers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class StragglerConfig:
    ema: float = 0.7            # smoothing of per-host step time
    threshold: float = 1.8      # x median -> straggler
    grace_steps: int = 3        # consecutive flags before acting
    policy: str = "reassign"    # reassign | exclude | warn


@dataclass
class HostState:
    ema_time: Optional[float] = None
    flags: int = 0
    excluded: bool = False
    shard: int = -1


class StragglerWatchdog:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.hosts: Dict[int, HostState] = {
            i: HostState(shard=i) for i in range(n_hosts)}
        self.spare_shards: List[int] = []
        self.events: List[dict] = []

    def record(self, host: int, step: int, dt: float) -> Optional[dict]:
        h = self.hosts[host]
        h.ema_time = dt if h.ema_time is None else (
            self.cfg.ema * h.ema_time + (1 - self.cfg.ema) * dt)
        med = self._median()
        if med is None:
            return None
        if h.ema_time > self.cfg.threshold * med and not h.excluded:
            h.flags += 1
            if h.flags >= self.cfg.grace_steps:
                return self._act(host, step, med)
        else:
            h.flags = 0
        return None

    def _median(self) -> Optional[float]:
        ts = sorted(h.ema_time for h in self.hosts.values()
                    if h.ema_time is not None and not h.excluded)
        if len(ts) < max(2, len(self.hosts) // 2):
            return None
        return ts[len(ts) // 2]

    def _act(self, host: int, step: int, median: float) -> dict:
        h = self.hosts[host]
        ev = {"step": step, "host": host, "ema": h.ema_time,
              "median": median, "action": self.cfg.policy}
        if self.cfg.policy == "exclude":
            h.excluded = True
            self.spare_shards.append(h.shard)
            h.shard = -1
        elif self.cfg.policy == "reassign":
            # swap shards with the fastest host (it double-buffers); with
            # every other host excluded there is no one to reassign to —
            # degrade to a warn event instead of crashing the controller
            candidates = [x for x in self.hosts.values()
                          if not x.excluded and x is not h]
            if not candidates:
                ev["action"] = "warn"
            else:
                fastest = min(candidates, key=lambda x: x.ema_time or 1e9)
                ev["reassigned_to_host"] = [k for k, v in self.hosts.items()
                                            if v is fastest][0]
                fastest.shard, h.shard = h.shard, fastest.shard
        h.flags = 0
        self.events.append(ev)
        return ev

    def active_shard_map(self) -> Dict[int, int]:
        return {k: v.shard for k, v in self.hosts.items() if not v.excluded}
