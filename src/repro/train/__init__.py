"""repro.train subsystem."""
