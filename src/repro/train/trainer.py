"""Fault-tolerant training loop.

Wires together: model step (launch/steps.py), AdamW, schedule, sharded
data loader, checkpoint manager (atomic/async/auto-resume), straggler
watchdog, and optional compressed cross-pod DP (distributed/collectives).

Failure model exercised in tests: the process can die at ANY step (a
``crash_at`` hook injects this); a restarted Trainer resumes from the
latest committed checkpoint and — because the data stream is a function
of (seed, step, shard) — replays the exact same batches.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data import synth
from repro.data.loader import ShardedLoader
from repro.ft.straggler import StragglerWatchdog
from repro.launch import steps as step_lib
from repro.models import transformer as model_lib
from repro.optim import adamw, compression as comp_lib
from repro.distributed import collectives


@dataclass
class TrainerConfig:
    num_steps: int = 100
    batch: int = 8
    seq: int = 64
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep: int = 3
    log_every: int = 10
    hyper: step_lib.TrainHyper = field(default_factory=step_lib.TrainHyper)
    compress_dp: bool = False
    compression: comp_lib.CompressionConfig = field(
        default_factory=comp_lib.CompressionConfig)


class CrashInjected(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, mesh=None,
                 crash_at: Optional[int] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.crash_at = crash_at
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.watchdog = StragglerWatchdog(n_hosts=1)
        self.metrics_log: list = []
        self._build()

    # -------------- setup --------------

    def _build(self):
        rng = jax.random.PRNGKey(self.tcfg.seed)
        self.params = model_lib.init(rng, self.cfg)
        self.opt_state = adamw.init(self.params)
        self.step = 0
        if self.tcfg.compress_dp and self.mesh is not None:
            self.err = comp_lib.init_error(self.params)
            grad_fn = step_lib.make_grad_step(self.cfg)

            def cstep(params, opt_state, err, step_idx, batch):
                grads, metrics = grad_fn(params, batch)
                grads, err = collectives.compressed_pod_mean(
                    grads, err, self.mesh, self.tcfg.compression,
                    step=step_idx)
                from repro.optim import schedule
                lr = schedule.warmup_cosine(step_idx, self.tcfg.hyper.lr,
                                            self.tcfg.hyper.warmup,
                                            self.tcfg.hyper.total_steps)
                params, opt_state, stats = adamw.update(
                    grads, opt_state, params, lr, self.tcfg.hyper.adam)
                return params, opt_state, err, {**metrics, **stats, "lr": lr}
            self._jit_step = jax.jit(cstep, donate_argnums=(0, 1, 2))
        else:
            self.err = None
            fn = step_lib.make_train_step(self.cfg, self.tcfg.hyper)
            self._jit_step = jax.jit(fn, donate_argnums=(0, 1))

        def make_batch(step, shard):
            return synth.full_batch(self.cfg, self.tcfg.batch,
                                    self.tcfg.seq, step,
                                    seed=self.tcfg.seed, shard=shard)
        self.loader = ShardedLoader(make_batch)

    # -------------- resume --------------

    def try_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        restored, step, meta = self.ckpt.restore(state)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = step
        self.loader.reset(step)
        return True

    # -------------- loop --------------

    def train(self) -> Dict:
        it = iter(self.loader.reset(self.step))
        t_last = time.time()
        while self.step < self.tcfg.num_steps:
            step_i, host_batch = next(it)
            assert step_i == self.step, (step_i, self.step)
            batch = jax.tree.map(jnp.asarray, host_batch)
            if self.err is not None:
                self.params, self.opt_state, self.err, m = self._jit_step(
                    self.params, self.opt_state, self.err,
                    jnp.asarray(self.step), batch)
            else:
                self.params, self.opt_state, m = self._jit_step(
                    self.params, self.opt_state, jnp.asarray(self.step),
                    batch)
            self.step += 1
            now = time.time()
            self.watchdog.record(0, self.step, now - t_last)
            t_last = now
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.num_steps:
                rec = {"step": self.step,
                       **{k: float(v) for k, v in m.items()}}
                self.metrics_log.append(rec)
            if self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step,
                               {"params": self.params, "opt": self.opt_state},
                               metadata={"loss": float(m["loss"])})
            if self.crash_at is not None and self.step == self.crash_at:
                self.loader.stop()
                raise CrashInjected(f"injected crash at step {self.step}")
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       metadata={"final": True}, blocking=True)
        self.ckpt.wait()
        self.loader.stop()
        return {"final_step": self.step, "log": self.metrics_log}
