"""Serving example: batched continuous-batching generation, comparing the
full-KV cache against the paper's SRF state cache (same engine).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer as T
from repro.serving.engine import Engine, Request


def run(attn: str):
    cfg = registry.reduced("qwen3-4b", n_layers=2, attn_impl=attn)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=4, max_len=96)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(8):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, 12,
                                               ).astype(np.int32),
                           max_new=16))
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    cache = T.init_serve_cache(cfg, 1, 32768)
    cache_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                      for x in jax.tree.leaves(
                          jax.eval_shape(lambda: cache)))
    print(f"attn={attn:4s} requests={len(done)} tokens={toks} "
          f"wall={dt:.1f}s  cache@32k={cache_bytes/2**20:.1f} MiB")


def main():
    run("full")
    run("srf")   # paper technique: O(m d) state, context-length-free


if __name__ == "__main__":
    main()
