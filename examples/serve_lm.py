"""Serving example: paged continuous batching, comparing the full-KV
cache against the paper's SRF state cache (same engine, same pool).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer as T
from repro.serving import Engine, Request


def run(attn: str):
    cfg = registry.reduced("qwen3-4b", n_layers=2, attn_impl=attn)
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch_slots=8, max_len=96)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(16):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(4, 24)),
                                               ).astype(np.int32),
                           max_new=16))
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    rep = eng.cache_report(max_len=32768)
    print(f"attn={attn:4s} requests={len(done)} tokens={toks} "
          f"wall={dt:.1f}s  family={rep['family']} "
          f"bytes/token/layer@32k={rep['bytes_per_token_per_layer']:.1f}")


def main():
    run("full")
    run("srf")   # paper technique: O(m d) state, context-length-free
    print("(SRF serves the same batch from a constant-size state page "
          "per request — no KV growth)")


if __name__ == "__main__":
    main()
