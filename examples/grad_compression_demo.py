"""Structured-JL gradient compression demo: train the same tiny LM with
exact vs compressed(+error feedback) gradient aggregation and compare
loss curves + bytes on the wire.

(The cross-pod shard_map collective runs in the multi-device dry-run; here
the compression math itself is exercised single-host.)

    PYTHONPATH=src python examples/grad_compression_demo.py
"""
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data import synth
from repro.launch import steps as step_lib
from repro.models import transformer as T
from repro.optim import adamw, compression as C, schedule


def main():
    cfg = registry.reduced("qwen3-4b", n_layers=2)
    params0 = T.init(jax.random.PRNGKey(0), cfg)
    grad_fn = jax.jit(step_lib.make_grad_step(cfg))
    cc = C.CompressionConfig(chunk=4096, ratio=8, min_size=4096)

    def train(compressed: bool, steps=60):
        params = params0
        opt = adamw.init(params)
        err = C.init_error(params)
        losses = []
        for s in range(steps):
            batch = jax.tree.map(jnp.asarray,
                                 synth.full_batch(cfg, 8, 64, s))
            grads, m = grad_fn(params, batch)
            if compressed:
                cct = C.CompressionConfig(chunk=4096, ratio=8,
                                          min_size=4096, seed=s)
                _, grads, err = C.roundtrip_with_feedback(grads, err, cct)
                grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                     grads, params)
            lr = schedule.warmup_cosine(s, 1e-2, 10, steps)
            params, opt, _ = adamw.update(grads, opt, params, lr)
            losses.append(float(m["loss"]))
        return losses

    exact = train(False)
    comp = train(True)
    raw, wire = C.wire_bytes(params0, cc)
    print(f"wire bytes/step: exact={raw/2**20:.1f} MiB  "
          f"compressed={wire/2**20:.1f} MiB  ({raw/wire:.1f}x reduction)")
    print(f"loss exact:      {exact[0]:.3f} -> {exact[-1]:.3f}")
    print(f"loss compressed: {comp[0]:.3f} -> {comp[-1]:.3f}")


if __name__ == "__main__":
    main()
