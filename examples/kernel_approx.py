"""Paper reproduction figure (as CSV): kernel-approximation error vs
embedding dim m, for each structure class — the error should fall ~1/sqrt(m)
with structured classes tracking the unstructured baseline (Thm 10-12).

    PYTHONPATH=src python examples/kernel_approx.py > kernel_approx.csv
"""
import jax
import jax.numpy as jnp

from repro.core import estimators as E
from repro.core import spinner


def main():
    n = 128
    v1 = jax.random.normal(jax.random.PRNGKey(1), (n,))
    v1 = v1 / jnp.linalg.norm(v1)
    v2 = jax.random.normal(jax.random.PRNGKey(2), (n,))
    v2 = v2 / jnp.linalg.norm(v2)
    print("kind,f,m,mean_abs_err,std")
    for kind in ["unstructured", "circulant", "toeplitz", "ldr"]:
        for fname in ["heaviside", "trig"]:
            for m in [16, 64, 256, 1024]:
                pipe = spinner.single(kind, m=m, n=n, r=2)
                mean, std = E.mc_error(jax.random.PRNGKey(5), pipe, fname,
                                       v1, v2, n_trials=32)
                print(f"{kind},{fname},{m},{float(mean):.5f},{float(std):.5f}")


if __name__ == "__main__":
    main()
